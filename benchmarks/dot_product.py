"""Paper §III.B / Fig. 4: the 64-length dot-product compute flow.

(a) Exactness: the pure-integer flow (absorbed micro-exponent shifts, one
    final float multiply) equals the dequantized-float dot BIT-EXACTLY —
    the property that lets hardware drop the per-group float multipliers.
(b) Multiplier accounting (Fig. 4, analytic — no RTL here): per 64-length
    PE dot, HiF4 needs 1 small FP + 1 large INT multiplier at the tree
    root; NVFP4 (4 groups of 16) needs 4 + 4. The paper's area claim
    (~1/3 incremental area, ~-10% power) follows from this 6-multiplier
    elimination; we reproduce the count, not the synthesis.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hif4, nvfp4
from repro.core.qlinear import hif4_dot_fixed_point


def run(n_trials: int = 64, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    exact = 0
    for t in range(n_trials):
        k1, k2 = jax.random.split(jax.random.fold_in(key, t))
        scale = 2.0 ** ((t % 13) - 6)
        a = jax.random.normal(k1, (64,), jnp.float32) * scale
        b = jax.random.normal(k2, (64,), jnp.float32) * scale
        fp = float(hif4_dot_fixed_point(a, b))
        ga, gb = hif4.quantize_groups(a[None]), hif4.quantize_groups(b[None])
        deq = float(
            jnp.sum(hif4.dequantize_groups(ga) * hif4.dequantize_groups(gb))
        )
        exact += int(fp == deq)

    # NVFP4 absorbed-int flow for comparison (4 groups of 16, S3P1 halves)
    k1, k2 = jax.random.split(jax.random.fold_in(key, 999))
    a = jax.random.normal(k1, (64,), jnp.float32)
    b = jax.random.normal(k2, (64,), jnp.float32)
    ga = nvfp4.quantize_groups(a.reshape(4, 16))
    gb = nvfp4.quantize_groups(b.reshape(4, 16))
    ia, sa = nvfp4.to_absorbed_int(ga)
    ib, sb = nvfp4.to_absorbed_int(gb)
    acc = jnp.sum(ia.astype(jnp.int32) * ib.astype(jnp.int32), axis=-1)
    nv_fp = float(jnp.sum(sa * sb * acc.astype(jnp.float32)))
    nv_deq = float(
        jnp.sum(nvfp4.dequantize_groups(ga) * nvfp4.dequantize_groups(gb))
    )

    counts = {
        # per 64-length PE dot, beyond the shared int adder tree
        "hif4": {"fp_multipliers": 1, "int_multipliers_large": 1,
                 "groups_per_pe": 1, "element_int_width": "S2P2 (5b)"},
        "nvfp4": {"fp_multipliers": 4, "int_multipliers_large": 4,
                  "groups_per_pe": 4, "element_int_width": "S3P1 (5b)"},
    }
    return {
        "hif4_exact_fraction": exact / n_trials,
        "nvfp4_flow_matches_dequant": abs(nv_fp - nv_deq) < 1e-5 * max(abs(nv_deq), 1e-9),
        "multiplier_counts": counts,
        "multipliers_eliminated_vs_nvfp4": 6,
    }


def main():
    out = run()
    print("== §III.B: 64-length dot-product compute flow ==")
    print(f"  HiF4 integer flow == dequant dot (bit-exact): "
          f"{out['hif4_exact_fraction'] * 100:.0f}% of trials")
    print(f"  NVFP4 4-group flow matches dequant: "
          f"{out['nvfp4_flow_matches_dequant']}")
    print("  multiplier accounting per 64-length PE (Fig. 4):")
    for f, c in out["multiplier_counts"].items():
        print(f"    {f:6} fp x{c['fp_multipliers']}  large-int x"
              f"{c['int_multipliers_large']}  ({c['groups_per_pe']} group(s))")
    print(f"  -> HiF4 eliminates {out['multipliers_eliminated_vs_nvfp4']} "
          f"multipliers per PE vs NVFP4")
    assert out["hif4_exact_fraction"] == 1.0
    assert out["nvfp4_flow_matches_dequant"]


if __name__ == "__main__":
    main()
