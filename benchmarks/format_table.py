"""Paper Table I + Table II: format constants, derived from the
implementation (not hard-coded) and checked against the paper's numbers."""
import jax.numpy as jnp
import numpy as np

from repro.core import hif4, nvfp4
from repro.core import rounding as R


def run() -> dict:
    rows = {
        "hif4": {
            "storage_bits": hif4.BITS_PER_VALUE,
            "group_size": hif4.GROUP_SIZE,
            "element": "S1P2 (E1M2), 3-bit significand",
            "scale": "E6M2 (bias 48)",
            "max_pos": float(hif4.MAX_POS),
            "min_pos": float(hif4.MIN_POS),
            "global_range_binades": float(np.log2(hif4.MAX_POS / hif4.MIN_POS)),
            "local_range_binades": float(np.log2(7.0 / 0.25)),
        },
        "nvfp4": {
            "storage_bits": nvfp4.BITS_PER_VALUE,
            "group_size": nvfp4.GROUP_SIZE,
            "element": "E2M1, 2-bit significand",
            "scale": "E4M3",
            "max_pos": float(nvfp4.MAX_POS),
            "min_pos": float(nvfp4.MIN_POS),
            "global_range_binades": float(np.log2(nvfp4.MAX_POS / nvfp4.MIN_POS)),
            "local_range_binades": float(np.log2(6.0 / 0.5)),
        },
    }
    # paper checks (Table II)
    checks = {
        "hif4_max_is_2^18*1.3125": rows["hif4"]["max_pos"] == 2.0 ** 18 * 1.3125,
        "hif4_min_is_2^-50": rows["hif4"]["min_pos"] == 2.0 ** -50,
        "nvfp4_max_is_2^11*1.3125": rows["nvfp4"]["max_pos"] == 2.0 ** 11 * 1.3125,
        "nvfp4_min_is_2^-10": rows["nvfp4"]["min_pos"] == 2.0 ** -10,
        "e6m2_nan_code_reserved": int(
            R.encode_e6m2(R.round_e6m2(jnp.float32(1e30)))
        ) != R.E6M2_NAN_BITS,
    }
    return {"rows": rows, "checks": checks}


def main():
    out = run()
    print("== Table I/II: format constants (derived from implementation) ==")
    for name, row in out["rows"].items():
        print(f"  {name}:")
        for k, v in row.items():
            print(f"    {k:22} {v}")
    print("  paper-claim checks:", out["checks"])
    assert all(out["checks"].values())


if __name__ == "__main__":
    main()
