"""Paper Tables III-V proxy: LLM inference accuracy per quantization mode.

The container is offline (no 7B-671B checkpoints), so we reproduce the
paper's QUALITATIVE ordering on a trained-from-scratch small LM evaluated
on held-out synthetic data (DESIGN.md §8):

    BF16 >= HiF4+HiGPTQ >= HiF4 >= NVFP4+PTS >= NVFP4   (accuracy)

plus the Mistral-7B phenomenon: inject a wide-dynamic-range scale pattern
into the weights and NVFP4 direct-cast collapses to random-guess level
("inference crash", Table III) while HiF4 survives — the 69-vs-22-binade
global range at work.

Metrics: next-token accuracy + CE loss on held-out batches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.higptq import quantize_stacked
from repro.core.metrics import agreement
from repro.core.qlinear import QuantConfig
from repro.data import SyntheticLMDataset
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import TrainLoopConfig, train

MODES = ("bf16", "nvfp4", "nvfp4_pts", "hif4", "hif4_higptq")


def _ctx(fmt: str) -> ModelCtx:
    q = QuantConfig() if fmt == "bf16" else QuantConfig(
        fmt=fmt.replace("_higptq", ""), offline_weights=fmt.endswith("higptq"))
    return ModelCtx(quant=q, remat=False, attn_q_chunk=32, attn_k_chunk=32)


def _eval(cfg, params, fmt: str, data: SyntheticLMDataset, n_batches=4,
          ref_preds=None, ctx=None):
    ctx = ctx or _ctx(fmt)
    losses, accs, agrees = [], [], []
    fwd = jax.jit(lambda p, b: _loss_acc_preds(p, b, cfg, ctx))
    preds_out = []
    for i in range(n_batches):
        batch = data.batch_at(10_000 + i)     # held out from training steps
        l, a, pred = fwd(params, batch)
        losses.append(float(l))
        accs.append(float(a))
        preds_out.append(pred)
        if ref_preds is not None:
            agrees.append(agreement(pred, ref_preds[i]))
    return {
        "loss": float(np.mean(losses)),
        "acc": float(np.mean(accs)),
        "agree_bf16": float(np.mean(agrees)) if agrees else 1.0,
        "preds": preds_out,
    }


def _loss_acc_preds(params, batch, cfg, ctx):
    tokens = batch["tokens"]
    x = lm.embed_tokens(params, tokens, cfg, ctx)
    h, _ = lm._backbone(params, x, cfg, ctx, mode="train")
    logits = lm.lm_logits(params, h, cfg, ctx)
    from repro.models.common import cross_entropy
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
    preds = jnp.argmax(logits[:, :-1], -1)
    acc = jnp.mean(preds == tokens[:, 1:])
    return loss, acc, preds


def _layer_calibration(cfg, params, data):
    """Per-layer TRUE calibration inputs: the post-norm activations each
    quantized matmul actually consumes (GPTQ's Hessian is only meaningful
    for the layer's real input distribution)."""
    from repro.models import transformer as tf

    ctx = _ctx("bf16")
    tokens = data.batch_at(20_000)["tokens"]
    x = lm.embed_tokens(params, tokens, cfg, ctx)

    def body(h, p_layer):
        h1 = tf.norm_apply(p_layer["norm1"], h, cfg)          # attn input
        a, _ = tf.attn_full(p_layer["attn"], h1, cfg, ctx)
        h_mid = h + a
        h2 = tf.norm_apply(p_layer["norm2"], h_mid, cfg)      # mlp input
        f = tf.mlp_apply(p_layer["mlp"], h2, cfg, ctx)
        return h_mid + f, (h1, h2)

    _, (h1s, h2s) = jax.lax.scan(body, x, params["blocks"])
    d = cfg.d_model
    return (np.asarray(h1s.astype(jnp.float32)).reshape(h1s.shape[0], -1, d),
            np.asarray(h2s.astype(jnp.float32)).reshape(h2s.shape[0], -1, d))


def _apply_higptq(cfg, params, data):
    """Offline HiGPTQ with true per-layer calibration for the input
    projections (wq/wk/wv on norm1 output, wg/wu on norm2 output); output
    projections and biases stay direct-cast (their inputs depend on the
    just-quantized weights — the standard sequential-GPTQ refinement is
    out of scope for this proxy)."""
    h1s, h2s = _layer_calibration(cfg, params, data)
    n_samples = min(512, h1s.shape[1])

    blocks = jax.tree_util.tree_map(lambda v: v, params["blocks"])
    attn = dict(blocks["attn"])
    mlp = dict(blocks["mlp"])
    for key in ("wq", "wk", "wv"):
        attn[key] = quantize_stacked(blocks["attn"][key], h1s,
                                     n_samples=n_samples)
    for key in ("wg", "wu", "wi"):
        if key in mlp:
            mlp[key] = quantize_stacked(blocks["mlp"][key], h2s,
                                        n_samples=n_samples)
    # direct-cast the rest so the whole model is HiF4-quantized
    from repro.core.qlinear import quantize_params_offline
    rest = quantize_params_offline(
        {"attn": {"wo": blocks["attn"]["wo"]}, "mlp": {"wo": mlp["wo"]}},
        QuantConfig(fmt="hif4"))
    attn["wo"] = rest["attn"]["wo"]
    mlp["wo"] = rest["mlp"]["wo"]
    blocks = dict(blocks)
    blocks["attn"] = attn
    blocks["mlp"] = mlp

    out = dict(params)
    out["blocks"] = blocks
    return out


def _inject_outliers(params, alpha=2.0 ** 13):
    """Mistral-like wide numerical distribution, FUNCTION-PRESERVING.

    Scale every pre-attention/pre-MLP norm gain by alpha and divide the
    following projections' input rows by alpha: in exact arithmetic the
    network is unchanged, but activations now live at ~2^13 and weights at
    ~2^-13·w. BF16 (and HiF4's 69-binade range) absorb this; NVFP4's E4M3
    group scales clip at 448 on the activation side and underflow below
    2^-10 on the weight side -> the Table III "inference crash"."""
    blocks = jax.tree_util.tree_map(lambda x: x, params["blocks"])  # copy

    def scale_norm(norm):
        return {k: (v.astype(jnp.float32) * alpha).astype(v.dtype)
                if k == "w" else v for k, v in norm.items()}

    def scale_in_rows(w):
        # (L, d_in, ...): divide input rows by alpha
        return (w.astype(jnp.float32) / alpha).astype(w.dtype)

    blocks["norm1"] = scale_norm(blocks["norm1"])
    for k in ("wq", "wk", "wv"):
        blocks["attn"][k] = scale_in_rows(blocks["attn"][k])
    blocks["norm2"] = scale_norm(blocks["norm2"])
    ff = blocks.get("mlp", blocks.get("moe"))
    for k in ("wg", "wu", "wi"):
        if k in ff:
            ff[k] = scale_in_rows(ff[k])

    out = dict(params)
    out["blocks"] = blocks
    return out


NOISE = 0.35   # hard enough that 4-bit noise moves accuracy


def run(train_steps: int = 150, seed: int = 0) -> dict:
    cfg = get_arch("qwen1.5-0.5b").reduced()
    base_ctx = _ctx("bf16")
    params, _, hist = train(cfg, base_ctx, TrainLoopConfig(
        steps=train_steps, global_batch=8, seq_len=64, seed=seed,
        data_noise=NOISE))
    data = SyntheticLMDataset(cfg.vocab, 64, 8, seed=seed, noise=NOISE)

    ref = _eval(cfg, params, "bf16", data)
    results = {"bf16": ref}
    params_g = _apply_higptq(cfg, params, data)
    for mode in MODES[1:]:
        p = params_g if mode == "hif4_higptq" else params
        results[mode] = _eval(cfg, p, mode, data, ref_preds=ref["preds"])

    # weight-only PTQ comparison (isolates the HiGPTQ objective: bf16
    # activations, HiF4 weights baked offline)
    from repro.core.qlinear import quantize_params_offline
    direct = dict(params)
    direct["blocks"] = quantize_params_offline(
        params["blocks"], QuantConfig(fmt="hif4"), contract_axis=0)
    wctx = _ctx("bf16")
    wonly = {
        "direct_cast": _eval(cfg, direct, "bf16", data, ref_preds=ref["preds"],
                             ctx=wctx),
        "higptq": _eval(cfg, params_g, "bf16", data, ref_preds=ref["preds"],
                        ctx=wctx),
    }

    # crash experiment (Table III Mistral row)
    wide = _inject_outliers(params)
    crash = {}
    for mode in ("bf16", "nvfp4", "nvfp4_pts", "hif4"):
        crash[mode] = _eval(cfg, wide, mode, data)
    for d in (results, wonly, crash):
        for r in d.values():
            r.pop("preds", None)
    return {"train_final_loss": hist["loss"][-1], "standard": results,
            "weight_only": wonly, "outlier_model": crash,
            "random_guess_acc": 1.0 / cfg.vocab}


def main():
    out = run()
    print("== Tables III-V proxy: tiny-LM accuracy per quantization mode ==")
    print(f"{'mode':12} {'loss':>8} {'acc':>8} {'agree/bf16':>11}")
    for m in MODES:
        r = out["standard"][m]
        print(f"{m:12} {r['loss']:8.4f} {100 * r['acc']:7.2f}% "
              f"{100 * r['agree_bf16']:10.2f}%")
    print("\n-- weight-only PTQ (bf16 activations; isolates HiGPTQ) --")
    for m, r in out["weight_only"].items():
        print(f"{m:12} {r['loss']:8.4f} {100 * r['agree_bf16']:10.2f}%")
    print("\n-- wide-distribution model (Mistral-7B phenomenon) --")
    for m, r in out["outlier_model"].items():
        tag = "  << CRASH" if r["acc"] < 4 * out["random_guess_acc"] and m != "bf16" else ""
        print(f"{m:12} {r['loss']:8.3f} {100 * r['acc']:7.2f}%{tag}")

    s = out["standard"]
    # ordering claims (loss = the sensitive metric at this scale)
    assert s["hif4"]["loss"] <= s["nvfp4"]["loss"], "HiF4 must beat NVFP4"
    assert s["hif4"]["agree_bf16"] >= s["nvfp4"]["agree_bf16"] - 0.005
    w = out["weight_only"]
    assert w["higptq"]["loss"] <= w["direct_cast"]["loss"] + 1e-4, w
    o = out["outlier_model"]
    assert o["hif4"]["acc"] > 5 * o["nvfp4"]["acc"], "NVFP4 must crash, HiF4 survive"
    assert o["nvfp4_pts"]["acc"] > 5 * o["nvfp4"]["acc"], "PTS must rescue NVFP4"


if __name__ == "__main__":
    main()
