"""Scenario matrix: the serve perf-regression surface (BENCH_matrix.json).

    PYTHONPATH=src python -m benchmarks.matrix --cells smoke
    PYTHONPATH=src python -m benchmarks.matrix --cells all --update

Every cell is a :class:`repro.runtime.scenario.Scenario` declared below as
data: arch x impl x kv_format (bf16 / hif4 / paged-hif4) x policy preset x
batch x seqlen, with per-cell expected-dispatch assertions (which engine
route the cell MUST take — e.g. a paged cell must route through
``fused_paged_decode_attention``, never the chunked twin) and a per-cell
regression tolerance. Cells execute through the real serve stack
(``repro.runtime.scenario.run_scenarios``); each records measured decode /
prefill latency next to a roofline prediction from EXACT HiF4 payload byte
counts (0.5625 B/value packed weights; ``kvcache.kv_bytes_per_token`` KV)
against the measured stream bandwidth (``benchmarks.roofline``).

Gates (all named in GATE_NAMES; ``benchmarks/run.py check_matrix_gates``
enforces them against the committed trajectory, failing loudly — never
skipping — on a missing field, a failed dispatch assertion, a silent
hif4->bf16 fallback, or a ratio regression):

  cell_coverage            >= 30 cells over all 6 families, all 3 impls
  dispatch_ok              every cell passed its expected-dispatch asserts
  no_silent_fallback       kv_format_fallback only where the cell declared
                           it (ssm / hybrid expected-fallback cells)
  trajectory_regression    fresh decode_step_ms <= stored * rel_tol
                           (checked by `--cells` runs vs BENCH_matrix.json)
  packed_over_qdq_decode   packed decode >= 0.9x qdq (fused-matmul claim)
  hif4_over_bf16_kv_decode hif4-KV decode >= 0.9x bf16-KV (fused-attention
                           claim)
  guard_overhead           guarded decode (NaN sentinel + meta audit)
                           >= 0.98x unguarded (guards nearly free)
  journal_overhead         journaled paged decode (write-ahead journal,
                           one fsync per decode chunk)
                           >= 0.98x the chunk-matched unjournaled cell
  recovery_replay          the crash+resume cell recovered every request
                           bitwise-identical to its uninterrupted run and
                           recorded the recovery timings
  searched_policy_frontier the calibration-searched policy (repro
                           calibrate at the sensitive-fallback preset's
                           byte budget) serves the searched cell at
                           <= the preset's bytes AND <= its error on the
                           same calibration set (record["calibration"])

The two ratio gates moved here from ``benchmarks/serve_throughput.py``
(which still RECORDS its ratios in BENCH_serve.json, but no longer
asserts them) — this matrix is the single perf-regression surface.
"""
import argparse
import json
import os

from repro.runtime.scenario import Scenario, run_scenarios

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_matrix.json")
VERSION = 1

ARCHS = {
    "qwen": ("qwen1.5-0.5b", "dense"),
    "moe": ("granite-moe-1b-a400m", "moe"),
    "mamba": ("mamba2-1.3b", "ssm"),
    "hybrid": ("zamba2-2.7b", "hybrid"),
    "whisper": ("whisper-tiny", "audio"),
    "llava": ("llava-next-34b", "vlm"),
}

GATE_NAMES = frozenset({
    "cell_coverage", "dispatch_ok", "no_silent_fallback",
    "trajectory_regression", "packed_over_qdq_decode",
    "hif4_over_bf16_kv_decode", "guard_overhead", "journal_overhead",
    "recovery_replay", "searched_policy_frontier",
})

# the crash+resume cell recovery_replay inspects
RECOVERY_CELL = "qwen-packed-hif4-recovery"

# the calibration-searched policy cell searched_policy_frontier inspects:
# `repro calibrate` is run at the CALIBRATION_BASELINE preset's measured
# byte budget, the emitted policy lands at SEARCHED_POLICY, and the cell
# serves it through the normal --policy <file> path
CALIBRATION_CELL = "qwen-packed-hif4-searched"
CALIBRATION_BASELINE = "sensitive-fallback"
SEARCHED_POLICY = os.path.join(os.path.dirname(__file__),
                               "searched_policy.json")


def build_calibration(log=print) -> dict:
    """Run the calibrator for the searched cell: emit SEARCHED_POLICY at
    the baseline preset's byte budget and return the gate summary that
    lands in record["calibration"]."""
    from repro.calibrate import calibrate

    s = calibrate("qwen1.5-0.5b", reduced=True,
                  target_bpv=CALIBRATION_BASELINE, kv_format="hif4",
                  out=SEARCHED_POLICY, log=log)
    fb = s["baselines"][CALIBRATION_BASELINE]
    return {
        "cell": CALIBRATION_CELL,
        "policy": os.path.basename(SEARCHED_POLICY),
        "arch": s["arch"],
        "target": CALIBRATION_BASELINE,
        "budget_met": s["feasible"],
        "n_sites": s["n_sites"],
        "searched": {"total_bytes": s["total_bytes"],
                     "total_error": round(s["total_error"], 3),
                     "bpv": s["achieved_bpv"]},
        "baseline": {"total_bytes": fb["total_bytes"],
                     "total_error": round(fb["total_error"], 3),
                     "bpv": fb["achieved_bpv"]},
    }

# value = baseline decode_step_ms / subject decode_step_ms; the subject
# must hold >= min_ratio of the baseline's decode rate. Both sides of
# each ratio are timed interleaved in the same loop, so load phases
# cancel — these are the two hand-coded serve gates, now matrix cells.
RATIO_GATES = (
    {"name": "packed_over_qdq_decode", "subject": "qwen-packed-bf16",
     "baseline": "qwen-qdq-bf16", "min_ratio": 0.9},
    {"name": "hif4_over_bf16_kv_decode", "subject": "qwen-packed-hif4",
     "baseline": "qwen-packed-bf16", "min_ratio": 0.9},
    # guarded decode (NaN scan sentinel + per-chunk 0xFF meta audit) must
    # hold >= 0.98x of the unguarded cell's decode rate — the "guards are
    # nearly free" claim of the failure-semantics docs (<= ~1.02x cost)
    {"name": "guard_overhead", "subject": "qwen-packed-hif4-guarded",
     "baseline": "qwen-packed-hif4", "min_ratio": 0.98},
    # the write-ahead journal (record framing + one fsync per decode
    # chunk) must hold >= 0.98x of the chunk-matched unjournaled paged
    # cell's decode rate — durable bookkeeping is nearly free. Pool
    # checkpoints are a cadence knob timed by the recovery cell, not
    # ratio-gated here: at benchmark-cell scale (2-token chunks) any
    # cadence is absurdly dense relative to real serving.
    {"name": "journal_overhead",
     "subject": "qwen-packed-hif4-paged-journaled",
     "baseline": "qwen-packed-hif4-paged-chunked", "min_ratio": 0.98},
)


def _expect(family: str, impl: str, kv: str, paged: bool = False) -> tuple:
    """The dispatch assertions a (family, impl, kv_format) cell must pass —
    the single source of truth the cell declarations below draw from."""
    if kv == "hif4":
        if family == "ssm":
            e = ["kv:bf16", "kv:fallback", "attn:none"]
        elif family == "hybrid":
            e = ["kv:bf16", "kv:fallback", "attn:dense"]
        else:
            e = ["kv:hif4", "kv:no-fallback"]
            if paged:
                e.append("attn:fused_paged_decode_attention")
            elif impl in ("packed", "pallas") and family != "vlm":
                e.append("attn:fused_decode_attention")
            else:
                # qdq always takes the dense twin; so does the reduced vlm
                # arch, whose 1 kv-head x 32 d_head = 32 features/token is
                # below one 64-elem HiF4 group — the packed cache is
                # tail-only and the fused kernel is ineligible by design
                e.append("attn:twin")
    else:
        e = ["kv:bf16", "kv:no-fallback",
             "attn:none" if family == "ssm" else "attn:dense"]
    # hybrid's doubly-stacked blocks never pack; qdq fake-quants dense dots
    e.append("matmul:qdq" if (family == "hybrid" or impl == "qdq")
             else "matmul:fused")
    return tuple(e)


def _cells() -> tuple:
    cells = []
    # every family x every impl on the requested-hif4 column
    for short, (arch, family) in ARCHS.items():
        for impl in ("qdq", "packed", "pallas"):
            cells.append(Scenario(
                name=f"{short}-{impl}-hif4", arch=arch, impl=impl,
                kv_format="hif4", expect=_expect(family, impl, "hif4")))
    # every family on the bf16 column (packed impl), + the qdq baseline
    # the packed_over_qdq_decode ratio gate compares against
    for short, (arch, family) in ARCHS.items():
        cells.append(Scenario(
            name=f"{short}-packed-bf16", arch=arch, impl="packed",
            kv_format="bf16", expect=_expect(family, "packed", "bf16")))
    cells.append(Scenario(
        name="qwen-qdq-bf16", arch="qwen1.5-0.5b", impl="qdq",
        kv_format="bf16", expect=_expect("dense", "qdq", "bf16")))
    # mixed-policy presets on the packed path (dense + moe)
    for short in ("qwen", "moe"):
        arch, family = ARCHS[short]
        for policy in ("paper-iv", "sensitive-fallback"):
            cells.append(Scenario(
                name=f"{short}-packed-hif4-{policy}", arch=arch,
                impl="packed", kv_format="hif4", policy=policy,
                expect=_expect(family, "packed", "hif4")))
    # paged-hif4 page-pool cells (continuous-batching scheduler e2e)
    for short in ("qwen", "moe"):
        arch, family = ARCHS[short]
        cells.append(Scenario(
            name=f"{short}-packed-hif4-paged", arch=arch, impl="packed",
            kv_format="hif4", paged=True, rel_tol=4.0,
            expect=_expect(family, "packed", "hif4", paged=True)))
    # crash-safety column on the hot paged cell: a chunk-matched
    # unjournaled baseline, its journaled twin (journal_overhead gate),
    # and the crash+resume recovery cell (recovery_replay gate)
    cells.append(Scenario(
        name="qwen-packed-hif4-paged-chunked", arch="qwen1.5-0.5b",
        impl="packed", kv_format="hif4", paged=True, decode_chunk=2,
        rel_tol=4.0, expect=_expect("dense", "packed", "hif4", paged=True)))
    cells.append(Scenario(
        name="qwen-packed-hif4-paged-journaled", arch="qwen1.5-0.5b",
        impl="packed", kv_format="hif4", paged=True, journaled=True,
        decode_chunk=2, rel_tol=4.0,
        expect=_expect("dense", "packed", "hif4", paged=True)))
    cells.append(Scenario(
        name="qwen-packed-hif4-recovery", arch="qwen1.5-0.5b",
        impl="packed", kv_format="hif4", paged=True, journaled=True,
        recovery=True, decode_chunk=2, rel_tol=6.0,
        expect=_expect("dense", "packed", "hif4", paged=True)))
    # the calibration-searched policy on the hot dense cell: the emitted
    # file is regenerated by build_calibration() before this cell runs
    # (searched_policy_frontier gate). No matmul expectation: which sites
    # the search packs is DATA — plan.base (the attention-site config)
    # legitimately lands on bf16 when the probe measures wq/wk/wv as the
    # sensitive sites, while the mlp matmuls still serve PackedW fused.
    cells.append(Scenario(
        name=CALIBRATION_CELL, arch="qwen1.5-0.5b", impl="packed",
        kv_format="hif4", policy=SEARCHED_POLICY,
        expect=("kv:hif4", "kv:no-fallback",
                "attn:fused_decode_attention")))
    # the guarded twin of the hot dense cell (guard_overhead gate subject)
    cells.append(Scenario(
        name="qwen-packed-hif4-guarded", arch="qwen1.5-0.5b", impl="packed",
        kv_format="hif4", guarded=True,
        expect=_expect("dense", "packed", "hif4")))
    # batch / seqlen variation on the hot dense cell
    cells.append(Scenario(
        name="qwen-packed-hif4-b4", arch="qwen1.5-0.5b", impl="packed",
        kv_format="hif4", batch=4, expect=_expect("dense", "packed", "hif4")))
    cells.append(Scenario(
        name="qwen-packed-hif4-long", arch="qwen1.5-0.5b", impl="packed",
        kv_format="hif4", prompt_len=48, new_tokens=16,
        expect=_expect("dense", "packed", "hif4")))
    cells.append(Scenario(
        name="llava-packed-hif4-b4", arch="llava-next-34b", impl="packed",
        kv_format="hif4", batch=4, expect=_expect("vlm", "packed", "hif4")))
    return tuple(cells)


CELLS = _cells()

SMOKE = ("qwen-qdq-bf16", "qwen-packed-bf16", "qwen-packed-hif4",
         "whisper-packed-hif4", "mamba-packed-hif4", "qwen-packed-hif4-paged")


def compute_ratio_gates(by_name: dict) -> list:
    """Ratio gates prefer the subject cell's ``gate_timing`` entry for
    their baseline — the tight pairwise A/B interleave (see
    scenario.run_scenarios) that keeps both sides under identical
    machine conditions — and fall back to the global-rotation
    ``decode_step_ms`` when a run didn't produce one (subset runs,
    synthetic records)."""
    out = []
    for g in RATIO_GATES:
        sub, base = by_name.get(g["subject"]), by_name.get(g["baseline"])
        value = None
        if sub and base:
            gt = (sub.get("gate_timing") or {}).get(g["baseline"])
            if gt:
                value = round(gt["baseline_ms"] / gt["subject_ms"], 3)
            else:
                value = round(
                    base["decode_step_ms"] / sub["decode_step_ms"], 3)
        out.append({**g, "value": value})
    return out


def check(record: dict, *, min_cells: int = 30) -> None:
    """Static gates on a (committed) BENCH_matrix.json record — raises
    AssertionError on any violation, loudly naming the gate."""
    assert record.get("version") == VERSION, (
        f"BENCH_matrix.json version {record.get('version')!r} != {VERSION}")
    cells = record.get("cells")
    assert cells, "BENCH_matrix.json has no cells"
    names = [c["name"] for c in cells]
    assert len(set(names)) == len(names), f"duplicate cell names: {names}"
    by_name = {c["name"]: c for c in cells}

    # gate: cell_coverage
    families = {c["family"] for c in cells}
    impls = {c["impl"] for c in cells}
    assert len(cells) >= min_cells, (
        f"cell_coverage gate: {len(cells)} cells < {min_cells}")
    assert families >= {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}, (
        f"cell_coverage gate: families {sorted(families)} miss a family")
    assert impls >= {"qdq", "packed", "pallas"}, (
        f"cell_coverage gate: impls {sorted(impls)} miss an impl")

    for c in cells:
        # every cell must carry measurement + prediction + assertions
        for field in ("decode_step_ms", "roofline", "dispatch", "expect",
                      "rel_tol"):
            assert c.get(field) is not None, (
                f"cell {c['name']}: missing `{field}`")
        for field in ("bytes_per_step", "predicted_ms", "achieved_fraction"):
            assert c["roofline"].get(field) is not None, (
                f"cell {c['name']}: roofline missing `{field}`")
        # gate: dispatch_ok
        assert c.get("dispatch_ok") is True, (
            f"dispatch_ok gate: cell {c['name']} failed its expected-"
            f"dispatch assertions: {c.get('dispatch_failures')}")
        # gate: no_silent_fallback — a narrowed kv_format is only legal
        # when the cell DECLARED itself an expected-fallback cell
        if c["dispatch"]["kv_format_fallback"]:
            assert "kv:fallback" in c["expect"], (
                f"no_silent_fallback gate: cell {c['name']} fell back "
                f"{c['kv_format']}->{c['kv_format_resolved']} without "
                f"declaring kv:fallback")
        # the enc-dec families must serve the real format (cross-attention
        # KV packs — the permanent-fallback cells are gone)
        if c["family"] in ("audio", "vlm") and c["kv_format"] == "hif4":
            assert not c["dispatch"]["kv_format_fallback"], (
                f"no_silent_fallback gate: enc-dec cell {c['name']} must "
                f"serve packed HiF4 KV, not fall back")

    # gates: packed_over_qdq_decode, hif4_over_bf16_kv_decode
    gates = {g["name"]: g for g in record.get("ratio_gates", [])}
    for g in RATIO_GATES:
        got = gates.get(g["name"])
        assert got is not None, (
            f"{g['name']} gate missing from BENCH_matrix.json ratio_gates")
        both = g["subject"] in by_name and g["baseline"] in by_name
        if got["value"] is None:
            assert not both, (
                f"{g['name']} gate: value null although both cells "
                f"({g['subject']}, {g['baseline']}) are in the matrix — "
                f"the gate was skipped, not inapplicable")
        else:
            assert got["value"] >= g["min_ratio"], (
                f"{g['name']} gate: {got['value']}x < {g['min_ratio']}x "
                f"({g['subject']} vs {g['baseline']})")

    # gate: recovery_replay — the crash+resume cell crashed for real,
    # recovered every request bitwise, and recorded its recovery timings
    rc = by_name.get(RECOVERY_CELL)
    assert rc is not None, (
        f"recovery_replay gate: cell {RECOVERY_CELL} missing from matrix")
    rec = rc.get("recovery")
    assert rec, (
        f"recovery_replay gate: cell {RECOVERY_CELL} has no recovery report")
    assert rec.get("crashed") is True, (
        f"recovery_replay gate: the injected crash never fired: {rec}")
    assert rec.get("bitwise") is True, (
        f"recovery_replay gate: recovered outputs are NOT bitwise "
        f"identical to the uninterrupted run: {rec}")
    for field in ("recovery_ms", "resume_ms", "verified"):
        assert rec.get(field) is not None, (
            f"recovery_replay gate: recovery report missing `{field}`: "
            f"{rec}")

    # gate: searched_policy_frontier — the calibration-searched policy
    # must Pareto-match the hand-written fallback preset: <= its bytes at
    # <= its error on the same calibration set, and the cell must have
    # actually served the searched file through the --policy path
    cal = record.get("calibration")
    assert cal, ("searched_policy_frontier gate: record has no "
                 "`calibration` section")
    cc = by_name.get(CALIBRATION_CELL)
    assert cc is not None, (
        f"searched_policy_frontier gate: cell {CALIBRATION_CELL} missing "
        f"from matrix")
    assert str(cc.get("policy", "")).endswith(".json"), (
        f"searched_policy_frontier gate: cell {CALIBRATION_CELL} did not "
        f"serve a policy FILE: {cc.get('policy')!r}")
    assert cal.get("budget_met") is True, (
        f"searched_policy_frontier gate: search missed the "
        f"{cal.get('target')!r} byte budget: {cal}")
    sr, fb = cal.get("searched"), cal.get("baseline")
    assert sr and fb, (
        f"searched_policy_frontier gate: calibration section incomplete: "
        f"{cal}")
    assert sr["total_bytes"] <= fb["total_bytes"], (
        f"searched_policy_frontier gate: searched policy resident bytes "
        f"{sr['total_bytes']} > {cal['target']} baseline "
        f"{fb['total_bytes']}")
    assert sr["total_error"] <= fb["total_error"], (
        f"searched_policy_frontier gate: searched policy calibration "
        f"error {sr['total_error']} > {cal['target']} baseline "
        f"{fb['total_error']} at <= its bytes")


def compare(stored: dict, fresh_cells: list) -> list:
    """gate: trajectory_regression — fresh measurements vs the stored
    trajectory, per-cell rel_tol. Returns failure strings (empty = pass)."""
    by_name = {c["name"]: c for c in stored.get("cells", [])}
    failures = []
    for c in fresh_cells:
        ref = by_name.get(c["name"])
        if ref is None:
            continue
        limit = ref["decode_step_ms"] * c["rel_tol"]
        if c["decode_step_ms"] > limit:
            failures.append(
                f"trajectory_regression gate: cell {c['name']} decode "
                f"{c['decode_step_ms']} ms/step > stored "
                f"{ref['decode_step_ms']} * rel_tol {c['rel_tol']} "
                f"= {round(limit, 4)} ms")
        for e in ref.get("expect", []):
            if e not in c["expect"]:
                failures.append(
                    f"cell {c['name']} dropped expectation {e!r} vs stored")
    return failures


def main(argv=None):
    import jax

    from benchmarks import roofline

    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="smoke",
                    help="'all', 'smoke', or comma-separated cell names")
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--update", action="store_true",
                    help="write BENCH_matrix.json (requires --cells all)")
    args = ap.parse_args(argv)

    if args.cells == "all":
        cells = CELLS
    elif args.cells == "smoke":
        cells = tuple(c for c in CELLS if c.name in SMOKE)
    else:
        wanted = set(args.cells.split(","))
        unknown = wanted - {c.name for c in CELLS}
        assert not unknown, f"unknown cells: {sorted(unknown)}"
        cells = tuple(c for c in CELLS if c.name in wanted)

    mem_bw = roofline.measure_stream_bandwidth()
    print(f"[matrix] backend={jax.default_backend()} "
          f"stream bandwidth {mem_bw / 2**30:.1f} GiB/s, "
          f"{len(cells)} cells")
    calibration = None
    if any(c.name == CALIBRATION_CELL for c in cells):
        # the searched cell serves a file the calibrator emits: (re)build
        # it now so the cell always serves THIS run's search
        calibration = build_calibration()
        print(f"[matrix] calibration: searched "
              f"{calibration['searched']['total_bytes']} B / err "
              f"{calibration['searched']['total_error']} vs "
              f"{calibration['target']} {calibration['baseline']['total_bytes']} "
              f"B / err {calibration['baseline']['total_error']}")
    gate_pairs = tuple((g["baseline"], g["subject"]) for g in RATIO_GATES)
    results = run_scenarios(cells, repeats=args.repeats,
                            gate_pairs=gate_pairs)
    for c in results:
        ro = c["roofline"]
        ro["mem_bw"] = round(mem_bw)
        ro["predicted_ms"] = round(
            roofline.predict_step_ms(ro["bytes_per_step"], mem_bw), 6)
        ro["achieved_fraction"] = round(
            ro["predicted_ms"] / c["decode_step_ms"], 6)

    bad = [c for c in results if not c["dispatch_ok"]]
    for c in results:
        ro = c["roofline"]
        print(f"{c['name']:28} decode {c['decode_step_ms']:9.3f} ms/step  "
              f"roofline {ro['predicted_ms']:8.4f} ms "
              f"({ro['achieved_fraction'] * 100:6.2f}% of stream bw)  "
              f"kv={c['kv_format_resolved']:5} "
              f"{'OK' if c['dispatch_ok'] else 'DISPATCH-FAIL'}")
    assert not bad, (
        "dispatch_ok gate: cells failed their expected-dispatch "
        "assertions: "
        + "; ".join(f"{c['name']}: {c['dispatch_failures']}" for c in bad))

    record = {
        "version": VERSION,
        "backend": jax.default_backend(),
        "mem_bw": mem_bw,
        "repeats": args.repeats,
        "ratio_gates": compute_ratio_gates({c["name"]: c for c in results}),
        "cells": results,
    }
    if calibration is not None:
        record["calibration"] = calibration
        assert calibration["budget_met"], (
            "searched_policy_frontier gate: search missed the "
            f"{calibration['target']!r} byte budget")
        assert (calibration["searched"]["total_bytes"]
                <= calibration["baseline"]["total_bytes"]), calibration
        assert (calibration["searched"]["total_error"]
                <= calibration["baseline"]["total_error"]), calibration
        print(f"[gate] searched_policy_frontier: "
              f"{calibration['searched']['total_bytes']} B <= "
              f"{calibration['baseline']['total_bytes']} B, err "
              f"{calibration['searched']['total_error']} <= "
              f"{calibration['baseline']['total_error']}")
    for g in record["ratio_gates"]:
        if g["value"] is not None:
            print(f"[gate] {g['name']}: {g['value']}x (min {g['min_ratio']}x)")
            assert g["value"] >= g["min_ratio"], (
                f"{g['name']} gate: {g['value']}x < {g['min_ratio']}x")

    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            stored = json.load(f)
        failures = compare(stored, results)
        if failures:
            raise AssertionError(
                "matrix regression vs stored trajectory:\n  "
                + "\n  ".join(failures))
        print(f"[matrix] {len(results)} cells within tolerance of the "
              f"stored trajectory")

    if args.update:
        assert args.cells == "all", "--update requires --cells all"
        check(record)
        with open(OUT_PATH, "w") as f:
            json.dump(record, f, indent=1)
        print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
