"""Paper Fig. 3: Gaussian quantization-error sweep.

18 matrices 1024x1024, sigma = 0.01 * 2^x for x in [0, 17]; MSE of each
4-bit BFP format normalized to HiF4. Expected (paper §III.A):
  * stable plateau HiF4 : NVFP4 : MXFP4 = 1 : 1.32 : 1.89,
  * NVFP4 direct-cast error blows up when sigma approaches its numeric
    bounds (fixed by PTS), HiF4/MXFP4 never blow up.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import QDQ_FORMATS, qdq_error

FORMATS = QDQ_FORMATS


N_PAPER = 18          # paper sweep: x in [0, 17]
N_EXT = 20            # +2 beyond-paper points to expose the full overflow


def run(n: int = 1024, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    table = {f: [] for f in FORMATS}
    sigmas = [0.01 * 2.0 ** x for x in range(N_EXT)]
    for x, sigma in enumerate(sigmas):
        m = jax.random.normal(jax.random.fold_in(key, x), (n, n), jnp.float32)
        m = m * sigma
        for f in FORMATS:
            table[f].append(qdq_error(m, f, metric="mse"))
    # plateau = paper-range points where NVFP4 is within 15% of its median
    # ("excluding NVFP4's fluctuation", §III.A)
    nv = [table["nvfp4"][i] / table["hif4"][i] for i in range(N_PAPER)]
    med = float(np.median(nv))
    stable = [i for i in range(N_PAPER) if abs(nv[i] - med) < 0.15 * med]
    ratios = {
        f: float(np.mean([table[f][i] / table["hif4"][i] for i in stable]))
        for f in FORMATS
    }
    return {"sigmas": sigmas, "mse": table, "stable_ratio_vs_hif4": ratios,
            "stable_idx": stable}


def main():
    out = run()
    print("== Fig. 3: Gaussian MSE sweep (normalized to HiF4) ==")
    print(f"{'x':>3} {'sigma':>12} " + " ".join(f"{f:>11}" for f in FORMATS))
    for i, s in enumerate(out["sigmas"]):
        row = " ".join(
            f"{out['mse'][f][i] / out['mse']['hif4'][i]:11.2f}" for f in FORMATS
        )
        tag = "  (beyond paper)" if i >= N_PAPER else ""
        print(f"{i:3d} {s:12.4g} {row}{tag}")
    r = out["stable_ratio_vs_hif4"]
    print(f"\nstable-region MSE ratio  HiF4 : NVFP4 : MXFP4 = "
          f"1 : {r['nvfp4']:.2f} : {r['mxfp4']:.2f}   (paper: 1 : 1.32 : 1.89)")
    assert 1.15 < r["nvfp4"] < 1.5, r
    assert 1.6 < r["mxfp4"] < 2.2, r
    # NVFP4 fluctuates at BOTH range ends without PTS; PTS flattens it
    under = out["mse"]["nvfp4"][0] / out["mse"]["hif4"][0]
    over17 = out["mse"]["nvfp4"][17] / out["mse"]["hif4"][17]
    over19 = out["mse"]["nvfp4"][19] / out["mse"]["hif4"][19]
    pts19 = out["mse"]["nvfp4_pts"][19] / out["mse"]["hif4"][19]
    print(f"NVFP4 fluctuation: x=0 underflow x{under:.2f}; x=17 x{over17:.2f}; "
          f"x=19 x{over19:.1f}  (PTS at x=19: x{pts19:.2f})")
    assert under > 1.6 and over17 > 1.8, (under, over17)
    assert over19 > 10 and pts19 < 3, (over19, pts19)


if __name__ == "__main__":
    main()
