"""Roofline models: dryrun aggregation + the serve-matrix prediction.

Two consumers share this module:

- :func:`main` reads the per-cell records the dry-run wrote (loop-aware
  FLOPs / HBM bytes / modeled ICI wire bytes per device) and emits the
  markdown table for EXPERIMENTS.md, including the dominant term and
  MODEL_FLOPS/HLO ratio.
- ``benchmarks/matrix.py`` uses :func:`measure_stream_bandwidth` +
  :func:`predict_step_ms` to turn the scenario harness's EXACT payload
  byte counts (``repro.runtime.scenario.decode_step_bytes``) into a
  predicted decode-step time per matrix cell — decode at these sizes is
  memory-bound, so bytes / stream-bandwidth is the floor, and the
  achieved fraction is an arch-independent perf signal.
"""
import glob
import json
import os
import time

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def measure_stream_bandwidth(nbytes: int = 1 << 27, repeats: int = 5) -> float:
    """Measured stream bandwidth (bytes/s) of THIS backend: best-of-N on a
    jitted elementwise map over ``nbytes`` of f32 (reads + writes the
    array once each). The denominator every matrix-cell roofline
    prediction shares — measured per run, so the predictions move with
    the machine, while the achieved fraction stays comparable."""
    import jax
    import jax.numpy as jnp

    x = jnp.zeros(nbytes // 4, jnp.float32)
    f = jax.jit(lambda a: a * 1.0000001 + 1.0)
    jax.block_until_ready(f(x))                      # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return 2 * x.nbytes / best


def predict_step_ms(bytes_per_step: int, mem_bw: float) -> float:
    """Memory-roofline decode-step time (ms): payload bytes / bandwidth."""
    return bytes_per_step / mem_bw * 1e3


def load_records(directory: str = DEFAULT_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "error" not in r:
            recs.append(r)
    return recs


def table(recs, mesh: str = "16x16", quant: str = "hif4"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh or r.get("quant") != quant:
            continue
        # the table compares like against like: only FSDP-sharded runs
        # with an explicit seq_shard flag qualify (a record that disabled
        # FSDP or predates the flag would skew the per-mesh comparison)
        if r.get("fsdp") is False or r.get("seq_shard") not in (True, False):
            continue
        ro = r["roofline"]
        step = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "t_compute_ms": ro["t_compute_s"] * 1e3,
            "t_memory_ms": ro["t_memory_s"] * 1e3,
            "t_collective_ms": ro["t_collective_s"] * 1e3,
            "dominant": ro["dominant"],
            "roofline_fraction": ro["t_compute_s"] / step if step else 0.0,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "peak_gib": r["memory"]["peak_bytes_est"] / 2 ** 30,
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def markdown(rows, title: str) -> str:
    out = [f"### {title}", "",
           "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
           "| comp/roofline | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} | "
            f"{r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(out)


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both")
        return
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh=mesh)
        if rows:
            print(markdown(rows, f"Roofline terms per (arch x shape), mesh {mesh}"))
            print()


if __name__ == "__main__":
    main()
