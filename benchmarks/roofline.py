"""Roofline aggregation: experiments/dryrun/*.json -> §Roofline table.

Reads the per-cell records the dry-run wrote (loop-aware FLOPs / HBM bytes
/ modeled ICI wire bytes per device) and emits the markdown table for
EXPERIMENTS.md, including the dominant term and MODEL_FLOPS/HLO ratio.
"""
import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(directory: str = DEFAULT_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "error" not in r:
            recs.append(r)
    return recs


def table(recs, mesh: str = "16x16", quant: str = "hif4"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh or r.get("quant") != quant:
            continue
        if r.get("fsdp") is False or r.get("seq_shard") not in (True, False):
            pass
        ro = r["roofline"]
        step = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "t_compute_ms": ro["t_compute_s"] * 1e3,
            "t_memory_ms": ro["t_memory_s"] * 1e3,
            "t_collective_ms": ro["t_collective_s"] * 1e3,
            "dominant": ro["dominant"],
            "roofline_fraction": ro["t_compute_s"] / step if step else 0.0,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "peak_gib": r["memory"]["peak_bytes_est"] / 2 ** 30,
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def markdown(rows, title: str) -> str:
    out = [f"### {title}", "",
           "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
           "| comp/roofline | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} | "
            f"{r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(out)


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both")
        return
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh=mesh)
        if rows:
            print(markdown(rows, f"Roofline terms per (arch x shape), mesh {mesh}"))
            print()


if __name__ == "__main__":
    main()
