"""Benchmark suite driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-llm]

  format_table     -> Table I / II   (format constants)
  quant_error      -> Fig. 3         (Gaussian MSE sweep, 1 : 1.32 : 1.89)
  dot_product      -> §III.B / Fig.4 (fixed-point flow + multiplier counts)
  llm_accuracy     -> Tables III-V   (tiny-LM proxy incl. the NVFP4 crash)
  serve_throughput -> deployment     (scan-decode tok/s per impl,
                                      decode-step latency per kv_format,
                                      paged scheduler gated >= 2x slot
                                      admission at equal KV bytes bitwise
                                      vs solo, prefill latency, 4.5-bit
                                      weight + KV-cache residency
                                      -> BENCH_serve.json; the two 0.9x
                                      decode ratio gates moved to the
                                      scenario matrix)
  roofline         -> §Roofline      (aggregates experiments/dryrun/*.json)
  check_matrix_gates -> perf gates   (BENCH_matrix.json scenario matrix:
                                      cell coverage, expected dispatch,
                                      no silent hif4->bf16 fallback, the
                                      packed/qdq + hif4/bf16 decode
                                      ratios — benchmarks/matrix.py is
                                      the single perf-regression surface)
  check_docs       -> repo lint      (README/docs must not reference dead
                                      symbols, files, or gate names)
"""
import argparse
import json
import os
import sys
import time


def check_matrix_gates(path=None):
    """The scenario matrix (benchmarks/matrix.py) is THE perf-regression
    surface: every gate — cell coverage across all families/impls,
    per-cell expected-dispatch assertions, no silent hif4->bf16 fallback,
    and the packed>=0.9x-qdq / hif4-KV>=0.9x-bf16-KV decode ratios that
    used to live as hand-coded asserts in serve_throughput — is validated
    here against the committed BENCH_matrix.json, failing loudly (never
    skipping) on a missing field, a failed assertion, or a regressed
    ratio. Re-measurement against the stored trajectory is matrix.py's
    `--cells` runs; this check is the static side every CI run pays.
    """
    from benchmarks import matrix

    path = path or os.path.join(os.path.dirname(__file__),
                                "BENCH_matrix.json")
    assert os.path.exists(path), (
        f"{os.path.basename(path)} missing — run "
        f"`python -m benchmarks.matrix --cells all --update`")
    with open(path) as f:
        record = json.load(f)
    matrix.check(record)
    cells = record["cells"]
    gates = {g["name"]: g["value"] for g in record["ratio_gates"]}
    print(f"[matrix gates] {len(cells)} cells "
          f"({len({c['family'] for c in cells})} families, "
          f"{len({c['impl'] for c in cells})} impls) on "
          f"{record['backend']}; all dispatch assertions passed; " +
          ", ".join(f"{k} = {v}x" for k, v in gates.items()))


def check_serve_gates():
    """BENCH_serve.json must still RECORD the serving comparisons — the
    per-impl decode ratio and per-kv_format decode-step ratio fields, the
    mixed-policy rows, and the paged-scheduler row. A benchmark refactor
    that silently drops a field must fail here loudly, not skip. The 0.9x
    THRESHOLDS on the two decode ratios moved to the scenario matrix
    (check_matrix_gates); the paged admission/bitwise gate stays here. A
    null value is accepted ONLY when the recorded sweep demonstrably
    lacks one side of the comparison (a narrowed `--impl`/`--kv-format`
    run) — null with both sides present means the field was skipped,
    which is exactly the failure this check exists for.
    """
    path = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    assert os.path.exists(path), (
        "benchmarks/BENCH_serve.json missing — run benchmarks.serve_throughput")
    with open(path) as f:
        record = json.load(f)
    rows = record.get("results", [])
    impls = {r.get("impl") for r in rows}
    packed_kvs = {r.get("kv_format") for r in rows if r.get("impl") == "packed"}
    both_sides = {
        "packed_over_qdq_decode": {"packed", "qdq"} <= impls,
        "hif4_over_bf16_kv_decode": {"bf16", "hif4"} <= packed_kvs,
    }
    shown = {}
    for gate, covered in both_sides.items():
        assert gate in record, (
            f"BENCH_serve.json lacks the `{gate}` gate — serve_throughput "
            f"must record (and assert) it, not skip it")
        if record[gate] is None:
            assert not covered, (
                f"BENCH_serve.json has `{gate}` = null although the sweep "
                f"covered both sides of the comparison — the gate was "
                f"skipped, not inapplicable")
            shown[gate] = "n/a (narrowed sweep)"
        else:
            shown[gate] = f"{record[gate]}x"
    print(f"[serve gates] packed/qdq decode = "
          f"{shown['packed_over_qdq_decode']}, hif4/bf16 KV decode = "
          f"{shown['hif4_over_bf16_kv_decode']}")

    # mixed-policy rows (QuantPolicy presets): required whenever the sweep
    # exercised the packed impl — a benchmark refactor that silently drops
    # the per-site-policy comparison must fail here, not vanish
    assert "policy_rows" in record, (
        "BENCH_serve.json lacks `policy_rows` — serve_throughput must "
        "record the mixed-policy (uniform:hif4 vs paper-iv) comparison")
    rows = record["policy_rows"]
    if rows is None:
        assert "packed" not in impls, (
            "BENCH_serve.json has `policy_rows` = null although the sweep "
            "covered the packed impl — the policy comparison was skipped, "
            "not inapplicable")
        print("[policy rows] n/a (narrowed sweep)")
    else:
        for required in ("uniform:hif4", "paper-iv"):
            assert required in rows, (
                f"policy_rows lacks the `{required}` row — the mixed-policy "
                f"comparison must cover it")
            assert rows[required].get("decode_step_ms"), (
                f"policy_rows[{required!r}] has no decode_step_ms")
        print("[policy rows] " + ", ".join(
            f"{name}: {r['decode_step_ms']} ms/step, "
            f"{r['packed_sites']}/{r['n_sites']} packed"
            for name, r in rows.items()))

    # paged continuous batching: required whenever the sweep covered the
    # packed impl with real hif4 KV — the page-pool capacity claim (>= 2x
    # admission at equal KV bytes, bitwise vs solo) must be recorded and
    # passing, not silently dropped by a benchmark refactor
    assert "paged_serve" in record, (
        "BENCH_serve.json lacks `paged_serve` — serve_throughput must "
        "record the paged-vs-slot scheduler comparison")
    paged = record["paged_serve"]
    if paged is None:
        assert "hif4" not in packed_kvs, (
            "BENCH_serve.json has `paged_serve` = null although the sweep "
            "covered packed + hif4 KV — the paged comparison was skipped, "
            "not inapplicable")
        print("[paged serve] n/a (narrowed sweep)")
    else:
        assert paged.get("bitwise_vs_solo") is True, (
            "paged_serve.bitwise_vs_solo is not true — paged scheduling "
            "must be bit-identical to solo serving")
        ratio = paged.get("admission_ratio")
        assert ratio is not None and ratio >= 2.0, (
            f"paged_serve.admission_ratio = {ratio!r} (gate: >= 2x the "
            f"slot scheduler's concurrency at the same KV byte budget)")
        assert not paged.get("kv_format_fallback"), (
            "paged_serve ran on a fallen-back KV format — the pool is "
            "HiF4-only, this row is mislabeled")
        print(f"[paged serve] {paged['max_concurrent_paged']} vs "
              f"{paged['max_concurrent_slot']} concurrent "
              f"({ratio}x) at {paged['pool_bytes']} KV bytes, "
              f"{paged['shared_page_hits']} shared-page hits, "
              f"{paged['preemptions']} preemptions, bitwise vs solo")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-llm", action="store_true",
                    help="skip the (slow) tiny-LM accuracy proxy")
    args = ap.parse_args()

    from benchmarks import dot_product, format_table, quant_error, roofline

    sections = [
        ("format_table (Table I/II)", format_table.main),
        ("quant_error (Fig. 3)", quant_error.main),
        ("dot_product (§III.B / Fig. 4)", dot_product.main),
    ]
    if not args.skip_llm:
        from benchmarks import llm_accuracy, serve_throughput
        sections.append(("llm_accuracy (Tables III-V proxy)", llm_accuracy.main))
        # LLM-class work too: init + prefill + decode of the reduced model
        sections.append(
            ("serve_throughput (deployment)", lambda: serve_throughput.main([]))
        )
    sections.append(("roofline (§Roofline)", roofline.main))

    # the serve + matrix gates are checked even under --skip-llm (against
    # the committed BENCH_*.json): a missing gate fails loudly, never skips
    sections.append(("serve perf gates (BENCH_serve.json)", check_serve_gates))
    sections.append(("matrix perf gates (BENCH_matrix.json)", check_matrix_gates))

    from tools import check_docs
    sections.append(("check_docs (repo lint)", check_docs.main))

    failures = 0
    for name, fn in sections:
        print("=" * 72)
        print(f"== {name}")
        print("=" * 72)
        t0 = time.time()
        try:
            fn()
            print(f"[ok] {name} ({time.time() - t0:.1f}s)\n")
        except AssertionError as e:
            failures += 1
            print(f"[FAIL] {name}: {e}\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
