"""Benchmark suite driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-llm]

  format_table     -> Table I / II   (format constants)
  quant_error      -> Fig. 3         (Gaussian MSE sweep, 1 : 1.32 : 1.89)
  dot_product      -> §III.B / Fig.4 (fixed-point flow + multiplier counts)
  llm_accuracy     -> Tables III-V   (tiny-LM proxy incl. the NVFP4 crash)
  serve_throughput -> deployment     (scan-decode tok/s per impl — packed
                                      gated >= 0.9x qdq on the fused
                                      kernel path — prefill latency,
                                      4.5-bit weight + KV-cache residency
                                      -> BENCH_serve.json)
  roofline         -> §Roofline      (aggregates experiments/dryrun/*.json)
  check_docs       -> repo lint      (README/docs must not reference dead
                                      symbols or files)
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-llm", action="store_true",
                    help="skip the (slow) tiny-LM accuracy proxy")
    args = ap.parse_args()

    from benchmarks import dot_product, format_table, quant_error, roofline

    sections = [
        ("format_table (Table I/II)", format_table.main),
        ("quant_error (Fig. 3)", quant_error.main),
        ("dot_product (§III.B / Fig. 4)", dot_product.main),
    ]
    if not args.skip_llm:
        from benchmarks import llm_accuracy, serve_throughput
        sections.append(("llm_accuracy (Tables III-V proxy)", llm_accuracy.main))
        # LLM-class work too: init + prefill + decode of the reduced model
        sections.append(
            ("serve_throughput (deployment)", lambda: serve_throughput.main([]))
        )
    sections.append(("roofline (§Roofline)", roofline.main))

    from tools import check_docs
    sections.append(("check_docs (repo lint)", check_docs.main))

    failures = 0
    for name, fn in sections:
        print("=" * 72)
        print(f"== {name}")
        print("=" * 72)
        t0 = time.time()
        try:
            fn()
            print(f"[ok] {name} ({time.time() - t0:.1f}s)\n")
        except AssertionError as e:
            failures += 1
            print(f"[FAIL] {name}: {e}\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
