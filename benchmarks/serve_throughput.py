"""Serving benchmark: decode throughput, prefill latency, weight residency.

Measures the execution paths end to end on the reduced arch (CPU-honest
numbers — the point is the RELATIVE shape: packed must serve 0.5625 B/value
of weight residency and scan decode must amortize dispatch):

  * prefill latency (s) per impl
  * decode throughput (tokens/s aggregate over the batch) via the scan loop
  * weight bytes resident for the block matmul weights (bf16 vs packed),
    reported as B/value

Emits ``BENCH_serve.json`` next to this file and prints a table.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--impl qdq packed]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.qlinear import PACKABLE_KEYS, QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.serve_loop import (
    ServeConfig,
    packed_weight_bytes,
    prepare_params_for_serving,
    serve,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")


def _dense_block_bytes(params) -> tuple[int, int]:
    """(bytes, values) of the packable block weights in their dense dtype."""
    total = values = 0

    def walk(node, key=None):
        nonlocal total, values
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, k)
        elif key in PACKABLE_KEYS and hasattr(node, "nbytes"):
            total += int(node.nbytes)
            values += int(node.size)

    for blk in ("blocks", "shared", "enc_blocks"):
        if blk in params:
            walk(params[blk])
    return total, values


def bench_impl(cfg, params, ctx, *, batch, prompt_len, new_tokens):
    impl = ctx.quant.impl
    serving_params = prepare_params_for_serving(params, cfg, ctx.quant)

    nbytes_packed, nvals_packed = packed_weight_bytes(serving_params)
    dense_bytes, dense_vals = _dense_block_bytes(params)
    weight_bytes = nbytes_packed if nvals_packed else dense_bytes
    weight_vals = nvals_packed if nvals_packed else dense_vals

    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)}
    sc = ServeConfig(max_new_tokens=new_tokens)

    # warmup (compile prefill + decode scan), then measure
    toks = serve(cfg, serving_params, prompts, ctx, sc)
    jax.block_until_ready(toks)

    from repro.runtime.serve_loop import serving_ctx
    sctx = serving_ctx(ctx)
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, sctx))
    out = prefill(serving_params, prompts)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = prefill(serving_params, prompts)
    jax.block_until_ready(out)
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    toks = serve(cfg, serving_params, prompts, ctx, sc)
    jax.block_until_ready(toks)
    t_serve = time.perf_counter() - t0
    decode_tokens = batch * new_tokens
    tok_per_s = decode_tokens / max(t_serve - t_prefill, 1e-9)

    return {
        "impl": impl,
        "prefill_s": round(t_prefill, 4),
        "serve_s": round(t_serve, 4),
        "decode_tokens": decode_tokens,
        "decode_tok_per_s": round(tok_per_s, 2),
        "weight_bytes": weight_bytes,
        "weight_values": weight_vals,
        "bytes_per_value": round(weight_bytes / max(weight_vals, 1), 4),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    # pallas is interpret-mode off-TPU (orders of magnitude slow on CPU):
    # excluded from the default sweep, opt in with --impl ... pallas
    ap.add_argument("--impl", nargs="+", default=["qdq", "packed"],
                    choices=["qdq", "packed", "pallas"])
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    results = []
    for impl in args.impl:
        ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl), remat=False,
                       attn_q_chunk=32, attn_k_chunk=32)
        r = bench_impl(cfg, params, ctx, batch=args.batch,
                       prompt_len=args.prompt_len, new_tokens=args.new_tokens)
        results.append(r)
        print(f"{impl:8} prefill {r['prefill_s']*1e3:8.1f} ms   "
              f"decode {r['decode_tok_per_s']:9.1f} tok/s   "
              f"weights {r['weight_bytes']/2**20:6.2f} MiB "
              f"({r['bytes_per_value']:.4f} B/value)")

    record = {
        "arch": args.arch + "-smoke",
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "new_tokens": args.new_tokens,
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {OUT_PATH}")

    packed = [r for r in results if r["impl"] in ("packed", "pallas")]
    for r in packed:
        assert abs(r["bytes_per_value"] - 0.5625) < 1e-3, (
            f"{r['impl']}: packed residency {r['bytes_per_value']} B/value "
            f"!= 4.5 bits/value")


if __name__ == "__main__":
    main()
