"""Serving benchmark: decode throughput, prefill latency, weight + KV residency.

Measures the execution paths end to end on the reduced arch (CPU-honest
numbers — the point is the RELATIVE shape: packed must serve 0.5625 B/value
of weight residency, the hif4 KV cache must serve >= 3x fewer cache
bytes/token, and scan decode must amortize dispatch):

  * prefill latency (s) per impl x kv_format
  * decode throughput (tokens/s aggregate over the batch) via the scan loop,
    plus a per-impl decode comparison on identical geometry recorded as
    ``packed_over_qdq_decode`` (the fused dequantize-in-kernel matmul)
  * per-kv_format decode-step latency, measured interleaved on the jitted
    decode scan and recorded as ``hif4_over_bf16_kv_decode`` (the fused
    decode-attention claim: streaming packed KV tiles must not cost the
    bandwidth win the format buys). Both >= 0.9x thresholds are ENFORCED
    by the scenario matrix (benchmarks/matrix.py), not here
  * weight bytes resident for the block matmul weights (bf16 vs packed),
    reported as B/value
  * KV-cache bytes/token (measured from the real decode cache pytree) and
    the max-slot count a nominal HBM budget buys at full-arch scale —
    the serving-capacity term the packed cache exists to grow
  * mixed-policy rows (repro.core.policy presets uniform:hif4 / paper-iv /
    sensitive-fallback served through their resolved per-site plans):
    decode-step latency + weight residency per policy, recorded as
    ``policy_rows`` and required by benchmarks/run.py
  * paged continuous batching (``paged_serve``): the page-pool scheduler
    vs the whole-slot scheduler on a mixed-length shared-prefix trace at
    the SAME KV byte budget — must admit >= 2x the concurrent sequences,
    bitwise-identically to solo serving; required by benchmarks/run.py

Emits ``BENCH_serve.json`` next to this file and prints a table.

    PYTHONPATH=src python -m benchmarks.serve_throughput \
        [--impl qdq packed] [--kv-format bf16 hif4]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.policy import PACKABLE_WEIGHT_KEYS, get_policy
from repro.core.qlinear import QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.serve_loop import (
    ServeConfig,
    kv_cache_bytes,
    kv_format_fallback,
    packed_weight_bytes,
    prepare_params_for_serving,
    resolve_kv_format,
    serve,
    serve_requests,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")

# Nominal per-device HBM budget for the max-slot projection (the absolute
# number is illustrative; the hif4/bf16 RATIO is the measured claim).
HBM_BUDGET_GIB = 16
FULL_ARCH_CAPACITY = 4096              # tokens per slot at full-arch scale


def _dense_block_bytes(params) -> tuple[int, int]:
    """(bytes, values) of the packable block weights in their dense dtype."""
    total = values = 0

    def walk(node, key=None):
        nonlocal total, values
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, k)
        elif key in PACKABLE_WEIGHT_KEYS and hasattr(node, "nbytes"):
            total += int(node.nbytes)
            values += int(node.size)

    for blk in ("blocks", "shared", "enc_blocks"):
        if blk in params:
            walk(params[blk])
    return total, values


def kv_residency(cfg, full_cfg, *, batch, capacity, kv_format, bytes_per_value):
    """Measured cache bytes/token (reduced arch) + full-arch slot projection.

    The slot budget subtracts FULL-ARCH weight residency (embed/head stay
    bf16, block weights at the measured B/value) so packed weights also
    show up as extra slots — the reduced-arch weight bytes are noise
    against an HBM budget.
    """
    cache = lm.init_cache(cfg, batch, capacity, kv_format=kv_format)
    total, slots = kv_cache_bytes(cache)
    a = full_cfg.attn
    if a is None:                                  # attention-free family
        return {
            "kv_format": kv_format,
            "kv_cache_bytes": total,
            "kv_cache_bytes_per_token": 0.0,
            "kv_full_arch_bytes_per_token": 0,
            "kv_max_slots_full_arch": 0,
        }
    full_per_tok = kvcache.kv_bytes_per_token(
        a.n_kv_heads, a.d_head, kv_format) * full_cfg.n_layers
    embed_vals = full_cfg.vocab * full_cfg.d_model * (
        1 if full_cfg.tie_embeddings else 2)
    block_vals = max(full_cfg.n_params() - embed_vals, 0)
    full_weight_bytes = int(embed_vals * 2 + block_vals * bytes_per_value)
    budget = HBM_BUDGET_GIB * 2 ** 30 - full_weight_bytes
    max_slots = max(0, int(budget // (full_per_tok * FULL_ARCH_CAPACITY)))
    return {
        "kv_format": kv_format,
        "kv_cache_bytes": total,
        "kv_cache_bytes_per_token": round(total / max(slots, 1), 2),
        "kv_full_arch_bytes_per_token": full_per_tok,
        "kv_full_arch_weight_bytes": full_weight_bytes,
        "kv_max_slots_full_arch": max_slots,
    }


def kv_decode_step_comparison(cfg, serving_params, ctx, *, batch, prompt_len,
                              new_tokens, repeats=7):
    """Steady-state decode-step latency (ms/step) per kv_format, measured
    INTERLEAVED on the real serving stack.

    Times the jitted decode scan directly, feeding each call's returned
    state into the next (the scan donates its cache, so this is exactly
    the serving steady state) — no ``t_serve - t_prefill`` subtraction,
    whose two noisy wall-clock samples were measured to swing the
    hif4/bf16 ratio by >4x on CPU. The bf16 and hif4 samples alternate
    within one loop so sustained machine-load phases hit both formats
    equally (sequential phases were measured to swing even the best-of-5
    minimum by 2.5x). This is the number the hif4-KV gate is on.
    """
    from repro.runtime import serve_loop

    sctx = serve_loop.serving_ctx(ctx)
    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)}
    prefill = serve_loop._jit_prefill(cfg, sctx)
    step = serve_loop._jit_decode_scan(cfg, sctx, new_tokens, None)
    states = {}
    for kvf in ("bf16", "hif4"):
        logits, cache = prefill(serving_params, prompts)
        if kvf == "hif4":
            cache = serve_loop._jit_quantize_kv(cfg)(cache)
        cache = lm.pad_cache(cache, cfg, prompt_len + new_tokens)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = jnp.zeros(token.shape, bool)
        toks, token, cache, done = step(serving_params, token, cache, done)
        jax.block_until_ready(toks)                    # compile + warmup
        states[kvf] = (token, cache, done)

    best = {kvf: float("inf") for kvf in states}
    for _ in range(repeats):
        for kvf in ("bf16", "hif4"):
            token, cache, done = states[kvf]
            t0 = time.perf_counter()
            toks, token, cache, done = step(serving_params, token, cache, done)
            jax.block_until_ready(toks)
            best[kvf] = min(best[kvf], (time.perf_counter() - t0) / new_tokens)
            states[kvf] = (token, cache, done)
    return {kvf: round(t * 1e3, 4) for kvf, t in best.items()}


POLICY_ROW_NAMES = ("uniform:hif4", "paper-iv", "sensitive-fallback")


def policy_comparison(cfg, params, *, batch, prompt_len, new_tokens,
                      repeats=7):
    """Mixed-policy serving rows: decode-step latency + weight residency
    per policy preset (uniform:hif4 vs the paper's §IV placement vs the
    mixed hif4/bf16 sensitive-site fallback), each served through its own
    resolved plan on the packed path. Latencies are measured INTERLEAVED
    on the jitted decode scan, same methodology (and for the same noise
    reasons) as ``kv_decode_step_comparison``.

    NOTE the uniform:hif4 and paper-iv rows resolve to the SAME per-site
    configs by design (the legacy global config already implemented the
    paper's §IV placement) — asserted below, so their latency ratio is a
    same-program identity check (expected ~1.0x), not a mixed-policy
    result; sensitive-fallback is the genuinely mixed row.
    """
    from repro.runtime import serve_loop

    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)}
    uniform_plan = lm.quant_plan(cfg, get_policy("uniform:hif4",
                                                 impl="packed"))
    paper_plan = lm.quant_plan(cfg, get_policy("paper-iv", impl="packed"))
    assert ([(s.path, s.cfg, s.packed) for s in uniform_plan.sites]
            == [(s.path, s.cfg, s.packed) for s in paper_plan.sites]), (
        "uniform:hif4 and paper-iv must resolve identically — the shim IS "
        "the paper's placement; a drift here means a preset changed")

    # whether the mixed preset actually un-packs sites on THIS arch: its
    # fallback patterns target attn/mlp output projections, which mamba2
    # has none of (and hybrid packs nothing at all) — the structural
    # expectation the main() assertions check against
    sens_plan = lm.quant_plan(cfg, get_policy("sensitive-fallback",
                                              impl="packed"))
    mixed_differs = sens_plan.packed_paths != uniform_plan.packed_paths

    rows, states, steps, serving = {}, {}, {}, {}
    for name in POLICY_ROW_NAMES:
        plan = lm.quant_plan(cfg, get_policy(name, impl="packed"))
        ctx = ModelCtx(quant=plan.base, plan=plan, remat=False,
                       attn_q_chunk=32, attn_k_chunk=32)
        sp = prepare_params_for_serving(params, cfg, plan)
        packed_b, packed_v = packed_weight_bytes(sp)
        dense_b, dense_v = _dense_block_bytes(sp)   # PackedW leaves skipped
        sctx = serve_loop.serving_ctx(ctx)
        prefill = serve_loop._jit_prefill(cfg, sctx)
        step = serve_loop._jit_decode_scan(cfg, sctx, new_tokens, None)
        logits, cache = prefill(sp, prompts)
        cache = lm.pad_cache(cache, cfg, prompt_len + new_tokens)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = jnp.zeros(token.shape, bool)
        toks, token, cache, done = step(sp, token, cache, done)
        jax.block_until_ready(toks)                 # compile + warmup
        serving[name], steps[name] = sp, step
        states[name] = (token, cache, done)
        total_b, total_v = packed_b + dense_b, packed_v + dense_v
        rows[name] = {
            "packed_sites": len(plan.packed_paths),
            "n_sites": len(plan.sites),
            "weight_bytes": total_b,
            "packed_weight_bytes": packed_b,
            "bytes_per_value": round(total_b / max(total_v, 1), 4),
        }

    best = {name: float("inf") for name in rows}
    for _ in range(repeats):
        for name in rows:
            token, cache, done = states[name]
            t0 = time.perf_counter()
            toks, token, cache, done = steps[name](
                serving[name], token, cache, done)
            jax.block_until_ready(toks)
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / new_tokens)
            states[name] = (token, cache, done)
    for name in rows:
        rows[name]["decode_step_ms"] = round(best[name] * 1e3, 4)
    return rows, mixed_differs


PAGED_TRACE = {
    "page_tokens": 16,
    "budget": 8,
    "prefix_len": 24,
    "tail_lens": (8, 12, 16, 8, 12, 16, 80),   # mixed lengths, long one last
    "slot_slots": 2,                            # whole-slot byte baseline
    "decode_chunk": 2,
}


def paged_serve_comparison(cfg, params, ctx):
    """Paged-vs-slot scheduler on a mixed-length shared-prefix trace, at the
    SAME KV byte budget (the claim the page pool exists for).

    The whole-slot scheduler must reserve max-capacity slots, so a 2-slot
    budget serves 2 sequences at a time no matter how short most prompts
    are. The paged scheduler gets exactly those bytes as a page pool and
    admits by actual page demand, sharing the common 24-token prefix pages
    COW; concurrency is counted post-provisioning (sequences really
    decoding together). Per-request outputs are checked BITWISE against
    solo serving (same page-size KV tiling) — paging must buy admission,
    never bits.
    """
    import dataclasses

    t = PAGED_TRACE
    P, budget = t["page_tokens"], t["budget"]
    # mixed prompt lengths (32/36/40/104) need a flash chunk dividing them
    # all; the same ctx serves slot, paged, AND solo — parity stays bitwise
    ctx = dataclasses.replace(ctx, attn_q_chunk=4, attn_k_chunk=4)
    prefix = jax.random.randint(jax.random.PRNGKey(7), (t["prefix_len"],),
                                0, cfg.vocab)
    reqs = [jnp.concatenate([prefix, jax.random.randint(
        jax.random.PRNGKey(40 + i), (n,), 0, cfg.vocab)])
        for i, n in enumerate(t["tail_lens"])]
    cap = max(int(r.shape[0]) for r in reqs) + budget
    a = cfg.attn
    per_tok = kvcache.kv_bytes_per_token(
        a.n_kv_heads, a.d_head, "hif4") * cfg.n_layers
    page_bytes = kvcache.page_nbytes(a.n_kv_heads, a.d_head, P, cfg.n_layers)
    slot_bytes = t["slot_slots"] * cap * per_tok
    kv_pages = slot_bytes // page_bytes
    assert kv_pages * page_bytes == slot_bytes, (
        "trace sizing must make the byte budgets exactly equal")

    sc_slot = ServeConfig(max_new_tokens=budget,
                          decode_chunk=t["decode_chunk"], kv_format="hif4",
                          cache_capacity=cap)
    slot_stats: dict = {}
    serve_requests(cfg, params, reqs, ctx, sc_slot,
                   slots=t["slot_slots"], stats=slot_stats)

    sc_paged = dataclasses.replace(sc_slot, kv_pages=int(kv_pages),
                                   kv_page_tokens=P)
    paged_stats: dict = {}
    res_paged = serve_requests(cfg, params, reqs, ctx, sc_paged,
                               slots=len(reqs), stats=paged_stats)

    # bitwise parity vs solo serving under the same KV-tile partition
    # (tiles = pages; capacity is already a page multiple here)
    assert cap % P == 0
    solo_ctx = dataclasses.replace(ctx, attn_kv_block=P)
    sc_solo = ServeConfig(max_new_tokens=budget, kv_format="hif4",
                          cache_capacity=cap)
    bitwise = True
    for i, r in enumerate(reqs):
        solo = serve(cfg, params, {"tokens": r[None]}, solo_ctx, sc_solo)
        bitwise = bitwise and bool(jnp.array_equal(res_paged[i], solo[0]))

    return {
        "page_tokens": P,
        "kv_pages": int(kv_pages),
        "pool_bytes": int(slot_bytes),
        "prompt_lens": [int(r.shape[0]) for r in reqs],
        "shared_prefix_len": t["prefix_len"],
        "new_tokens": budget,
        "max_concurrent_slot": slot_stats["max_concurrent"],
        "max_concurrent_paged": paged_stats["max_concurrent"],
        "admission_ratio": round(paged_stats["max_concurrent"]
                                 / max(slot_stats["max_concurrent"], 1), 3),
        "shared_page_hits": paged_stats["shared_page_hits"],
        "preemptions": paged_stats["preemptions"],
        "lru_evictions": paged_stats["evictions"],
        "peak_live_pages": paged_stats["peak_live_pages"],
        "bitwise_vs_solo": bitwise,
        "kv_format_fallback": kv_format_fallback(cfg, ctx.quant, sc_paged),
    }


def bench_impl(cfg, params, ctx, *, batch, prompt_len, new_tokens,
               kv_format="bf16", full_cfg=None):
    impl = ctx.quant.impl
    serving_params = prepare_params_for_serving(params, cfg, ctx.quant)

    nbytes_packed, nvals_packed = packed_weight_bytes(serving_params)
    dense_bytes, dense_vals = _dense_block_bytes(params)
    weight_bytes = nbytes_packed if nvals_packed else dense_bytes
    weight_vals = nvals_packed if nvals_packed else dense_vals

    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)}
    sc = ServeConfig(max_new_tokens=new_tokens, kv_format=kv_format)

    # warmup (compile prefill + decode scan), then measure
    toks = serve(cfg, serving_params, prompts, ctx, sc)
    jax.block_until_ready(toks)

    from repro.runtime.serve_loop import serving_ctx
    sctx = serving_ctx(ctx)
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, sctx))
    out = prefill(serving_params, prompts)
    jax.block_until_ready(out)

    # best-of-3 on BOTH measurements: single CPU wall-clock samples at this
    # scale are noisy enough to flip the packed-vs-qdq gate, and the decode
    # rate is a t_serve - t_prefill difference, so an asymmetric noisy-high
    # prefill sample would corrupt it just as badly as a noisy serve. The
    # min is the "nothing else interfered" measurement of the compiled
    # program.
    t_prefill = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = prefill(serving_params, prompts)
        jax.block_until_ready(out)
        t_prefill = min(t_prefill, time.perf_counter() - t0)

    t_serve = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        toks = serve(cfg, serving_params, prompts, ctx, sc)
        jax.block_until_ready(toks)
        t_serve = min(t_serve, time.perf_counter() - t0)
    decode_tokens = batch * new_tokens
    tok_per_s = decode_tokens / max(t_serve - t_prefill, 1e-9)

    r = {
        "impl": impl,
        "prefill_s": round(t_prefill, 4),
        "serve_s": round(t_serve, 4),
        "decode_tokens": decode_tokens,
        "decode_tok_per_s": round(tok_per_s, 2),
        "weight_bytes": weight_bytes,
        "weight_values": weight_vals,
        "bytes_per_value": round(weight_bytes / max(weight_vals, 1), 4),
    }
    r.update(kv_residency(cfg, full_cfg or cfg, batch=batch,
                          capacity=prompt_len + new_tokens,
                          kv_format=kv_format,
                          bytes_per_value=r["bytes_per_value"]))
    return r


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    # pallas is interpret-mode off-TPU (orders of magnitude slow on CPU):
    # excluded from the default sweep, opt in with --impl ... pallas
    ap.add_argument("--impl", nargs="+", default=["qdq", "packed"],
                    choices=["qdq", "packed", "pallas"])
    # the hif4 KV cache only rides the packed impl in the default sweep
    # (kv_format is impl-orthogonal; one quantized-cache point suffices)
    ap.add_argument("--kv-format", nargs="+", default=["bf16", "hif4"],
                    choices=list(kvcache.KV_FORMATS))
    args = ap.parse_args(argv)

    full_cfg = get_arch(args.arch)
    cfg = full_cfg.reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    results = []
    kv_fallbacks = []
    for impl in args.impl:
        ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl), remat=False,
                       attn_q_chunk=32, attn_k_chunk=32)
        # hif4 rides the packed impl only, and only where resolve_kv_format
        # (the single source of truth on family support) makes it real —
        # a falling-back combination must not emit a mislabeled row, and
        # every dropped combination is recorded + printed loudly
        kv_formats = []
        for kvf in (args.kv_format if impl == "packed" else ["bf16"]):
            resolved = resolve_kv_format(cfg, ctx.quant,
                                         ServeConfig(kv_format=kvf),
                                         verbose=True)
            if resolved == kvf:
                kv_formats.append(kvf)
            else:
                kv_fallbacks.append({"impl": impl, "requested": kvf,
                                     "resolved": resolved})
        for kvf in kv_formats:
            r = bench_impl(cfg, params, ctx, batch=args.batch,
                           prompt_len=args.prompt_len,
                           new_tokens=args.new_tokens,
                           kv_format=kvf, full_cfg=full_cfg)
            results.append(r)
            print(f"{impl:8} kv={kvf:5} prefill {r['prefill_s']*1e3:8.1f} ms   "
                  f"decode {r['decode_tok_per_s']:9.1f} tok/s   "
                  f"weights {r['weight_bytes']/2**20:6.2f} MiB "
                  f"({r['bytes_per_value']:.4f} B/value)   "
                  f"kv {r['kv_cache_bytes_per_token']:7.1f} B/tok "
                  f"({r['kv_max_slots_full_arch']} slots @ "
                  f"{HBM_BUDGET_GIB} GiB full-arch)")

    # Per-impl decode comparison on identical geometry (bf16-KV rows only,
    # so the cache format doesn't confound the weight-path comparison).
    # This is the first point on the bench trajectory the fused kernel is
    # gated on: packed decode must stay >= 0.9x qdq decode.
    decode_by_impl = {r["impl"]: r["decode_tok_per_s"] for r in results
                      if r["kv_format"] == "bf16"}
    packed_over_qdq = None
    if "packed" in decode_by_impl and "qdq" in decode_by_impl:
        packed_over_qdq = round(
            decode_by_impl["packed"] / decode_by_impl["qdq"], 3)
        print(f"decode tok/s by impl: {decode_by_impl}  "
              f"(packed/qdq = {packed_over_qdq}x)")

    # Per-kv_format decode-step latency on identical geometry (packed
    # impl): the fused decode-attention claim. The packed cache must hold
    # decode within 0.9x of the bf16 cache — it was 0.70x when the packed
    # path materialized the whole cache to bf16 HBM every step.
    step_by_kv = {}
    hif4_over_bf16 = None
    hif4_rows = [r for r in results
                 if r["impl"] == "packed" and r["kv_format"] == "hif4"]
    if hif4_rows:
        ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl="packed"),
                       remat=False, attn_q_chunk=32, attn_k_chunk=32)
        serving_params = prepare_params_for_serving(params, cfg, ctx.quant)
        step_by_kv = kv_decode_step_comparison(
            cfg, serving_params, ctx, batch=args.batch,
            prompt_len=args.prompt_len, new_tokens=args.new_tokens)
        hif4_over_bf16 = round(step_by_kv["bf16"] / step_by_kv["hif4"], 3)
        print(f"decode step ms by kv_format: {step_by_kv}  "
              f"(hif4/bf16 decode rate = {hif4_over_bf16}x)")

    # Mixed-policy rows (per-site QuantPolicy presets on the packed path):
    # decode-step latency + residency per preset. Only meaningful when the
    # sweep exercises the packed impl; benchmarks/run.py fails loudly if
    # the rows are absent while packed was swept.
    policy_rows = None
    paper_iv_over_uniform = None
    mixed_differs = False
    if "packed" in args.impl:
        policy_rows, mixed_differs = policy_comparison(
            cfg, params, batch=args.batch, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens)
        paper_iv_over_uniform = round(
            policy_rows["uniform:hif4"]["decode_step_ms"]
            / policy_rows["paper-iv"]["decode_step_ms"], 3)
        for name, r in policy_rows.items():
            print(f"policy {name:20} decode {r['decode_step_ms']:8.3f} ms/step"
                  f"   weights {r['weight_bytes']/2**20:6.2f} MiB "
                  f"({r['bytes_per_value']:.4f} B/value, "
                  f"{r['packed_sites']}/{r['n_sites']} sites packed)")

    # Paged continuous batching on a mixed-length shared-prefix trace:
    # the page pool must buy >= 2x the whole-slot scheduler's concurrency
    # at the same KV byte budget, bitwise-identically to solo serving.
    # Only meaningful with the packed impl + real hif4 KV; benchmarks/run.py
    # fails loudly if the row is absent while both were swept.
    paged_serve = None
    if any(r["impl"] == "packed" and r["kv_format"] == "hif4"
           for r in results):
        ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl="packed"),
                       remat=False, attn_q_chunk=32, attn_k_chunk=32)
        serving_params = prepare_params_for_serving(params, cfg, ctx.quant)
        paged_serve = paged_serve_comparison(cfg, serving_params, ctx)
        print(f"paged serve: {paged_serve['max_concurrent_paged']} vs "
              f"{paged_serve['max_concurrent_slot']} concurrent "
              f"({paged_serve['admission_ratio']}x) at "
              f"{paged_serve['pool_bytes']} KV bytes, "
              f"{paged_serve['shared_page_hits']} shared-page hits, "
              f"{paged_serve['preemptions']} preemptions, "
              f"bitwise_vs_solo={paged_serve['bitwise_vs_solo']}")

    record = {
        "arch": args.arch + "-smoke",
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "new_tokens": args.new_tokens,
        "backend": jax.default_backend(),
        "hbm_budget_gib": HBM_BUDGET_GIB,
        "full_arch_capacity": FULL_ARCH_CAPACITY,
        "decode_tok_per_s_by_impl": decode_by_impl,
        "packed_over_qdq_decode": packed_over_qdq,
        "decode_step_ms_by_kv_format": step_by_kv,
        "hif4_over_bf16_kv_decode": hif4_over_bf16,
        "policy_rows": policy_rows,
        "paper_iv_over_uniform_decode": paper_iv_over_uniform,
        "paged_serve": paged_serve,
        "kv_format_fallbacks": kv_fallbacks,
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {OUT_PATH}")

    # hybrid keeps the QDQ artifact (its doubly-stacked mamba blocks don't
    # fit PackedW's single leading layer axis), so only assert true 4.5-bit
    # residency for families prepare_params_for_serving actually packs
    packed = [r for r in results if r["impl"] in ("packed", "pallas")]
    if cfg.family != "hybrid":
        for r in packed:
            assert abs(r["bytes_per_value"] - 0.5625) < 1e-3, (
                f"{r['impl']}: packed residency {r['bytes_per_value']} "
                f"B/value != 4.5 bits/value")

    # The two >= 0.9x decode-ratio THRESHOLDS now live in the scenario
    # matrix (benchmarks/matrix.py, gates packed_over_qdq_decode and
    # hif4_over_bf16_kv_decode, enforced by run.py::check_matrix_gates
    # with interleaved timing). This module keeps RECORDING both ratios —
    # check_serve_gates fails if either field goes missing or null while
    # the sweep covered both sides.

    # where the mixed preset structurally applies (its fallback patterns
    # match sites on this arch), it must actually be mixed: fewer packed
    # sites and correspondingly more resident bytes than uniform. mamba2
    # has no attn/mlp output projections and hybrid packs nothing, so
    # there the two legitimately coincide.
    if policy_rows is not None and mixed_differs:
        assert (policy_rows["sensitive-fallback"]["packed_sites"]
                < policy_rows["uniform:hif4"]["packed_sites"]), policy_rows
        assert (policy_rows["sensitive-fallback"]["weight_bytes"]
                > policy_rows["uniform:hif4"]["weight_bytes"]), policy_rows
    elif policy_rows is not None:
        assert (policy_rows["sensitive-fallback"]["packed_sites"]
                == policy_rows["uniform:hif4"]["packed_sites"]), policy_rows

    # capacity + exactness gate on the paged scheduler: same KV bytes must
    # admit at least 2x the sequences, and paging must never change bits
    if paged_serve is not None:
        assert paged_serve["bitwise_vs_solo"], (
            "paged continuous batching diverged from solo serving — paging "
            "must buy admission, never bits")
        assert paged_serve["admission_ratio"] >= 2.0, (
            f"paged scheduler admitted only "
            f"{paged_serve['admission_ratio']}x the slot scheduler's "
            f"sequences at the same byte budget (gate: >= 2x)")

    by_kv = {r["kv_format"]: r for r in results}
    if ("hif4" in by_kv and "bf16" in by_kv
            and by_kv["hif4"]["kv_cache_bytes_per_token"] > 0):
        ratio = (by_kv["bf16"]["kv_cache_bytes_per_token"]
                 / by_kv["hif4"]["kv_cache_bytes_per_token"])
        print(f"kv cache reduction (bf16/hif4): {ratio:.2f}x")
        assert ratio >= 3.0, (
            f"hif4 KV cache must cut bytes/token >= 3x, got {ratio:.2f}x")


if __name__ == "__main__":
    main()
