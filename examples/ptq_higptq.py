"""PTQ walkthrough: direct-cast vs HiGPTQ on a trained layer (paper §IV-A).

    PYTHONPATH=src python examples/ptq_higptq.py
"""
import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core.higptq import higptq_quantize, layer_output_error


def main():
    key = jax.random.PRNGKey(0)
    K, N, S = 512, 128, 1024

    # a "trained" weight with structure + calibration activations
    kw, kx, km = jax.random.split(key, 3)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.04
    base = jax.random.normal(kx, (S, K // 8), jnp.float32)
    x = base @ (jax.random.normal(km, (K // 8, K)) * 0.4)

    def direct_cast(w):
        g = hif4.quantize_groups(w.T.reshape(N, K // 64, 64))
        return hif4.dequantize_groups(g).reshape(N, K).T

    wq_d = direct_cast(w)
    wq_g = higptq_quantize(w, x)

    e_d = layer_output_error(w, wq_d, x)
    e_g = layer_output_error(w, wq_g, x)
    print("layer output error ||X(W - Wq)|| / ||XW||")
    print(f"  HiF4 direct-cast : {e_d:.4f}")
    print(f"  HiF4 + HiGPTQ    : {e_g:.4f}  ({100 * (1 - e_g / e_d):.1f}% lower)")


if __name__ == "__main__":
    main()
