"""Quickstart: the HiF4 format in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. quantize a tensor with Algorithm 1 and inspect the unit structure
2. compare quantization error against NVFP4 / MXFP4 (paper Fig. 3)
3. run the fixed-point dot product (paper §III.B) — bit-exact vs dequant
4. run the Pallas kernels (interpret mode on CPU)
"""
import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core.formats import available_formats, get_format
from repro.core.metrics import mse
from repro.core.qlinear import hif4_dot_fixed_point
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 64), jnp.float32) * 0.02

    # -- 1. one HiF4 unit ----------------------------------------------------
    g = hif4.quantize_groups(x)
    print("== HiF4 unit structure (first group) ==")
    print(f"  E6M2 level-1 scale : {float(g.e6m2[0]):.3e}")
    print(f"  E1_8 micro-exps    : {g.e1_8[0].tolist()}")
    print(f"  E1_16 micro-exps   : {g.e1_16[0].tolist()}")
    print(f"  S1P2 elements [:8] : {g.s1p2[0, :8].tolist()}")
    print(f"  storage            : {hif4.BITS_PER_VALUE} bits/value\n")

    # -- 2. format comparison --------------------------------------------------
    big = jax.random.normal(jax.random.fold_in(key, 1), (1024, 1024)) * 0.01
    print("== quantization MSE on N(0, 0.01^2) (paper Fig. 3 point x=0) ==")
    errs = {}
    for name in available_formats():
        fmt = get_format(name)
        errs[name] = float(mse(big, fmt.qdq(big)))
    for name, e in sorted(errs.items(), key=lambda kv: kv[1]):
        print(f"  {name:10} mse={e:.3e}  (x{e / errs['hif4']:.2f} vs hif4)")
    print()

    # -- 3. fixed-point dot product ---------------------------------------------
    a = jax.random.normal(jax.random.fold_in(key, 2), (64,)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (64,)) * 0.1
    fp = float(hif4_dot_fixed_point(a, b))
    ga, gb = hif4.quantize_groups(a[None]), hif4.quantize_groups(b[None])
    deq = float(jnp.sum(hif4.dequantize_groups(ga) * hif4.dequantize_groups(gb)))
    print("== 64-length dot: integer flow vs dequantized floats ==")
    print(f"  fixed-point: {fp:.6f}   dequant: {deq:.6f}   equal: {fp == deq}\n")

    # -- 4. Pallas kernels -------------------------------------------------------
    m = jax.random.normal(jax.random.fold_in(key, 4), (32, 256)) * 0.1
    w = jax.random.normal(jax.random.fold_in(key, 5), (256, 32)) * 0.05
    y = ops.matmul(m, w, block_m=32, block_n=32, block_k=128)
    ref = m @ w
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    print("== Pallas HiF4 matmul kernel (interpret mode) ==")
    print(f"  output {y.shape}, relative error vs f32 matmul: {rel:.3%}")


if __name__ == "__main__":
    main()
