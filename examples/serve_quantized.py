"""End-to-end serving driver (the paper's kind: inference).

Trains a small LM briefly so it has real structure, then serves batched
requests under every quantization mode and reports greedy-token agreement
with the BF16 reference — the deployment-shaped version of Tables III-V.

    PYTHONPATH=src python examples/serve_quantized.py [--steps 120]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.qlinear import QuantConfig
from repro.models.common import ModelCtx
from repro.runtime import ServeConfig, TrainLoopConfig, serve, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    base_ctx = ModelCtx(remat=False, attn_q_chunk=32, attn_k_chunk=32)

    print(f"training reduced {args.arch} for {args.steps} steps ...")
    params, _, hist = train(cfg, base_ctx, TrainLoopConfig(
        steps=args.steps, global_batch=8, seq_len=64))
    print(f"  loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                            (4, 24), 0, cfg.vocab)}
    sc = ServeConfig(max_new_tokens=args.new_tokens)

    ref = serve(cfg, params, prompts, base_ctx, sc)
    print(f"\nbatched serving: {prompts['tokens'].shape[0]} requests, "
          f"{args.new_tokens} new tokens each")
    print(f"{'mode':22} {'agreement with bf16':>20}")
    print(f"{'bf16':22} {'100.0%':>20}")
    for fmt in ("hif4", "nvfp4", "nvfp4_pts", "mxfp4"):
        ctx = ModelCtx(quant=QuantConfig(fmt=fmt), remat=False,
                       attn_q_chunk=32, attn_k_chunk=32)
        toks = serve(cfg, params, prompts, ctx, sc)
        agree = float(jnp.mean(toks == ref)) * 100
        print(f"{fmt:22} {agree:19.1f}%")

    # hif4 again, but served from REAL 4.5-bit packed buffers (impl='packed'
    # — the deployment artifact; see docs/EXECUTION.md for the dispatch
    # matrix). Same quantized values, 0.5625 B/value of weight residency.
    from repro.core import kvcache
    from repro.runtime.serve_loop import (
        packed_weight_bytes, prepare_params_for_serving)
    qp = QuantConfig(fmt="hif4", impl="packed")
    ctx = ModelCtx(quant=qp, remat=False, attn_q_chunk=32, attn_k_chunk=32)
    serving_params = prepare_params_for_serving(params, cfg, qp)
    nbytes, nvals = packed_weight_bytes(serving_params)
    toks = serve(cfg, serving_params, prompts, ctx, sc)
    agree = float(jnp.mean(toks == ref)) * 100
    print(f"{'hif4 (impl=packed)':22} {agree:19.1f}%"
          f"   [{nbytes / nvals:.4f} B/value resident]")

    # ... and with the KV cache ALSO packed at 4.5 bits/value
    # (kv_format='hif4', repro.core.kvcache): the cache is the term that
    # grows with slots x capacity, so this is what buys serving scale.
    toks = serve(cfg, serving_params, prompts, ctx,
                 ServeConfig(max_new_tokens=args.new_tokens,
                             kv_format="hif4"))
    agree = float(jnp.mean(toks == ref)) * 100
    a = cfg.attn
    kv_tok = kvcache.kv_bytes_per_token(a.n_kv_heads, a.d_head,
                                        "hif4") * cfg.n_layers
    kv_bf16 = kvcache.kv_bytes_per_token(a.n_kv_heads, a.d_head,
                                         "bf16") * cfg.n_layers
    print(f"{'hif4 + hif4 kv cache':22} {agree:19.1f}%"
          f"   [kv {kv_tok} B/token vs bf16 {kv_bf16} "
          f"-> {kv_bf16 / kv_tok:.2f}x slots]")

    # MIXED per-site policy (repro.core.policy): the sensitive-fallback
    # preset keeps the outlier-sensitive output/down projections bf16
    # dense while the rest of the body serves packed — placement is a
    # rule list resolved into a site plan, not a code edit.
    from repro.core.policy import get_policy
    from repro.models import lm
    plan = lm.quant_plan(cfg, get_policy("sensitive-fallback", impl="packed"))
    pctx = ModelCtx(quant=plan.base, plan=plan, remat=False,
                    attn_q_chunk=32, attn_k_chunk=32)
    mixed_params = prepare_params_for_serving(params, cfg, plan)
    nbytes_m, nvals_m = packed_weight_bytes(mixed_params)
    toks = serve(cfg, mixed_params, prompts, pctx, sc)
    agree = float(jnp.mean(toks == ref)) * 100
    print(f"{'policy: sens-fallback':22} {agree:19.1f}%"
          f"   [{len(plan.packed_paths)}/{len(plan.sites)} sites packed, "
          f"{nbytes_m / max(nvals_m, 1):.4f} B/value on packed sites]")


if __name__ == "__main__":
    main()
