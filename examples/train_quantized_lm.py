"""End-to-end QAT-style training driver with HiF4 A-W fake quant + STE,
fault-tolerant checkpointing, and HiF4-compressed DP gradient all-reduce
when more than one device is available.

    PYTHONPATH=src python examples/train_quantized_lm.py [--steps 200]

(The paper's conclusion flags HiF4 training as future work; this driver
demonstrates the framework side: the 69-binade range casts gradients
directly, no per-tensor scale sweep.)
"""
import argparse
import os
import tempfile

from repro.configs import get_arch
from repro.core.qlinear import QuantConfig
from repro.models.common import ModelCtx
from repro.runtime import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--quant", default="hif4")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "hif4_train_ckpt")

    for fmt in ("none", args.quant):
        ctx = ModelCtx(quant=QuantConfig(fmt=fmt), remat=False,
                       attn_q_chunk=32, attn_k_chunk=32)
        _, _, hist = train(cfg, ctx, TrainLoopConfig(
            steps=args.steps, global_batch=8, seq_len=64,
            checkpoint_dir=ckpt + "_" + fmt, checkpoint_every=50))
        print(f"{fmt:6}: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
              f"(mean step {1e3 * sum(hist['step_time']) / len(hist['step_time']):.0f}ms, "
              f"stragglers: {len(hist['stragglers'])})")


if __name__ == "__main__":
    main()
