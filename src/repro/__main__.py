"""Single front door for the launchers: ``python -m repro <cmd> ...``.

Each subcommand forwards argv to the matching ``repro.launch.*`` module,
so ``python -m repro calibrate --arch ...`` and
``python -m repro.launch.calibrate --arch ...`` are the same program.
"""
import sys

COMMANDS = {
    "calibrate": ("repro.launch.calibrate", "search a QuantPolicy from "
                  "calibration activations"),
    "serve": ("repro.launch.serve", "offline packing + batched decode"),
    "train": ("repro.launch.train", "train-loop entry"),
    "dryrun": ("repro.launch.dryrun", "compile-only cost readout"),
    "breakdown": ("repro.launch.breakdown", "per-instruction cost tables"),
}


def main():
    argv = sys.argv[1:]
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro <command> [args]\n\ncommands:")
        for name, (_, desc) in COMMANDS.items():
            print(f"  {name:10} {desc}")
        raise SystemExit(0 if argv else 2)
    cmd = argv[0]
    if cmd not in COMMANDS:
        print(f"unknown command {cmd!r} (expected one of "
              f"{', '.join(COMMANDS)})", file=sys.stderr)
        raise SystemExit(2)
    mod_name = COMMANDS[cmd][0]
    import importlib

    mod = importlib.import_module(mod_name)
    sys.argv = [f"python -m {mod_name}"] + argv[1:]
    mod.main()


if __name__ == "__main__":
    main()
