"""Calibration subsystem: sensitivity-driven automatic QuantPolicy search.

Turns a small calibration activation set into a searched
:class:`repro.core.policy.QuantPolicy` on the accuracy-vs-bytes frontier,
in three layers (see docs/EXECUTION.md §Calibration):

probe   (:mod:`repro.calibrate.probe`)  — one bf16 forward over the
        calibration batches with the per-site activation tap installed
        (``repro.core.tap``), then per-site scores: quantization error
        per format (hif4 / nvfp4 / mxfp4 / bf16-fallback, HiF4 rounded
        offline with HiGPTQ), byte residency per format, and the site's
        roofline latency contribution.
search  (:mod:`repro.calibrate.search`) — greedy marginal-utility sweep
        over error-per-byte-saved: given a target bytes-per-value budget,
        assign each site the cheapest format whose marginal error fits;
        the full Pareto curve is part of the result.
emit    (:mod:`repro.calibrate.emit`)   — a valid QuantPolicy JSON
        (provenance-stamped, loads via ``repro.core.policy.get_policy``
        and rides inside serving artifacts with zero extra wiring) plus a
        ``calibration_report.json`` recording every per-site score.

CLI: ``python -m repro calibrate --arch <a> --target-bpv 0.7 --out
policy.json`` (``repro.launch.calibrate``).
"""
from repro.calibrate.emit import emit_policy, emit_report
from repro.calibrate.probe import CalibrationResult, probe_sites
from repro.calibrate.search import (
    FormatOption,
    FrontierResult,
    SiteScore,
    frontier_search,
)
from repro.calibrate.run import calibrate

__all__ = [
    "CalibrationResult",
    "FormatOption",
    "FrontierResult",
    "SiteScore",
    "calibrate",
    "emit_policy",
    "emit_report",
    "frontier_search",
    "probe_sites",
]
