"""Emit a searched assignment as QuantPolicy JSON + the calibration report.

The emitted policy is a plain, hand-editable policy file:

* a catch-all ``{"pattern": "*", "fmt": "none"}`` base rule, then one
  exact-path rule per site the search quantizes — later-rules-win
  inheritance, same as the hand-written presets;
* ``impl`` is deliberately left off every rule so the serving-side
  ``--impl`` flag keeps working (``get_policy`` prepends it as a base
  rule for file policies);
* ``provenance`` stamps how the placement was chosen (arch, calibration
  set, target and achieved bytes/value) so the policy file — and any
  serving artifact it rides in — is auditable.

The report (``calibration_report.json``) is the full audit trail: every
per-site per-format score the probe measured, the complete
accuracy-vs-bytes Pareto curve the search walked, and the baseline
preset comparisons scored on the same table.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.core.policy import KVCacheConfig, QuantPolicy, QuantRule

REPORT_VERSION = 1


def emit_policy(assignment: dict, *, name: str = "searched",
                kv_format: str = "bf16", provenance: Optional[dict] = None,
                out: Optional[str] = None) -> QuantPolicy:
    """Build (and optionally write) the QuantPolicy for an assignment.

    ``assignment`` maps site path -> format name; ``bf16``/``none`` sites
    fall through to the catch-all rule and get no rule of their own.
    """
    rules = [QuantRule("*", fmt="none")]
    for path in sorted(assignment):
        fmt = assignment[path]
        if fmt not in ("bf16", "none"):
            rules.append(QuantRule(path, fmt=fmt))
    pol = QuantPolicy(rules=tuple(rules), kv=KVCacheConfig(kv_format),
                      name=name)
    if provenance is not None:
        pol = pol.with_provenance(provenance)
    if out is not None:
        with open(out, "w") as f:
            json.dump(pol.to_json_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
    return pol


def emit_report(result, frontier, *, target_bpv: float,
                baselines: Optional[dict] = None,
                out: Optional[str] = None) -> dict:
    """Assemble (and optionally write) ``calibration_report.json``.

    ``result`` is the probe's CalibrationResult, ``frontier`` the search's
    FrontierResult; ``baselines`` maps a preset name to its
    ``{assignment, total_bytes, total_error, achieved_bpv}`` scored on the
    same table (``repro.calibrate.search.assignment_cost``).
    """
    report = {
        "version": REPORT_VERSION,
        "arch": result.arch,
        "family": result.family,
        "calibration": {
            "n_batches": result.n_batches,
            "batch": result.batch,
            "seq_len": result.seq_len,
            "seed": result.seed,
            "n_calib_rows": result.n_calib_rows,
        },
        "mem_bw_gbps": (None if result.mem_bw is None
                        else round(result.mem_bw / 1e9, 3)),
        "target_bpv": target_bpv,
        "search": {
            "assignment": dict(sorted(frontier.assignment.items())),
            "total_bytes": round(frontier.total_bytes),
            "total_error": frontier.total_error,
            "achieved_bpv": round(frontier.achieved_bpv, 6),
            "feasible": frontier.feasible,
        },
        "pareto_curve": list(frontier.curve),
        "sites": [dict(r) for r in result.rows],
        "baselines": baselines or {},
    }
    if out is not None:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return report
