"""Sensitivity probe: per-site quantization-error scores from one bf16 pass.

Runs the calibration batches through the model ONCE in bf16 with the
per-site activation tap installed (``repro.core.tap`` — the capture hooks
ride ``ModelCtx.site_quant`` and the engine funnel, so every family's
dense/qbmm sites record without model changes), then scores every site
the resolved :class:`~repro.core.policy.QuantPlan` enumerates:

* **error per format** (``repro.core.metrics.QDQ_FORMATS`` + bf16):
  relative layer-output error ``||X(W - Wq)||_F / ||X W||_F``
  (``repro.core.metrics.rel_output_error``) against the site's captured
  activations, per layer, averaged over the stack. HiF4 is additionally
  scored with HiGPTQ offline rounding (``repro.core.higptq``) wherever
  the site structurally admits an offline artifact — that rounded score
  is what serving would actually ship, so it is the one the frontier
  search prices;
* **byte residency per format**: 0.5625 B/value for HiF4 on a packable
  site (the PackedW payload), 2 B/value (bf16 at rest) everywhere else —
  matching exactly what ``prepare_params_for_serving`` + the plan's
  ``packed_paths`` would make resident;
* **roofline latency contribution**: site bytes / measured stream
  bandwidth (``benchmarks/roofline.py``), when a bandwidth is supplied.

The probe is model-agnostic: site enumeration, packability and
contraction axes all come from plan resolution, and activations come
from the tap, so any family ``lm._backbone`` serves (dense / moe / ssm /
hybrid / vlm / audio) probes through the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core import tap as site_tap
from repro.core.higptq import higptq_quantize
from repro.core.metrics import QDQ_FORMATS, rel_output_error
from repro.core.policy import QuantPlan, get_policy
from repro.models import lm
from repro.models.common import ModelCtx

# byte costs at rest: the PackedW payload (4.5-bit codes + scale metadata,
# see repro.core.qlinear.PackedW) vs bf16
PACKED_BPV = 0.5625
DENSE_BPV = 2.0

# sites the byte budget governs are the matmul weight sites that own a
# resident tensor: "embed" is a gather table the policy clamps to
# fmt='none', and a tied "lm_head" owns no tensor of its own (it reads
# embed.T) — neither can trade bytes, so neither enters the budget
# (see _in_budget).


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Everything the search + emitter need, plus the audit rows."""

    arch: str
    family: str
    plan: QuantPlan              # uniform:hif4/packed reference resolution
    rows: tuple                  # per-site audit dicts (report schema)
    n_batches: int
    seq_len: int
    batch: int
    seed: int
    n_calib_rows: int            # activation rows captured per site (min)
    mem_bw: Optional[float]      # bytes/s, None = no roofline measurement

    def site_scores(self):
        """The searchable score table (``repro.calibrate.search``)."""
        from repro.calibrate.search import FormatOption, SiteScore

        out = []
        for r in self.rows:
            if not r["in_budget"]:
                continue
            opts = [FormatOption("bf16", DENSE_BPV, 0.0)]
            if r["packable"]:
                opts.append(FormatOption(
                    "hif4", PACKED_BPV, r["errors"]["hif4"]))
            out.append(SiteScore(path=r["path"], n_values=r["n_values"],
                                 options=tuple(opts)))
        return out


def _forward(params, batch, cfg, ctx):
    """One captured bf16 forward: prompt -> logits, any family."""
    if cfg.family == "audio":
        bos = jnp.zeros((batch["frames"].shape[0], 4), jnp.int32)
        x = lm.embed_tokens(params, bos, cfg, ctx)
        x = x + lm.sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        h, _ = lm._backbone(params, x, cfg, ctx, mode="train",
                            frames=batch["frames"])
    elif cfg.embeds_input:
        x = batch["embeds"].astype(ctx.compute_dtype)
        h, _ = lm._backbone(params, x, cfg, ctx, mode="train")
    else:
        x = lm.embed_tokens(params, batch["tokens"], cfg, ctx)
        h, _ = lm._backbone(params, x, cfg, ctx, mode="train")
    return lm.lm_logits(params, h, cfg, ctx)


def _in_budget(site, params) -> bool:
    if site.path == "embed":
        return False
    if site.path == "lm_head" and "lm_head" not in params:
        return False                                  # tied: reads embed.T
    return True


def _site_k(site) -> Optional[int]:
    """Contraction width K of one (stacked) site, from its plan record."""
    if site.contract_axes:
        return int(np.prod([site.shape[a] for a in site.contract_axes]))
    if len(site.shape) >= 2:
        return int(site.shape[0])    # tied lm_head: (d, V) contracts d
    return None


def _stacked(site) -> bool:
    return site.path.split(".")[0] in ("blocks", "shared", "enc_blocks")


def _weight_matrices(params, site) -> list:
    """Per-layer (K, N) contraction-major matrices for one site."""
    node = params
    for part in site.path.split("."):
        if part not in node:
            if site.path == "lm_head":          # tied: reads embed.T
                return [np.asarray(params["embed"], np.float32).T]
            raise KeyError(f"no param tensor at site {site.path!r}")
        node = node[part]
    w = np.asarray(node, np.float32)
    if not _stacked(site):
        ca = site.contract_axes or (0,)
        m = np.moveaxis(w, ca, range(len(ca)))
        return [m.reshape(int(np.prod(m.shape[:len(ca)])), -1)]
    out = []
    ca = tuple(a - 1 for a in site.contract_axes) or (0,)
    for l in range(w.shape[0]):
        m = np.moveaxis(w[l], ca, range(len(ca)))
        out.append(m.reshape(int(np.prod(m.shape[:len(ca)])), -1))
    return out


def _score_site(site, w_layers, x_layers, n_samples: int) -> dict:
    """Per-format mean layer-output error for one site."""
    errors = {f: [] for f in QDQ_FORMATS}
    higptq_errs = []
    from repro.core.formats import get_format

    for w_l, x_l in zip(w_layers, x_layers):
        x_l = x_l[:n_samples]
        for f in QDQ_FORMATS:
            wq = np.asarray(get_format(f).qdq(jnp.asarray(w_l.T))).T
            errors[f].append(rel_output_error(w_l, wq, x_l))
        if site.quantize_offline and w_l.shape[0] % 64 == 0:
            wg = higptq_quantize(jnp.asarray(w_l), jnp.asarray(x_l))
            higptq_errs.append(rel_output_error(w_l, np.asarray(wg), x_l))
    out = {f: float(np.mean(errors[f])) for f in QDQ_FORMATS}
    out["bf16"] = 0.0
    out["hif4_direct"] = out["hif4"]
    if higptq_errs:
        # what serving ships for a packed site: the HiGPTQ-rounded weight
        out["hif4"] = float(np.mean(higptq_errs))
    return out


def probe_sites(cfg: ArchConfig, *, params: Optional[dict] = None,
                n_batches: int = 2, batch: int = 2, seq_len: int = 64,
                seed: int = 0, n_samples: int = 256,
                mem_bw: Optional[float] = None,
                log=print) -> CalibrationResult:
    """Run the calibration pass and score every plan site (see module
    docstring). ``params`` defaults to a seeded random init (the same
    convention the scenario matrix serves)."""
    from repro.runtime.scenario import prefill_batch

    if params is None:
        params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    plan = lm.quant_plan(cfg, get_policy("uniform:hif4", impl="packed"))
    ctx = ModelCtx(remat=False, attn_q_chunk=8, attn_k_chunk=8)

    expect_k = {}
    for s in plan.sites:
        k = _site_k(s)
        if k is not None and s.path != "embed":
            expect_k[s.path] = k
    t = site_tap.ActivationTap(expect_k=expect_k)
    with jax.disable_jit(), site_tap.capture(t):
        for i in range(n_batches):
            out = _forward(params, prefill_batch(cfg, batch, seq_len,
                                                 seed=seed + i), cfg, ctx)
            jax.block_until_ready(out)
    log(f"[calibrate] probe: {n_batches} batches of ({batch}, {seq_len}) "
        f"through {cfg.family} forward; {len(t.paths())} sites captured")

    rows = []
    n_min = None
    for s in sorted(plan.sites, key=lambda s: s.path):
        in_budget = _in_budget(s, params)
        row = {
            "path": s.path,
            "n_values": s.n_values,
            "shape": list(s.shape),
            "packable": bool(s.packed),
            "in_budget": in_budget,
            "captured": s.path in t.records,
        }
        if s.path == "embed" or s.path not in t.records:
            # no matmul consumed this site this pass (embed is a gather);
            # keep the row for the audit but give the search nothing to
            # trade — scoring without real inputs would be fiction
            row.update({"errors": None, "bytes": None, "roofline_ms": None})
            rows.append(row)
            continue
        L = s.shape[0] if _stacked(s) else 1
        w_layers = _weight_matrices(params, s)
        x_layers = [t.rows(s.path, layer=l, n_layers=L) for l in range(L)]
        n_min = min(n_min or 10 ** 9, min(x.shape[0] for x in x_layers))
        row["errors"] = _score_site(s, w_layers, x_layers, n_samples)
        bpv = {f: DENSE_BPV for f in list(QDQ_FORMATS) + ["bf16"]}
        if s.packed:
            bpv["hif4"] = PACKED_BPV
        row["bytes"] = {f: round(b * s.n_values) for f, b in bpv.items()}
        if mem_bw:
            row["roofline_ms"] = {
                f: round(b / mem_bw * 1e3, 6) for f, b in row["bytes"].items()}
        else:
            row["roofline_ms"] = None
        rows.append(row)

    return CalibrationResult(
        arch=cfg.name, family=cfg.family, plan=plan, rows=tuple(rows),
        n_batches=n_batches, seq_len=seq_len, batch=batch, seed=seed,
        n_calib_rows=int(n_min or 0), mem_bw=mem_bw)
