"""End-to-end calibration: probe -> frontier search -> emitted policy.

``calibrate()`` is the library entry the CLI (``repro.launch.calibrate``)
and the scenario matrix's searched-policy cell both drive. Beyond
chaining the three layers it does the two pieces of bookkeeping that make
the output trustworthy:

* **baseline scoring** — the hand-written presets (``sensitive-fallback``,
  ``paper-iv``) are resolved against the same architecture and priced on
  the SAME probe score table, so "searched beats the fallback preset" is
  an apples-to-apples claim on one calibration set;
* **budget verification** — the emitted policy is round-tripped through
  ``get_policy`` -> ``lm.quant_plan`` and the byte residency recomputed
  from the resolved plan's ``packed_paths`` (exactly what
  ``prepare_params_for_serving`` packs). The search's byte accounting and
  the serving stack's must agree to the byte, or calibrate() raises.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.calibrate.emit import emit_policy, emit_report
from repro.calibrate.probe import DENSE_BPV, PACKED_BPV, probe_sites
from repro.calibrate.search import assignment_cost, frontier_search
from repro.configs import get_arch
from repro.core.policy import QuantRule, get_policy
from repro.models import lm

BASELINE_PRESETS = ("sensitive-fallback", "paper-iv")


def measure_bandwidth() -> Optional[float]:
    """Stream bandwidth in bytes/s via benchmarks/roofline.py, or None
    when the benchmarks package is not importable (it lives at the repo
    root, outside the installed ``repro`` tree)."""
    try:
        from benchmarks.roofline import measure_stream_bandwidth
    except ImportError:
        return None
    return float(measure_stream_bandwidth())


def _preset_assignment(cfg, preset: str, budget_paths) -> dict:
    """What a hand-written preset assigns, in the search's vocabulary:
    'hif4' where its resolved plan packs, 'bf16' elsewhere."""
    plan = lm.quant_plan(cfg, get_policy(preset, impl="packed"))
    return {p: ("hif4" if p in plan.packed_paths else "bf16")
            for p in budget_paths}


def _plan_bytes(plan, budget_sites) -> float:
    """Byte residency of the in-budget sites under a resolved plan —
    the serving-side ground truth (``packed_paths`` is exactly the set
    ``prepare_params_for_serving`` packs)."""
    return sum((PACKED_BPV if s.path in plan.packed_paths else DENSE_BPV)
               * s.n_values for s in budget_sites)


def calibrate(arch: str, *, reduced: bool = True, target_bpv=0.7,
              n_batches: int = 2, batch: int = 2, seq_len: int = 64,
              seed: int = 0, kv_format: str = "bf16",
              out: Optional[str] = None, report_out: Optional[str] = None,
              mem_bw: Optional[float] = None, measure_bw: bool = False,
              log=print) -> dict:
    """Probe ``arch``, search the frontier at ``target_bpv``, emit the
    policy (to ``out`` when given) and return the summary dict.

    ``target_bpv`` is a float budget in bytes/value — or the name of a
    baseline preset (``sensitive-fallback``, ``paper-iv``), meaning
    "match that preset's measured byte residency on this architecture":
    the Pareto comparison at equal bytes the matrix's
    searched_policy_frontier gate records.
    """
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    if mem_bw is None and measure_bw:
        mem_bw = measure_bandwidth()

    result = probe_sites(cfg, n_batches=n_batches, batch=batch,
                         seq_len=seq_len, seed=seed, mem_bw=mem_bw, log=log)
    sites = result.site_scores()
    budget_paths = [s.path for s in sites]
    n_total = sum(s.n_values for s in sites)
    baselines = {}
    for preset in BASELINE_PRESETS:
        a = _preset_assignment(cfg, preset, budget_paths)
        b, e = assignment_cost(sites, a)
        baselines[preset] = {
            "assignment": a, "total_bytes": round(b), "total_error": e,
            "achieved_bpv": round(b / n_total, 6),
        }

    target_spec = target_bpv
    if isinstance(target_bpv, str):
        if target_bpv not in baselines:
            raise ValueError(
                f"target_bpv={target_bpv!r}: expected a float or one of "
                f"{sorted(baselines)}")
        target_bpv = baselines[target_bpv]["total_bytes"] / n_total

    frontier = frontier_search(sites, target_bpv)
    log(f"[calibrate] search: target {target_bpv:.6g} B/value over "
        f"{len(sites)} sites -> achieved {frontier.achieved_bpv:.4f} "
        f"(feasible={frontier.feasible})")

    provenance = {
        "tool": "repro calibrate",
        "arch": cfg.name,
        "reduced": reduced,
        "target_bpv": round(target_bpv, 6),
        "target_spec": str(target_spec),
        "achieved_bpv": round(frontier.achieved_bpv, 6),
        "feasible": frontier.feasible,
        "calibration": {"n_batches": n_batches, "batch": batch,
                        "seq_len": seq_len, "seed": seed,
                        "n_calib_rows": result.n_calib_rows},
    }
    policy = emit_policy(frontier.assignment,
                         name=f"searched:{cfg.name}@{target_spec}",
                         kv_format=kv_format, provenance=provenance,
                         out=out)

    # budget verification against the serving stack's own byte accounting:
    # round-trip the emitted file through get_policy (or, without a file,
    # the in-memory equivalent of its impl-prepend) and recompute residency
    # from the resolved plan's packed_paths.
    if out is not None:
        served = get_policy(out, impl="packed")
    else:
        served = dataclasses.replace(
            policy, rules=(QuantRule("*", impl="packed"),) + policy.rules)
    plan = lm.quant_plan(cfg, served)
    in_budget = set(budget_paths)
    budget_sites = [s for s in plan.sites if s.path in in_budget]
    measured = _plan_bytes(plan, budget_sites)
    if abs(measured - frontier.total_bytes) > 0.5:
        raise AssertionError(
            f"search byte accounting ({frontier.total_bytes:.0f}) disagrees "
            f"with the resolved plan's packed_paths residency "
            f"({measured:.0f}) — the emitted policy does not serve what the "
            f"search priced")
    budget = target_bpv * n_total
    if frontier.feasible and measured > budget + 1e-6:
        raise AssertionError(
            f"emitted policy misses its own budget: {measured:.0f} B "
            f"resident > {budget:.0f} B allowed at {target_bpv} B/value")
    log(f"[calibrate] verified: {measured:.0f} B resident over "
        f"{n_total} values = {measured / n_total:.4f} B/value "
        f"(budget {target_bpv:.6g}), plan packs {len(plan.packed_paths)} "
        f"sites")

    report = emit_report(result, frontier, target_bpv=target_bpv,
                         baselines=baselines, out=report_out)
    return {
        "arch": cfg.name,
        "family": cfg.family,
        "target_bpv": round(target_bpv, 6),
        "target_spec": str(target_spec),
        "achieved_bpv": round(measured / n_total, 6),
        "feasible": frontier.feasible,
        "total_bytes": round(measured),
        "total_error": frontier.total_error,
        "n_sites": len(sites),
        "n_packed": len(plan.packed_paths & in_budget),
        "assignment": dict(sorted(frontier.assignment.items())),
        "baselines": baselines,
        "policy": policy,
        "policy_path": out,
        "report_path": report_out,
        "report": report,
    }
