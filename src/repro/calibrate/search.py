"""Frontier search: assign each site the cheapest format whose error fits.

Pure arithmetic over a per-site score table — no model, no activations —
so the search is property-testable in isolation (tests/test_calibrate.py
drives it with random tables under hypothesis).

Every site offers a set of :class:`FormatOption`\\ s (format name, bytes
per value at rest, measured error). The search:

1. drops dominated options per site (another option with <= bytes and
   <= error) and keeps the lower convex hull of the survivors in
   (bytes, error) space — ratios between consecutive hull points are
   then non-decreasing as bytes shrink;
2. starts every site at its max-bytes / min-error hull point and lists
   each site's hull steps as candidate MOVES, priced at marginal
   weighted-error per byte saved;
3. applies moves globally cheapest-first (deterministic tie-break on
   site path) until the byte budget ``target_bpv * total_values`` is
   met, recording the full Pareto curve along the way.

Because the applied move sequence is a PREFIX of one fixed global order,
raising ``target_bpv`` can only shorten the prefix: total error is
monotone non-increasing and total bytes monotone non-decreasing in the
target — the property the hypothesis test pins.

A site's ``weight`` (default: its value count) scales its error into the
objective, so a 1% output error on a 65k-value projection outweighs the
same error on a tiny router.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class FormatOption:
    """One residency choice for a site: ``fmt`` at ``bytes_per_value``
    costing ``error`` (any non-negative score; the probe uses relative
    layer-output error)."""

    fmt: str
    bytes_per_value: float
    error: float


@dataclasses.dataclass(frozen=True)
class SiteScore:
    """One site's row of the score table."""

    path: str
    n_values: int
    options: tuple  # tuple[FormatOption, ...], at least one
    weight: Optional[float] = None  # objective scale; default n_values

    @property
    def w(self) -> float:
        return float(self.n_values if self.weight is None else self.weight)


@dataclasses.dataclass(frozen=True)
class FrontierResult:
    """The searched assignment plus the Pareto curve that led to it.

    assignment   : {site path: chosen format name}
    total_bytes  : bytes at rest under the assignment
    total_error  : sum of weighted site errors under the assignment
    achieved_bpv : total_bytes / total values
    feasible     : the byte budget was met (False = even the cheapest
                   assignment exceeds it; the cheapest is returned)
    curve        : [{bpv, total_bytes, total_error, moved, fmt}] — entry 0
                   is the all-min-error start, one entry per applied move
                   when infeasible/exact, the full move list otherwise
                   (the complete accuracy-vs-bytes frontier artifact)
    """

    assignment: dict
    total_bytes: float
    total_error: float
    achieved_bpv: float
    feasible: bool
    curve: tuple


def _hull(options: Sequence[FormatOption]) -> list:
    """Dominance-filtered lower convex hull, max-bytes first.

    Input options are arbitrary; output is ordered by strictly decreasing
    bytes_per_value with strictly increasing error, and consecutive
    error-per-byte-saved ratios non-decreasing (convexity) — the shape
    the prefix-monotone greedy needs."""
    # dominance filter: keep the min-error option at each bytes level,
    # then drop any option beaten on both axes
    best_at = {}
    for o in options:
        cur = best_at.get(o.bytes_per_value)
        if cur is None or (o.error, o.fmt) < (cur.error, cur.fmt):
            best_at[o.bytes_per_value] = o
    cands = sorted(best_at.values(),
                   key=lambda o: (-o.bytes_per_value, o.error, o.fmt))
    undominated = []
    for o in cands:  # bytes descending: a kept point with >= error is
        while undominated and undominated[-1].error >= o.error:  # dominated
            undominated.pop()
        undominated.append(o)
    # graham-scan style convexification in (bytes, error), bytes desc
    hull: list = []
    for o in undominated:
        while len(hull) >= 2:
            a, b = hull[-2], hull[-1]
            # slope of a->b vs a->o (error rise per byte saved); keep b
            # only if it bends the right way (convex)
            lhs = (b.error - a.error) * (a.bytes_per_value - o.bytes_per_value)
            rhs = (o.error - a.error) * (a.bytes_per_value - b.bytes_per_value)
            if lhs >= rhs:
                hull.pop()
            else:
                break
        hull.append(o)
    return hull


def frontier_search(sites: Sequence[SiteScore],
                    target_bpv: float) -> FrontierResult:
    """Greedy marginal-utility frontier search (see module docstring)."""
    assert sites, "frontier_search needs at least one site"
    hulls = {s.path: _hull(s.options) for s in sites}
    n_total = sum(s.n_values for s in sites)
    budget = target_bpv * n_total

    # start: every site at its min-error (= max-bytes hull) point
    assignment = {s.path: hulls[s.path][0].fmt for s in sites}
    total_bytes = sum(hulls[s.path][0].bytes_per_value * s.n_values
                      for s in sites)
    total_error = sum(hulls[s.path][0].error * s.w for s in sites)

    # candidate moves: each site's hull steps, priced marginally. Within a
    # site, convexity makes ratios non-decreasing, so a global sort keeps
    # per-site order — the applied sequence is a prefix of one fixed list.
    moves = []
    for s in sites:
        h = hulls[s.path]
        for i in range(1, len(h)):
            d_bytes = (h[i - 1].bytes_per_value
                       - h[i].bytes_per_value) * s.n_values
            d_error = (h[i].error - h[i - 1].error) * s.w
            moves.append((d_error / d_bytes, s.path, i, d_bytes, d_error,
                          h[i].fmt))
    moves.sort(key=lambda m: (m[0], m[1], m[2]))

    curve = [{"bpv": round(total_bytes / n_total, 6),
              "total_bytes": total_bytes, "total_error": total_error,
              "moved": None, "fmt": None}]
    met = total_bytes <= budget
    for _ratio, path, _i, d_bytes, d_error, fmt in moves:
        # the curve walks EVERY move (the full frontier is an artifact);
        # the assignment only follows it until the budget is met
        total_b = curve[-1]["total_bytes"] - d_bytes
        total_e = curve[-1]["total_error"] + d_error
        curve.append({"bpv": round(total_b / n_total, 6),
                      "total_bytes": total_b, "total_error": total_e,
                      "moved": path, "fmt": fmt})
        if not met:
            assignment[path] = fmt
            total_bytes -= d_bytes
            total_error += d_error
            met = total_bytes <= budget
    feasible = met
    return FrontierResult(
        assignment=assignment,
        total_bytes=total_bytes,
        total_error=total_error,
        achieved_bpv=total_bytes / n_total,
        feasible=feasible,
        curve=tuple(curve),
    )


def assignment_cost(sites: Sequence[SiteScore], assignment: dict) -> tuple:
    """(total_bytes, total_error) of an explicit {path: fmt} assignment —
    used to score a hand-written preset on the same table the search ran
    on. Falls back to a site's min-error option when the assignment names
    a format the site has no option for."""
    total_b = total_e = 0.0
    for s in sites:
        by_fmt = {o.fmt: o for o in s.options}
        o = by_fmt.get(assignment.get(s.path))
        if o is None:
            o = min(s.options, key=lambda o: (o.error, o.bytes_per_value))
        total_b += o.bytes_per_value * s.n_values
        total_e += o.error * s.w
    return total_b, total_e
