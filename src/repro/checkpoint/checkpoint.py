"""Fault-tolerant checkpointing: atomic, hashed, async, reshard-on-restore.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json    # tree structure, shapes, dtypes, sha256 per array
        arr_00000.npy ... arr_NNNNN.npy
        extra.json       # non-array state (data-iterator state, step, ...)

Atomicity: written into ``step_XXX.tmp`` then os.rename'd — a crash mid-
write never leaves a directory the loader would accept (``latest_step``
only considers directories with a valid manifest).

Reshard-on-restore / elastic scaling: arrays are saved UNSHARDED (gathered
to host); ``load_checkpoint(..., shardings=)`` device_puts each leaf with
the target sharding, so a checkpoint written on a 256-chip mesh restores
onto 512 chips (or 1 CPU) without conversion — the elastic-scaling path.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """Base of typed checkpoint-load errors (subclasses RuntimeError so
    pre-existing ``except RuntimeError`` handling keeps working)."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint array's bytes no longer match the sha256 recorded in
    its manifest — the payload was corrupted after it was written."""


def _tree_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None, *, verify: bool = True) -> str:
    """Atomically write ``tree`` (a pytree of arrays) + ``extra`` state."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _tree_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "arrays": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)                      # gathers sharded arrays
        fn = f"arr_{i:05d}.npy"
        # raw-byte container: numpy can't serialize bf16/f8 natively
        np.save(os.path.join(tmp, fn),
                np.frombuffer(arr.tobytes(), dtype=np.uint8))
        entry = {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if verify:
            with open(os.path.join(tmp, fn), "rb") as f:
                entry["sha256"] = hashlib.sha256(f.read()).hexdigest()
        manifest["arrays"].append(entry)

    with open(os.path.join(tmp, "extra.json"), "w") as f:
        json.dump(extra or {}, f)
    # manifest LAST: its presence marks the payload complete
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Largest step with a complete (manifest-bearing) checkpoint."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if not os.path.exists(os.path.join(directory, name, "manifest.json")):
            continue
        s = int(m.group(1))
        best = s if best is None or s > best else best
    return best


def load_checkpoint(directory: str, step: int, target_tree: Any,
                    *, shardings: Any = None, verify: bool = False):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional pytree of NamedShardings (same structure); each
    leaf is device_put with its target sharding — reshard-on-restore.
    Returns (tree, extra).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _tree_paths(target_tree)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
    )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves)
    )

    out = []
    for entry, ref, shd in zip(manifest["arrays"], leaves, shard_leaves):
        fp = os.path.join(path, entry["file"])
        if verify and "sha256" in entry:
            with open(fp, "rb") as f:
                h = hashlib.sha256(f.read()).hexdigest()
            if h != entry["sha256"]:
                raise CheckpointCorruptError(
                    f"checkpoint array {fp} fails its manifest sha256 "
                    f"(expected {entry['sha256'][:12]}..., got {h[:12]}...) "
                    "— the payload was corrupted after the atomic write; "
                    "restore an earlier step or re-save the checkpoint")
        raw = np.load(fp)
        arr = np.frombuffer(raw.tobytes(), dtype=_resolve_dtype(entry["dtype"]))
        arr = arr.reshape(entry["shape"])
        want = tuple(ref.shape)
        assert tuple(arr.shape) == want, (entry["file"], arr.shape, want)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    with open(os.path.join(path, "extra.json")) as f:
        extra = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, out), extra


class CheckpointManager:
    """Async wrapper: overlaps serialization with the next train steps."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # materialize on host NOW (so the train loop can donate buffers)
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", name))
            and os.path.exists(os.path.join(self.directory, name, "manifest.json"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
