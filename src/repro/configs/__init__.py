# One module per assigned architecture; registration happens on import via
# repro.configs.base.register_arch. Use get_arch("<id>") / all_archs().
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    ShapeConfig,
    all_archs,
    applicable_shapes,
    get_arch,
    get_shape,
)
