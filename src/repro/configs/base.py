"""Config system: architecture, shape, and run configuration.

Every assigned architecture is a frozen :class:`ArchConfig` registered under
its id; ``--arch <id>`` in the launchers resolves through
:func:`get_arch`. ``ArchConfig.reduced()`` yields the scaled-down variant
used by CPU smoke tests (same family/features, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int = 0
    activation: str = "swiglu"      # swiglu | squared_relu | gelu
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0      # zamba2: shared attn block every N layers
    enc_layers: int = 0             # whisper: encoder depth (enc-dec if > 0)
    embeds_input: bool = False      # vlm/audio: frontend stub feeds embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""                # provenance tag from the assignment

    # ----- derived -----
    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid only; see DESIGN.md SS6)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    def n_params(self) -> int:
        """Approximate parameter count (embedding included)."""
        d, L = self.d_model, self.n_layers
        p = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn is not None:
            a = self.attn
            per_layer += d * a.n_heads * a.d_head * 2          # q, o
            per_layer += d * a.n_kv_heads * a.d_head * 2       # k, v
        if self.moe is not None:
            m = self.moe
            n_mats = 3 if self.activation == "swiglu" else 2
            per_layer += m.n_experts * d * m.d_expert * n_mats + d * m.n_experts
        elif self.d_ff:
            n_mats = 3 if self.activation == "swiglu" else 2
            per_layer += d * self.d_ff * n_mats
        if self.ssm is not None:
            s = self.ssm
            di = s.expand * d
            n_heads = di // s.head_dim
            conv_dim = di + 2 * s.n_groups * s.d_state
            per_layer += d * (2 * di + 2 * s.n_groups * s.d_state + n_heads)
            per_layer += conv_dim * s.conv_kernel + di * d
        n_body = L if not self.is_encdec else L + self.enc_layers
        if self.hybrid_attn_every and self.attn is not None:
            # shared attention block counted once, not per invocation
            a = self.attn
            shared = d * (a.n_heads + 2 * a.n_kv_heads) * a.d_head + a.n_heads * a.d_head * d
            shared += d * self.d_ff * (3 if self.activation == "swiglu" else 2)
            ssm_layers = L
            return p + ssm_layers * per_layer + shared
        return p + n_body * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        n_mats = 3 if self.activation == "swiglu" else 2
        inactive = (m.n_experts - m.top_k) * self.d_model * m.d_expert * n_mats
        return self.n_params() - self.n_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=128,
            vocab=512,
            d_ff=256 if self.d_ff else 0,
        )
        if self.attn is not None:
            kw["attn"] = dataclasses.replace(
                self.attn,
                n_heads=4,
                n_kv_heads=max(1, 4 * self.attn.n_kv_heads // self.attn.n_heads),
                d_head=32,
            )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=4,
                top_k=min(2, self.moe.top_k),
                d_expert=128,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 1
        if self.enc_layers:
            kw["enc_layers"] = 2
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch per kind
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable_shapes(arch: ArchConfig) -> list[str]:
    """The assignment's applicability rules (DESIGN.md SS6)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.sub_quadratic:
        out.append("long_500k")
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCHES: dict[str, ArchConfig] = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _ARCHES[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    try:
        return _ARCHES[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_ARCHES)}")


def all_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_ARCHES)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # import every config module once so registration side effects run
    from repro.configs import (  # noqa: F401
        granite_moe_1b,
        llava_next_34b,
        mamba2_1p3b,
        nemotron_4_340b,
        phi35_moe,
        qwen15_0p5b,
        qwen15_4b,
        qwen3_4b,
        whisper_tiny,
        zamba2_2p7b,
    )

    _LOADED = True
