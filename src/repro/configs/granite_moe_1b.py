"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        vocab=49155,
        d_ff=512,
        activation="swiglu",
        attn=AttnConfig(
            n_heads=16,
            n_kv_heads=8,
            d_head=64,
            rope_theta=10_000.0,
        ),
        moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )
)
