"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.

The anyres-tiling vision frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, seq, d_model); the framework runs the language backbone on them.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        vocab=64000,
        d_ff=20480,
        activation="swiglu",
        attn=AttnConfig(
            n_heads=56,
            n_kv_heads=8,
            d_head=128,
            rope_theta=5_000_000.0,
        ),
        embeds_input=True,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    )
)
