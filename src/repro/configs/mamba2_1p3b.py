"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        vocab=50280,
        d_ff=0,                     # attention-free, no FFN (Mamba block only)
        ssm=SSMConfig(
            d_state=128,
            expand=2,
            head_dim=64,
            n_groups=1,
            conv_kernel=4,
            chunk=256,
        ),
        tie_embeddings=True,
        source="arXiv:2405.21060; unverified",
    )
)
