"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.

Features: GQA, squared-ReLU (non-gated) FFN.  [arXiv:2402.16819; unverified]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        vocab=256000,
        d_ff=73728,
        activation="squared_relu",
        attn=AttnConfig(
            n_heads=96,
            n_kv_heads=8,
            d_head=192,
            rope_theta=10_000.0,
        ),
        source="arXiv:2402.16819; unverified",
    )
)
