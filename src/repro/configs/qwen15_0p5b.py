"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.

Features: QKV bias (MHA: kv == heads).  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        vocab=151936,
        d_ff=2816,
        activation="swiglu",
        attn=AttnConfig(
            n_heads=16,
            n_kv_heads=16,
            d_head=64,
            qkv_bias=True,
            rope_theta=1_000_000.0,
        ),
        tie_embeddings=True,
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
)
