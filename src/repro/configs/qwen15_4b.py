"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.

Features: QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        vocab=151936,
        d_ff=6912,
        activation="swiglu",
        attn=AttnConfig(
            n_heads=20,
            n_kv_heads=20,
            d_head=128,
            qkv_bias=True,
            rope_theta=1_000_000.0,
        ),
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
)
