"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

Features: qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        vocab=151936,
        d_ff=9728,
        activation="swiglu",
        attn=AttnConfig(
            n_heads=32,
            n_kv_heads=8,
            d_head=128,          # qwen3 uses d_head=128 (not d_model/n_heads)
            qkv_bias=False,
            qk_norm=True,
            rope_theta=1_000_000.0,
        ),
        source="hf:Qwen/Qwen3-8B; hf",
    )
)
