"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; the conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (batch, frames,
d_model) for the encoder. Decoder shapes follow the LM shape set with
seq_len interpreted as encoder frames (prefill) / decoder KV length
(decode).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig, AttnConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,                 # decoder layers
        enc_layers=4,               # encoder layers
        d_model=384,
        vocab=51865,
        d_ff=1536,
        activation="gelu",
        attn=AttnConfig(
            n_heads=6,
            n_kv_heads=6,
            d_head=64,
            rope_theta=10_000.0,    # whisper uses learned/sinusoidal pos; we use RoPE-free sinusoidal
        ),
        embeds_input=True,
        source="arXiv:2212.04356; unverified",
    )
)
