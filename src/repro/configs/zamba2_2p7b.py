"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks.

We follow the Zamba2 scheme at the granularity this framework models: 54
Mamba2 layers with ONE shared full transformer block (attention + FFN)
invoked every ``hybrid_attn_every`` layers, each invocation keeping its own
KV cache. (Zamba2's per-invocation LoRA deltas on the shared block are
omitted — noted in DESIGN.md.)  [arXiv:2411.15242; hf]
"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        vocab=32000,
        d_ff=10240,
        activation="gelu",
        attn=AttnConfig(
            n_heads=32,
            n_kv_heads=32,
            d_head=80,
            rope_theta=10_000.0,
        ),
        ssm=SSMConfig(
            d_state=64,
            expand=2,
            head_dim=64,
            n_groups=1,
            conv_kernel=4,
            chunk=256,
        ),
        hybrid_attn_every=6,
        source="arXiv:2411.15242; hf",
    )
)
