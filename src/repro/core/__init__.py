# The paper's primary contribution: the HiF4 block floating-point format,
# its conversion algorithm (Alg. 1), baseline formats (NVFP4/MXFP4),
# quantized matmul, and HiGPTQ. Pure JAX; Pallas kernels in repro.kernels.
from repro.core.formats import available_formats, get_format  # noqa: F401
