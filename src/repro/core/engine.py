"""Execution engine: the single dispatch point for quantized matmuls.

``QuantConfig.impl`` selects how a quantized contraction actually executes;
every model-side linear layer funnels through :func:`matmul`, so the three
paths advertised by the config are now real dispatch instead of
documentation:

  qdq    — fake-quant the operands, matmul in bf16/f32. Lowers on any
           backend and is differentiable (STE); the training and accuracy-
           experiment path.
  packed — the weight is resident as a :class:`~repro.core.qlinear.PackedW`
           (HiF4 bit-packed buffers, 0.5625 bytes/value) and is contracted
           by the FUSED packed-operand matmul: the kernel reads the 4.5-bit
           payload tiles directly and expands them to absorbed int8 inside
           VMEM (``repro.kernels.fused_matmul``), so serving HBM traffic is
           the packed payload — no (K, N) bf16/int8 intermediate. Off-TPU
           the identical contraction runs as straight-line XLA
           (``fused_packed_matmul_xla``); activations are quantized
           dynamically either way. The serving deployment path.
  pallas — the paper's §III.B fixed-point flow. On a PackedW it IS the
           fused packed kernel (same dispatch as ``packed``); on a dense
           weight it ``hif4_quantize``s both operands (Algorithm 1 kernel)
           and contracts with ``bfp_matmul_quantized``. Runs in interpret
           mode off-TPU.

Dispatch is **total**: a combination an impl cannot execute falls back to
the closest executable path instead of erroring, so model code never guards
call sites. The fallbacks (see docs/EXECUTION.md for the full matrix):

  * non-HiF4 formats on ``pallas``          -> qdq (kernels are HiF4-only)
  * ``weights_only`` on ``pallas``          -> qdq (the integer dot
                                               inherently quantizes both)
  * dense (unpacked) weight under ``packed``-> qdq (nothing resident to
                                               contract against)
  * PackedW under ``qdq``                   -> dequantize-then-dot (a
                                               4.5-bit buffer can only be
                                               dequantized)
  * PackedW × ``weights_only`` / non-HiF4
    fmt / non-innermost contraction         -> dequantize-then-dot (the
                                               fused kernel quantizes
                                               activations and tiles K)
  * contraction not a whole number of
    64-groups                               -> qdq

The engine context also carries the :class:`ShardCtx` that packed-weight
dequantization needs (gather the 4.5-bit payload, not the dequantized bf16
weight) — previously a module-level mutable (``_PACKED_SHARD``), now
threaded explicitly from the model context.

Decode attention over an HiF4-packed KV cache dispatches here too
(:func:`attention_decode`): impl packed/pallas on a kernel-tileable cache
on TPU runs the fused Pallas flash kernel
(``repro.kernels.fused_attention`` — the 4.5-bit payload expands per KV
tile inside VMEM); every other combination runs its bit-exact XLA twin,
whose bf16 working set is still one KV tile. The bf16 cache path never
enters the engine. See docs/EXECUTION.md for the attention matrix.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hif4, kvcache
from repro.core import tap as site_tap
from repro.core.qlinear import (
    NO_QUANT,
    PackedW,
    QuantConfig,
    quantize_activation,
    quantize_weight,
)
# Imported at module scope deliberately: the kernel modules concretize
# bf16-rounded constants at import time, so a first import from inside a
# traced scan body would see tracers and fail.
from repro.kernels.bfp_matmul import bfp_matmul_quantized, select_block_sizes
from repro.kernels.fused_attention import (
    fused_decode_attention,
    fused_decode_attention_xla,
    fused_paged_decode_attention,
    fused_paged_decode_attention_xla,
    kernel_compatible,
    select_kv_block,
)
from repro.kernels.fused_matmul import (
    absorbed_activation,
    fused_packed_matmul,
    fused_packed_matmul_xla,
)
from repro.kernels.hif4_quant import hif4_quantize
from repro.sharding.rules import NO_SHARD, ShardCtx


@functools.lru_cache(maxsize=None)
def _default_backend() -> str:
    """Backend detection, resolved once per process.

    ``jax.default_backend()`` walks the backend registry; un-cached it ran
    on EVERY matmul dispatch inside the decode scan body (trace time, but
    per call site per retrace).
    """
    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class EngineCtx:
    """Everything a quantized contraction needs besides its operands."""

    quant: QuantConfig = NO_QUANT
    shard: ShardCtx = dataclasses.field(default_factory=lambda: NO_SHARD)
    # Pallas interpret mode: None = auto (interpret everywhere but TPU).
    interpret: Optional[bool] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is None:
            return _default_backend() != "tpu"
        return self.interpret


DEFAULT_ENGINE = EngineCtx()


def matmul(
    x: jnp.ndarray,
    w,
    ectx: EngineCtx = DEFAULT_ENGINE,
    *,
    contract_x: int = -1,
    contract_w: int = 0,
    precision=None,
    accum_dtype=None,
) -> jnp.ndarray:
    """``x @ w`` through the configured execution path.

    ``w`` is a dense array or a :class:`PackedW`. ``accum_dtype`` is the dot
    OUTPUT dtype on the qdq/packed-fallback paths (default x.dtype; see
    qmatmul for the TP wire rationale); the fused/pallas kernels always
    accumulate f32 and cast once at the end.
    """
    cfg = ectx.quant
    # calibration probe: record this contraction's activation operand under
    # the site path ModelCtx.site_quant marked (no-op without an installed
    # tap — see repro.core.tap)
    site_tap.consume_pending(x, contract_x)
    if isinstance(w, PackedW):
        if _fused_packed_ok(cfg, x, contract_x, w):
            return _fused_packed_matmul(x, w, ectx)
        return _packed_matmul(x, w, ectx, contract_x=contract_x,
                              accum_dtype=accum_dtype)
    if (
        cfg.enabled
        and cfg.impl == "pallas"
        and _pallas_activation_ok(cfg, x, contract_x)
        and _pallas_weight_ok(w, contract_w)
    ):
        return _pallas_dense_matmul(x, w, ectx)
    return _qdq_matmul(x, w, cfg, contract_x=contract_x, contract_w=contract_w,
                       precision=precision, accum_dtype=accum_dtype)


def qdq_einsum(eq: str, a: jnp.ndarray, w: jnp.ndarray, ectx: EngineCtx,
               *, a_axis: int = -1, w_axis: int = 1) -> jnp.ndarray:
    """Batched-contraction einsum (MoE expert matmuls) on the qdq path.

    Batched-expert weights have no packed/pallas dispatch yet (the (E, C)
    dispatch buffer re-tiles per step, so there is no static packed operand
    to contract against); they always execute fake-quant regardless of
    ``impl`` — documented in the docs/EXECUTION.md matrix.
    """
    cfg = ectx.quant
    site_tap.consume_pending(a, a_axis)
    if cfg.enabled:
        a = quantize_activation(a, cfg, axis=a_axis)
        w = quantize_weight(w, cfg, axis=w_axis)
    return jnp.einsum(eq, a, w)


# ---------------------------------------------------------------------------
# qdq path
# ---------------------------------------------------------------------------


def _qdq_matmul(x, w, cfg, *, contract_x, contract_w, precision, accum_dtype):
    out_dtype = x.dtype
    if cfg.enabled:
        x = quantize_activation(x, cfg, axis=contract_x)
        w = quantize_weight(w, cfg, axis=contract_w)
    cx = contract_x % x.ndim
    cw = contract_w % w.ndim
    y = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((cx,), (cw,)), ((), ())),
        precision=precision,
        preferred_element_type=accum_dtype or out_dtype,
    )
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# fused packed path: the kernel consumes the 4.5-bit payload directly
# ---------------------------------------------------------------------------


def _fused_packed_ok(cfg: QuantConfig, x, contract_x: int, w: PackedW) -> bool:
    """The fused kernel dynamically quantizes activations and tiles K, so it
    needs: a packed/pallas impl on the HiF4 format, both-operand
    quantization, and an innermost-axis contraction of exactly K."""
    return (
        cfg.impl in ("packed", "pallas")
        and cfg.fmt == "hif4"
        and not cfg.weights_only
        and contract_x % x.ndim == x.ndim - 1
        and x.shape[-1] == w.shape2d[0]
    )


# The XLA twin's group-batched dot materializes a (K/64, M, N) f32
# intermediate (the Pallas kernel keeps it tile-sized in VMEM). Fine for
# decode (tiny M) and smoke prefill; at large-M off-TPU prefill it would be
# K/64 times the output — cap it and take the dequantize fallback instead.
_XLA_FUSED_PART_BYTES_MAX = 128 * 2 ** 20


def _fused_packed_matmul(x, w: PackedW, ectx: EngineCtx):
    """Serving hot path: dynamic activation quant × packed resident weight,
    dequantized inside the contraction — never a (K, N) HBM intermediate."""
    out_dtype = x.dtype
    k, n = w.shape2d
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if ectx.resolved_interpret():
        # Off-TPU there is no Pallas lowering; interpret mode is a test
        # vehicle, not a serving path. Run the SAME fused contraction as
        # straight-line XLA (bit-exact vs the kernel; see fused_matmul) —
        # unless its batched-dot intermediate would dwarf the output.
        part_bytes = (k // hif4.GROUP_SIZE) * x2.shape[0] * n * 4
        if part_bytes > _XLA_FUSED_PART_BYTES_MAX:
            return _packed_matmul(x, w, ectx, contract_x=-1, accum_dtype=None)
        codes_km, meta_km = w.kernel_operands(shard=ectx.shard)
        ai, asc = absorbed_activation(x2)
        y = fused_packed_matmul_xla(ai, asc, codes_km, meta_km)
    else:
        codes_km, meta_km = w.kernel_operands(shard=ectx.shard)
        ai, asc = hif4_quantize(x2, interpret=False)
        y = fused_packed_matmul(ai, asc, codes_km, meta_km, interpret=False)
    return y.reshape(lead + (n,)).astype(out_dtype)


def packed_dispatch_info(quant: QuantConfig, w: PackedW, *, decode_m: int,
                         prefill_m: int, interpret: Optional[bool] = None):
    """What the engine will actually run for ``w`` under ``quant`` — the
    launcher prints this next to the residency lines.

    Returns a dict with ``fused`` (bool), ``execution`` (human string), and
    per-regime kernel block sizes (None on the XLA twin, which doesn't
    tile).
    """
    ectx = EngineCtx(quant=quant, interpret=interpret)
    k, n = w.shape2d
    probe = jax.ShapeDtypeStruct((decode_m, k), jnp.bfloat16)
    fused = _fused_packed_ok(quant, probe, -1, w)
    if not fused:
        return {"fused": False, "execution": "dequantize-then-dot fallback",
                "decode_blocks": None, "prefill_blocks": None}
    if ectx.resolved_interpret():
        return {"fused": True,
                "execution": "XLA fused contraction (off-TPU twin)",
                "decode_blocks": None, "prefill_blocks": None}
    return {"fused": True, "execution": "Pallas fused kernel",
            "decode_blocks": select_block_sizes(decode_m, n, k),
            "prefill_blocks": select_block_sizes(prefill_m, n, k)}


# ---------------------------------------------------------------------------
# fused decode-attention path: the kernel consumes the packed KV cache
# ---------------------------------------------------------------------------


def _fused_attn_ok(cfg: QuantConfig, k_cache: dict, n_kv_heads: int,
                   d_head: int) -> bool:
    """The Pallas decode-attention kernel needs a packed/pallas impl and a
    kernel-tileable cache (kernel-tile layout, no staging tail, head blocks
    dividing the head count)."""
    return (
        cfg.impl in ("packed", "pallas")
        and kernel_compatible(k_cache, n_kv_heads, d_head)
    )


def attention_decode(
    q: jnp.ndarray,          # (B, H, D) single query token
    k_cache: dict,           # HiF4-packed leaves {codes, meta, tail}
    v_cache: dict,
    length: jnp.ndarray,     # (B,) valid cache prefix per slot
    n_kv_heads: int,
    d_head: int,
    ectx: EngineCtx = DEFAULT_ENGINE,
    *,
    pages: Optional[jnp.ndarray] = None,   # (B, max_pages) page table
    block_kv: Optional[int] = None,        # contiguous KV tile override
) -> jnp.ndarray:
    """Decode attention against a PACKED KV cache, dispatched like matmul.

    impl packed/pallas x a kernel-tileable cache x TPU runs the fused
    Pallas kernel (``repro.kernels.fused_attention``): the 4.5-bit payload
    streams into VMEM and expands per KV tile. Every other combination —
    off-TPU, qdq impl, artifact layout, staging tail — runs the bit-exact
    XLA twin, whose bf16 working set is still ONE KV tile, never the cache.
    bf16 caches never reach this function (``attn_decode`` keeps the dense
    path untouched). See docs/EXECUTION.md for the full matrix.

    With ``pages`` set, ``k_cache``/``v_cache`` are page-POOL leaves
    ((n_pages, F, P), ``repro.core.kvcache.init_page_pool``) and the same
    dispatch picks the paged kernel / paged XLA twin — the KV-tile grid
    axis walks the page table instead of a contiguous token axis.
    ``block_kv`` overrides the contiguous tile size (the paged tile IS
    the page size); serving threads it from ``ModelCtx.attn_kv_block`` so
    a solo reference run can align its tile partition with a paged run
    for bitwise comparison.
    """
    fused = (_fused_attn_ok(ectx.quant, k_cache, n_kv_heads, d_head)
             and not ectx.resolved_interpret())
    if pages is not None:
        if fused:
            return fused_paged_decode_attention(
                q, k_cache, v_cache, pages, length,
                n_kv_heads=n_kv_heads, d_head=d_head, interpret=False)
        return fused_paged_decode_attention_xla(
            q, k_cache, v_cache, pages, length, n_kv_heads, d_head)
    if fused:
        return fused_decode_attention(
            q, k_cache, v_cache, length,
            n_kv_heads=n_kv_heads, d_head=d_head, block_kv=block_kv,
            interpret=False)
    return fused_decode_attention_xla(
        q, k_cache, v_cache, length, n_kv_heads, d_head, block_kv=block_kv)


def attention_dispatch_info(quant: QuantConfig, k_cache: dict, *,
                            n_kv_heads: int, d_head: int,
                            interpret: Optional[bool] = None,
                            paged: bool = False):
    """What :func:`attention_decode` will run for this cache under
    ``quant`` — the launcher prints it next to the fused-matmul line,
    and the scenario matrix asserts it per cell.

    Returns ``fused`` (bool: the Pallas kernel), ``execution`` (human
    string), ``block_kv`` (the KV tile both executions stream),
    ``kernel_eligible`` (backend-NEUTRAL: the cache/impl combination the
    fused kernel accepts — True still runs the bit-exact twin off-TPU),
    and ``route`` (the exact function dispatch picks on THIS backend).
    ``paged=True`` answers for a page-pool cache (``pages`` passed to
    :func:`attention_decode`): the same eligibility picks the paged
    kernel / paged twin pair instead.
    """
    ectx = EngineCtx(quant=quant, interpret=interpret)
    block = (kvcache.pool_page_tokens(k_cache) if paged
             else select_kv_block(kvcache.seq_capacity(k_cache)))
    eligible = _fused_attn_ok(quant, k_cache, n_kv_heads, d_head)
    routes = (("fused_paged_decode_attention", "fused_paged_decode_attention_xla")
              if paged else
              ("fused_decode_attention", "fused_decode_attention_xla"))
    if not eligible:
        if quant.impl not in ("packed", "pallas"):
            why = f"impl={quant.impl}"
        elif not kvcache.is_kernel_layout(k_cache):
            why = "artifact layout"
        else:
            # the only remaining kernel_compatible failure: F % 64 != 0
            # (a tail-free F always makes Hkv divisible by the head block)
            why = "staging tail"
        return {"fused": False, "block_kv": block, "kernel_eligible": False,
                "route": routes[1],
                "execution": f"XLA twin (chunked dequantize; {why})"}
    if ectx.resolved_interpret():
        return {"fused": False, "block_kv": block, "kernel_eligible": True,
                "route": routes[1],
                "execution": "XLA twin (chunked dequantize; off-TPU)"}
    return {"fused": True, "block_kv": block, "kernel_eligible": True,
            "route": routes[0], "execution": "Pallas fused kernel"}


# ---------------------------------------------------------------------------
# packed fallback: dequantize the PackedW in-graph, then a dense dot.
# Taken when the fused kernel cannot run (qdq impl, weights_only, non-HiF4
# activation format, non-innermost contraction) — see docs/EXECUTION.md.
# ---------------------------------------------------------------------------


def _packed_matmul(x, w: PackedW, ectx: EngineCtx, *, contract_x, accum_dtype):
    out_dtype = x.dtype
    wd = w.dequantize(shard=ectx.shard)                 # (K, N) dense
    x = quantize_activation(x, ectx.quant, axis=contract_x)
    cx = contract_x % x.ndim
    y = jax.lax.dot_general(
        x,
        wd,
        dimension_numbers=(((cx,), (0,)), ((), ())),
        preferred_element_type=accum_dtype or out_dtype,
    )
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# pallas path: Algorithm-1 quantize kernel + §III.B fixed-point matmul
# ---------------------------------------------------------------------------


def _pallas_activation_ok(cfg: QuantConfig, x, contract_x: int) -> bool:
    return (
        cfg.fmt == "hif4"
        and not cfg.weights_only
        and contract_x % x.ndim == x.ndim - 1
        and x.shape[-1] % hif4.GROUP_SIZE == 0
    )


def _pallas_weight_ok(w, contract_w: int) -> bool:
    return (
        w.ndim == 2
        and contract_w % w.ndim == 0
        and w.shape[0] % hif4.GROUP_SIZE == 0
    )


def _pallas_dense_matmul(x, w, ectx: EngineCtx):
    """Both operands quantized by the Algorithm-1 kernel each call (A-W
    dynamic quantization; the offline-weights variant is the fused packed
    path)."""
    interp = ectx.resolved_interpret()
    out_dtype = x.dtype
    lead, K = x.shape[:-1], x.shape[-1]
    N = w.shape[1]
    ai, asc = hif4_quantize(x.reshape(-1, K), interpret=interp)
    wi, wsc = hif4_quantize(w.T, interpret=interp)       # rows along K-groups
    y = bfp_matmul_quantized(ai, asc, wi.T, wsc.T, interpret=interp)
    return y.reshape(lead + (N,)).astype(out_dtype)


def packed_to_absorbed(w: PackedW) -> tuple[jnp.ndarray, jnp.ndarray]:
    """PackedW -> (ints (K, N) int8, scales (K/64, N) f32) for the kernel.

    The 4-bit codes + 32-bit meta expand to the absorbed-shift integers of
    §III.B (micro-exponents become left shifts, |q| <= 28) without ever
    materializing the bf16 weight. The fused kernel performs exactly this
    expansion per VMEM tile; this host-level version exists as the
    materialized reference the fused path is tested bit-exact against.
    """
    return hif4.absorbed_int_km(*w.kernel_operands())
