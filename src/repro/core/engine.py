"""Execution engine: the single dispatch point for quantized matmuls.

``QuantConfig.impl`` selects how a quantized contraction actually executes;
every model-side linear layer funnels through :func:`matmul`, so the three
paths advertised by the config are now real dispatch instead of
documentation:

  qdq    — fake-quant the operands, matmul in bf16/f32. Lowers on any
           backend and is differentiable (STE); the training and accuracy-
           experiment path.
  packed — the weight is resident as a :class:`~repro.core.qlinear.PackedW`
           (HiF4 bit-packed buffers, 0.5625 bytes/value) and is dequantized
           group-wise inside the jitted graph; activations are quantized
           dynamically. The serving deployment path.
  pallas — the paper's §III.B fixed-point flow: ``hif4_quantize`` both
           operands (Algorithm 1 kernel), contract each 64-group on the MXU
           in int8 with a single f32 ``a_scale * b_scale`` rescale per
           group (``bfp_matmul_quantized``). Runs in interpret mode off-TPU.

Dispatch is **total**: a combination an impl cannot execute falls back to
the closest executable path instead of erroring, so model code never guards
call sites. The fallbacks (see docs/EXECUTION.md for the full matrix):

  * non-HiF4 formats on ``pallas``          -> qdq (kernels are HiF4-only)
  * ``weights_only`` on ``pallas``          -> qdq (the integer dot
                                               inherently quantizes both)
  * dense (unpacked) weight under ``packed``-> qdq (nothing resident to
                                               contract against)
  * PackedW under ``qdq``                   -> packed (a 4.5-bit buffer
                                               can only be dequantized)
  * contraction not a whole number of
    64-groups                               -> qdq

The engine context also carries the :class:`ShardCtx` that packed-weight
dequantization needs (gather the 4.5-bit payload, not the dequantized bf16
weight) — previously a module-level mutable (``_PACKED_SHARD``), now
threaded explicitly from the model context.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core.qlinear import (
    NO_QUANT,
    PackedW,
    QuantConfig,
    quantize_activation,
    quantize_weight,
)
# Imported at module scope deliberately: the kernel modules concretize
# bf16-rounded constants at import time, so a first import from inside a
# traced scan body would see tracers and fail.
from repro.kernels.bfp_matmul import bfp_matmul_quantized
from repro.kernels.hif4_quant import hif4_quantize
from repro.sharding.rules import NO_SHARD, ShardCtx


@dataclasses.dataclass(frozen=True)
class EngineCtx:
    """Everything a quantized contraction needs besides its operands."""

    quant: QuantConfig = NO_QUANT
    shard: ShardCtx = dataclasses.field(default_factory=lambda: NO_SHARD)
    # Pallas interpret mode: None = auto (interpret everywhere but TPU).
    interpret: Optional[bool] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret


DEFAULT_ENGINE = EngineCtx()


def matmul(
    x: jnp.ndarray,
    w,
    ectx: EngineCtx = DEFAULT_ENGINE,
    *,
    contract_x: int = -1,
    contract_w: int = 0,
    precision=None,
    accum_dtype=None,
) -> jnp.ndarray:
    """``x @ w`` through the configured execution path.

    ``w`` is a dense array or a :class:`PackedW`. ``accum_dtype`` is the dot
    OUTPUT dtype on the qdq/packed paths (default x.dtype; see qmatmul for
    the TP wire rationale); the pallas path always accumulates f32 in the
    kernel and casts once at the end.
    """
    cfg = ectx.quant
    if isinstance(w, PackedW):
        if cfg.impl == "pallas" and _pallas_activation_ok(cfg, x, contract_x):
            return _pallas_packed_matmul(x, w, ectx)
        return _packed_matmul(x, w, ectx, contract_x=contract_x,
                              accum_dtype=accum_dtype)
    if (
        cfg.enabled
        and cfg.impl == "pallas"
        and _pallas_activation_ok(cfg, x, contract_x)
        and _pallas_weight_ok(w, contract_w)
    ):
        return _pallas_dense_matmul(x, w, ectx)
    return _qdq_matmul(x, w, cfg, contract_x=contract_x, contract_w=contract_w,
                       precision=precision, accum_dtype=accum_dtype)


def qdq_einsum(eq: str, a: jnp.ndarray, w: jnp.ndarray, ectx: EngineCtx,
               *, a_axis: int = -1, w_axis: int = 1) -> jnp.ndarray:
    """Batched-contraction einsum (MoE expert matmuls) on the qdq path.

    Batched-expert weights have no packed/pallas dispatch yet (the (E, C)
    dispatch buffer re-tiles per step, so there is no static packed operand
    to contract against); they always execute fake-quant regardless of
    ``impl`` — documented in the docs/EXECUTION.md matrix.
    """
    cfg = ectx.quant
    if cfg.enabled:
        a = quantize_activation(a, cfg, axis=a_axis)
        w = quantize_weight(w, cfg, axis=w_axis)
    return jnp.einsum(eq, a, w)


# ---------------------------------------------------------------------------
# qdq path
# ---------------------------------------------------------------------------


def _qdq_matmul(x, w, cfg, *, contract_x, contract_w, precision, accum_dtype):
    out_dtype = x.dtype
    if cfg.enabled:
        x = quantize_activation(x, cfg, axis=contract_x)
        w = quantize_weight(w, cfg, axis=contract_w)
    cx = contract_x % x.ndim
    cw = contract_w % w.ndim
    y = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((cx,), (cw,)), ((), ())),
        precision=precision,
        preferred_element_type=accum_dtype or out_dtype,
    )
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# packed path: PackedW resident buffers, dequantized in-graph
# ---------------------------------------------------------------------------


def _packed_matmul(x, w: PackedW, ectx: EngineCtx, *, contract_x, accum_dtype):
    out_dtype = x.dtype
    wd = w.dequantize(shard=ectx.shard)                 # (K, N) dense
    x = quantize_activation(x, ectx.quant, axis=contract_x)
    cx = contract_x % x.ndim
    y = jax.lax.dot_general(
        x,
        wd,
        dimension_numbers=(((cx,), (0,)), ((), ())),
        preferred_element_type=accum_dtype or out_dtype,
    )
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# pallas path: Algorithm-1 quantize kernel + §III.B fixed-point matmul
# ---------------------------------------------------------------------------


def _pallas_activation_ok(cfg: QuantConfig, x, contract_x: int) -> bool:
    return (
        cfg.fmt == "hif4"
        and not cfg.weights_only
        and contract_x % x.ndim == x.ndim - 1
        and x.shape[-1] % hif4.GROUP_SIZE == 0
    )


def _pallas_weight_ok(w, contract_w: int) -> bool:
    return (
        w.ndim == 2
        and contract_w % w.ndim == 0
        and w.shape[0] % hif4.GROUP_SIZE == 0
    )


def _pallas_dense_matmul(x, w, ectx: EngineCtx):
    """Both operands quantized by the Algorithm-1 kernel each call (A-W
    dynamic quantization; the offline-weights variant is the packed path)."""
    interp = ectx.resolved_interpret()
    out_dtype = x.dtype
    lead, K = x.shape[:-1], x.shape[-1]
    N = w.shape[1]
    ai, asc = hif4_quantize(x.reshape(-1, K), interpret=interp)
    wi, wsc = hif4_quantize(w.T, interpret=interp)       # rows along K-groups
    y = bfp_matmul_quantized(ai, asc, wi.T, wsc.T, interpret=interp)
    return y.reshape(lead + (N,)).astype(out_dtype)


def packed_to_absorbed(w: PackedW) -> tuple[jnp.ndarray, jnp.ndarray]:
    """PackedW -> (ints (K, N) int8, scales (K/64, N) f32) for the kernel.

    The 4-bit codes + 32-bit meta expand to the absorbed-shift integers of
    §III.B (micro-exponents become left shifts, |q| <= 28) without ever
    materializing the bf16 weight — the pallas serving operand.
    """
    k, n = w.shape2d
    g = hif4.unpack_groups(hif4.HiF4Packed(w.codes, w.meta))
    ints, scale = hif4.to_absorbed_int(g)               # (n, k/64, 64), (n, k/64)
    return ints.reshape(n, k).T, scale.astype(jnp.float32).T


def _pallas_packed_matmul(x, w: PackedW, ectx: EngineCtx):
    """Fused serving path: dynamic activation quant (Algorithm 1 kernel) x
    packed resident weight, contracted by the fixed-point kernel."""
    interp = ectx.resolved_interpret()
    out_dtype = x.dtype
    k, n = w.shape2d
    lead = x.shape[:-1]
    assert x.shape[-1] == k, (x.shape, w.shape2d)
    ai, asc = hif4_quantize(x.reshape(-1, k), interpret=interp)
    wi, wsc = packed_to_absorbed(w)
    y = bfp_matmul_quantized(ai, asc, wi, wsc, interpret=interp)
    return y.reshape(lead + (n,)).astype(out_dtype)
