"""Format registry: the single entry point the rest of the framework uses.

``get_format(name)`` returns a :class:`BFPFormat` whose ``qdq(x, axis)`` maps
a tensor to its nearest representable tensor in that format (fake-quant) —
this is the "simulated 4-bit BFP" methodology of the paper's SS IV and it
lowers on every backend (CPU/TPU), which is what the multi-pod dry-run needs.
The packed/kernel paths live in ``repro.core.hif4`` / ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import hif4, mxfp4, nvfp4


@dataclasses.dataclass(frozen=True)
class BFPFormat:
    name: str
    group_size: int
    bits_per_value: float
    max_pos: float
    min_pos: float
    local_dynamic_range_binades: float
    qdq: Callable[..., jnp.ndarray]          # (x, axis=-1) -> x_hat
    needs_pts: bool = False


_REGISTRY: dict[str, BFPFormat] = {}


def _register(fmt: BFPFormat) -> BFPFormat:
    _REGISTRY[fmt.name] = fmt
    return fmt


HIF4 = _register(
    BFPFormat(
        name="hif4",
        group_size=hif4.GROUP_SIZE,
        bits_per_value=hif4.BITS_PER_VALUE,
        max_pos=hif4.MAX_POS,
        min_pos=hif4.MIN_POS,
        local_dynamic_range_binades=4.81,   # log2(7 / 0.25)
        qdq=hif4.qdq,
    )
)

NVFP4 = _register(
    BFPFormat(
        name="nvfp4",
        group_size=nvfp4.GROUP_SIZE,
        bits_per_value=nvfp4.BITS_PER_VALUE,
        max_pos=nvfp4.MAX_POS,
        min_pos=nvfp4.MIN_POS,
        local_dynamic_range_binades=3.58,   # log2(6 / 0.5)
        qdq=nvfp4.qdq,
    )
)

NVFP4_PTS = _register(
    BFPFormat(
        name="nvfp4_pts",
        group_size=nvfp4.GROUP_SIZE,
        bits_per_value=nvfp4.BITS_PER_VALUE,
        max_pos=nvfp4.MAX_POS,
        min_pos=nvfp4.MIN_POS,
        local_dynamic_range_binades=3.58,
        qdq=nvfp4.qdq_pts,
        needs_pts=True,
    )
)

MXFP4 = _register(
    BFPFormat(
        name="mxfp4",
        group_size=mxfp4.GROUP_SIZE,
        bits_per_value=mxfp4.BITS_PER_VALUE,
        max_pos=2.0 ** 127 * 6.0,
        min_pos=2.0 ** -127 * 0.5,
        local_dynamic_range_binades=3.58,
        qdq=mxfp4.qdq,
    )
)


def get_format(name: Optional[str]) -> Optional[BFPFormat]:
    """Look up a format; ``None``/"none"/"bf16" mean no quantization."""
    if name is None or name in ("none", "bf16"):
        return None
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown BFP format {name!r}; have {sorted(_REGISTRY)}")


def available_formats() -> list[str]:
    return sorted(_REGISTRY)
