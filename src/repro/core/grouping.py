"""Axis<->group reshaping shared by every BFP format.

All formats quantize along one tensor axis in fixed-size groups. This module
centralizes the move-axis / pad / reshape bookkeeping so format code only
ever sees (..., group_size) blocks.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def pad_to_multiple(x: jnp.ndarray, multiple: int, axis: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), n


def to_groups(x: jnp.ndarray, axis: int, group: int) -> tuple[jnp.ndarray, int]:
    """Return (y, orig_len): y has shape (..., n_groups, group) with the
    grouped axis moved last; pads with zeros if needed."""
    x = jnp.moveaxis(x, axis, -1)
    x, orig = pad_to_multiple(x, group, -1)
    y = x.reshape(x.shape[:-1] + (x.shape[-1] // group, group))
    return y, orig


def from_groups(y: jnp.ndarray, axis: int, orig_len: int) -> jnp.ndarray:
    x = y.reshape(y.shape[:-2] + (y.shape[-2] * y.shape[-1],))
    x = x[..., :orig_len]
    return jnp.moveaxis(x, -1, axis)


def apply_grouped(
    fn: Callable[[jnp.ndarray], jnp.ndarray],
    x: jnp.ndarray,
    axis: int,
    group: int,
) -> jnp.ndarray:
    """Apply ``fn`` on (..., group) blocks of ``x`` along ``axis``."""
    y, orig = to_groups(x, axis, group)
    out = fn(y)
    return from_groups(out, axis, orig).astype(x.dtype)
