"""HiFloat4 (HiF4) block floating-point format — the paper's contribution.

A HiF4 unit = 64 S1P2 elements + 32-bit metadata:
    [ E6M2 scale : 8b | E1_8 micro-exps : 8b | E1_16 micro-exps : 16b ]
Value of element i (1-based):
    V_i = E6M2 * 2^(E1_8[ceil(i/8)] + E1_16[ceil(i/4)]) * S1P2_i

This module implements Algorithm 1 (BF16 -> HiF4) with explicit bf16
emulation of every step the paper executes in bf16 hardware, plus
dequantization, bit-packing (4.5 bits/value storage), and the integer
"absorbed shift" representation used by the fixed-point dot product
(paper SS III.B).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rounding as R

GROUP_SIZE = 64
N_E1_8 = 8    # level-2 micro-exponents: one per 8 elements
N_E1_16 = 16  # level-3 micro-exponents: one per 4 elements
BITS_PER_VALUE = 4.5
# E6M2 code 0xFF decodes to NaN on every path (expand_meta_km below,
# rounding.decode_e6m2). Algorithm 1 NEVER produces it, so its presence in
# packed metadata is definitionally corruption — the health sentinel the
# serving guard (repro.runtime.guard) counts on packed KV pages.
META_NAN = 0xFF
MAX_POS = (2.0 ** 15 * 1.5) * 4.0 * 1.75   # = 2^18 * 1.3125  (Table II)
MIN_POS = 2.0 ** -48 * 0.25                # = 2^-50           (Table II)
INTRA_MAX = 7.0                            # 2^(1+1) * 1.75 (Alg. 1 line 8)

_RECIP7_BF16 = float(jnp.asarray(1.0 / 7.0, jnp.bfloat16))  # (1/7)_BF16


class HiF4Groups(NamedTuple):
    """Value-level (unpacked) HiF4 representation of shape (..., 64) data."""

    e6m2: jnp.ndarray    # (...,)     f32, value on the E6M2 grid
    e1_8: jnp.ndarray    # (..., 8)   int32 in {0, 1}
    e1_16: jnp.ndarray   # (..., 16)  int32 in {0, 1}
    s1p2: jnp.ndarray    # (..., 64)  f32, value on the S1P2 grid


class HiF4Packed(NamedTuple):
    """Bit-packed HiF4: 4.5 bits/value storage (deployment artifact)."""

    codes: jnp.ndarray   # (..., 32) uint8 — two 4-bit S1P2 codes per byte
    meta: jnp.ndarray    # (...,)    uint32 — e6m2<<24 | e1_8<<16 | e1_16


def quantize_groups(v: jnp.ndarray) -> HiF4Groups:
    """Algorithm 1: convert (..., 64) bf16/f32 values to HiF4 components.

    f32 inputs use the explicitly bf16-emulated path (every bf16 hardware
    rounding simulated with round_bf16). bf16 inputs take the NATIVE path:
    arithmetic runs in bf16 directly — bf16 multiplies round exactly like
    the simulated round_bf16(product), and every intermediate value on the
    S1P2/E6M2 grids is exactly bf16-representable, so the two paths agree
    BITWISE (property-tested) while the native one halves the HBM traffic
    of in-graph activation quantization.
    """
    if v.dtype == jnp.bfloat16:
        return _quantize_groups_bf16(v)
    v = v.astype(jnp.float32)
    av = jnp.abs(v)
    lead = v.shape[:-1]

    # Stage 1: three-level tree max reduction (lines 1-7).
    v16 = jnp.max(av.reshape(lead + (16, 4)), axis=-1)          # (..., 16)
    v8 = jnp.max(v16.reshape(lead + (8, 2)), axis=-1)           # (..., 8)
    vmax = jnp.max(v8, axis=-1)                                 # (...,)

    # Stage 2: hierarchical scaling metadata (lines 8-14).
    sf = R.round_bf16(R.round_bf16(vmax) * _RECIP7_BF16)        # line 8
    e6m2 = R.round_e6m2(sf)                                     # line 9
    rec = R.e6m2_reciprocal_bf16(e6m2)                          # line 10
    e1_8 = (R.round_bf16(v8 * rec[..., None]) > 4.0)            # line 11
    e1_8 = e1_8.astype(jnp.int32)
    shift2 = jnp.repeat(e1_8, 2, axis=-1)                       # (..., 16)
    t16 = R.round_bf16(v16 * rec[..., None]) * jnp.ldexp(jnp.float32(1.0), -shift2)
    e1_16 = (t16 >= 2.0).astype(jnp.int32)                      # line 13

    # Stage 3: scale and round the 64 elements (lines 15-18).
    shift8 = jnp.repeat(e1_8, 8, axis=-1)                       # (..., 64)
    shift4 = jnp.repeat(e1_16, 4, axis=-1)                      # (..., 64)
    scaled = R.round_bf16(v * rec[..., None]) * jnp.ldexp(
        jnp.float32(1.0), -(shift8 + shift4)
    )
    s1p2 = R.quantize_s1p2(scaled)                              # line 18
    return HiF4Groups(e6m2=e6m2, e1_8=e1_8, e1_16=e1_16, s1p2=s1p2)


def _quantize_groups_bf16(v: jnp.ndarray) -> HiF4Groups:
    """Native-bf16 Algorithm 1 (the big (..., 64) buffers never touch f32).

    Per-group metadata (1/64 of the data) still routes through f32 for the
    E6M2 grid arithmetic — that part is cheap.
    """
    bf = jnp.bfloat16
    av = jnp.abs(v)
    lead = v.shape[:-1]
    v16 = jnp.max(av.reshape(lead + (16, 4)), axis=-1)          # bf16, exact
    v8 = jnp.max(v16.reshape(lead + (8, 2)), axis=-1)
    vmax = jnp.max(v8, axis=-1)

    sf = vmax * bf(_RECIP7_BF16)                                # bf16 RNE = line 8
    e6m2 = R.round_e6m2(sf.astype(jnp.float32))                 # small, f32
    rec_f32 = R.e6m2_reciprocal_bf16(e6m2)
    rec = rec_f32.astype(bf)                                    # exactly bf16

    e1_8 = ((v8 * rec[..., None]) > bf(4.0)).astype(jnp.int32)  # line 11
    shift2 = jnp.repeat(e1_8, 2, axis=-1)
    t16 = (v16 * rec[..., None]) * jnp.exp2(-shift2).astype(bf)
    e1_16 = (t16 >= bf(2.0)).astype(jnp.int32)                  # line 13

    shift8 = jnp.repeat(e1_8, 8, axis=-1)
    shift4 = jnp.repeat(e1_16, 4, axis=-1)
    scaled = (v * rec[..., None]) * jnp.exp2(-(shift8 + shift4)).astype(bf)
    # S1P2 rounding: x4, RNE to int in [-7, 7], /4 — all exact in bf16
    q = jnp.clip(jnp.round(scaled * bf(4.0)), -7.0, 7.0)
    s1p2 = q * bf(0.25)                                         # stays bf16
    return HiF4Groups(e6m2=e6m2, e1_8=e1_8, e1_16=e1_16, s1p2=s1p2)


def dequantize_groups(g: HiF4Groups) -> jnp.ndarray:
    """Equation 2: reconstruct (..., 64) values.

    Computes in the s1p2 dtype: the product E6M2 * 2^shift * S1P2 carries
    at most 2+3 significant bits, so it is EXACT in bf16 as well as f32 —
    the native-bf16 path keeps the big buffers bf16 end to end.
    """
    dt = g.s1p2.dtype
    shift = jnp.repeat(g.e1_8, 8, axis=-1) + jnp.repeat(g.e1_16, 4, axis=-1)
    scale = g.e6m2.astype(dt)[..., None] * jnp.exp2(shift).astype(dt)
    return scale * g.s1p2


def meta_nan_mask(meta: jnp.ndarray) -> jnp.ndarray:
    """Elementwise True where a packed meta word carries the E6M2 NaN
    sentinel (scale byte == :data:`META_NAN`). Any True is corruption:
    Algorithm 1 never emits 0xFF, and every decode path turns it into NaN
    (:func:`expand_meta_km`), so this mask is the cheap integrity probe
    health audits reduce over."""
    return (meta >> 24) == jnp.uint32(META_NAN)


# ---------------------------------------------------------------------------
# Fixed-point ("absorbed shift") view — paper SS III.B
# ---------------------------------------------------------------------------


def to_absorbed_int(g: HiF4Groups) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Absorb micro-exponents into integer elements (S2P2-and-wider view).

    Returns ``(ints, scale)`` where ``ints`` is (..., 64) int8 holding
    S1P2-quarters shifted left by (E1_8 + E1_16) — |q| <= 7*4 = 28 — and
    ``scale`` is (...,) f32 = E6M2 / 4 (the 1/4 is the quarter-LSB of
    S1P2). Reconstruction ``scale * ints`` and the dot product
    ``scale_A*scale_B*sum(intA*intB)`` are *exact* (verified in tests).
    """
    quarters = R.s1p2_to_int(g.s1p2).astype(jnp.int32)
    shift = jnp.repeat(g.e1_8, 8, axis=-1) + jnp.repeat(g.e1_16, 4, axis=-1)
    ints = (quarters << shift).astype(jnp.int8)
    scale = g.e6m2 * 0.25  # each operand contributes sqrt(1/16) = 1/4
    return ints, scale


# ---------------------------------------------------------------------------
# K-major ("kernel-tile") bit-layout helpers — usable from inside a kernel
# ---------------------------------------------------------------------------
#
# The packed artifact stores a weight output-major: codes (N, K/64, 32),
# meta (N, K/64) (see docs/FORMATS.md).  A matmul kernel consumes the
# CONTRACTION axis innermost, so the serving re-layout transposes the
# payload once into K-major 2-D buffers
#
#     codes_km (K/2, N) uint8    row k2 holds elements 2*k2 (low nibble)
#                                and 2*k2+1 (high nibble) of column n
#     meta_km  (K/64, N) uint32  one group record per 64 contraction rows
#
# and the helpers below expand a (bk/2, bn) / (bk/64, bn) VMEM tile of
# those buffers to the absorbed-shift int8 operand of paper §III.B.  They
# are pure jnp on whatever tile they are given — the same code runs inside
# a Pallas kernel on VMEM refs and in the XLA twin of the fused matmul.


def expand_codes_km(codes_km: jnp.ndarray) -> jnp.ndarray:
    """(bk/2, bn) uint8 K-major code bytes -> (bk, bn) int32 S1P2 quarters.

    Low nibble is the even contraction row, high nibble the odd one; the
    4-bit code is sign<<3 | quarters (rounding.encode_s1p2)."""
    lo = (codes_km & 0xF).astype(jnp.int32)
    hi = (codes_km >> 4).astype(jnp.int32)
    half, bn = codes_km.shape
    c4 = jnp.stack([lo, hi], axis=1).reshape(half * 2, bn)
    mag = c4 & 0x7
    return jnp.where((c4 >> 3) & 1, -mag, mag)


def expand_meta_km(meta_km: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(bg, bn) uint32 K-major group metadata -> (shift, scale).

    ``shift`` (bg*64, bn) int32 is the per-element micro-exponent sum
    E1_8 + E1_16; ``scale`` (bg, bn) f32 is the absorbed group scale
    E6M2 / 4 (bitwise identical to ``decode_e6m2(meta>>24) * 0.25`` but
    written with exp2 on the small per-group tile only, no LUT)."""
    bg, bn = meta_km.shape
    w8 = meta_km >> 16                       # E1_8 bits in 23..16
    w16 = meta_km                            # E1_16 bits in 15..0
    r = jnp.arange(GROUP_SIZE, dtype=jnp.uint32)
    s8 = ((w8[:, None, :] >> (r[None, :, None] // 8)) & 1).astype(jnp.int32)
    s4 = ((w16[:, None, :] >> (r[None, :, None] // 4)) & 1).astype(jnp.int32)
    shift = (s8 + s4).reshape(bg * GROUP_SIZE, bn)
    code = meta_km >> 24
    # 2^eb built by exponent-field bitcast: jnp.exp2 is a polynomial
    # approximation that is NOT exact across the E6M2 range (observed
    # exp2(15) != 32768 on CPU), and the scale must stay on the exact
    # power-of-two grid. eb in [-48, 15] is always a normal f32.
    eb = (code >> 2).astype(jnp.int32) - 48
    pow2 = jax.lax.bitcast_convert_type(
        ((eb + 127) << 23).astype(jnp.uint32), jnp.float32)
    m2 = (code & 0x3).astype(jnp.float32)
    scale = pow2 * (1.0 + m2 * 0.25) * 0.25
    # E6M2 0xFF is NaN (never produced by Algorithm 1, but corrupted bits
    # must decode identically on every path — decode_e6m2 parity)
    scale = jnp.where(code == 0xFF, jnp.nan, scale)
    return shift, scale


def absorbed_int_km(codes_km: jnp.ndarray, meta_km: jnp.ndarray):
    """K-major packed tile -> (ints (bk, bn) int8, scale (bk/64, bn) f32).

    The §III.B absorbed-shift operand (micro-exponents folded in as left
    shifts, |q| <= 28), produced directly from the 4.5-bit payload without
    materializing values: bitwise identical to
    ``to_absorbed_int(unpack_groups(...))`` re-laid out K-major."""
    quarters = expand_codes_km(codes_km)
    shift, scale = expand_meta_km(meta_km)
    return (quarters << shift).astype(jnp.int8), scale


def dequantize_km(codes_km: jnp.ndarray, meta_km: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """K-major packed buffers -> (K, N) dense values.

    ``scale * ints`` carries <= 6 significant bits, so the reconstruction
    is exact in bf16 as well as f32 — and unlike the output-major
    dequantize it needs no final (N, K) -> (K, N) transpose and no
    per-element exp2 (shifts are integer left-shifts)."""
    ints, scale = absorbed_int_km(codes_km, meta_km)
    scale_k = jnp.repeat(scale, GROUP_SIZE, axis=0)
    return (scale_k * ints.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Bit packing (storage at 4.5 bits/value)
# ---------------------------------------------------------------------------


def pack_groups(g: HiF4Groups) -> HiF4Packed:
    codes4 = R.encode_s1p2(g.s1p2)                               # (..., 64) uint8
    lo = codes4[..., 0::2]
    hi = codes4[..., 1::2]
    codes = (lo | (hi << 4)).astype(jnp.uint8)                   # (..., 32)

    e6_bits = R.encode_e6m2(g.e6m2).astype(jnp.uint32)           # (...,)
    w8 = jnp.sum(
        g.e1_8.astype(jnp.uint32) << jnp.arange(N_E1_8, dtype=jnp.uint32), axis=-1
    )
    w16 = jnp.sum(
        g.e1_16.astype(jnp.uint32) << jnp.arange(N_E1_16, dtype=jnp.uint32), axis=-1
    )
    meta = (e6_bits << 24) | (w8 << 16) | w16
    return HiF4Packed(codes=codes, meta=meta)


def quantize_packed(v: jnp.ndarray) -> HiF4Packed:
    """Algorithm 1 + bit packing in one step: (..., 64) values -> 4.5-bit
    storage. This is the unit every packed artifact is built from — weights
    (:class:`repro.core.qlinear.PackedW`) and the KV cache
    (:mod:`repro.core.kvcache`) share it, so their bits always agree with
    the QDQ grid (see docs/FORMATS.md for the layout)."""
    return pack_groups(quantize_groups(v))


def dequantize_packed(p: HiF4Packed) -> jnp.ndarray:
    """Inverse of :func:`quantize_packed` up to the value grid: unpack the
    bits and reconstruct the (..., 64) values (exact, also in bf16)."""
    return dequantize_groups(unpack_groups(p))


def unpack_groups(p: HiF4Packed) -> HiF4Groups:
    lo = p.codes & 0xF
    hi = p.codes >> 4
    codes4 = jnp.stack([lo, hi], axis=-1).reshape(p.codes.shape[:-1] + (GROUP_SIZE,))
    s1p2 = R.decode_s1p2(codes4)

    e6m2 = R.decode_e6m2((p.meta >> 24).astype(jnp.uint8))
    w8 = (p.meta >> 16) & 0xFF
    w16 = p.meta & 0xFFFF
    e1_8 = ((w8[..., None] >> jnp.arange(N_E1_8, dtype=jnp.uint32)) & 1).astype(jnp.int32)
    e1_16 = ((w16[..., None] >> jnp.arange(N_E1_16, dtype=jnp.uint32)) & 1).astype(
        jnp.int32
    )
    return HiF4Groups(e6m2=e6m2, e1_8=e1_8, e1_16=e1_16, s1p2=s1p2)


# ---------------------------------------------------------------------------
# Tensor-level QDQ entry point (axis -> groups of 64)
# ---------------------------------------------------------------------------


def qdq(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Quantize-dequantize ("fake quant") along ``axis`` in groups of 64."""
    from repro.core.grouping import apply_grouped  # local import, no cycle

    return apply_grouped(
        lambda v: dequantize_groups(quantize_groups(v)), x, axis, GROUP_SIZE
    )
