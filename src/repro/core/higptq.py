"""HiGPTQ: GPTQ tailored to the HiF4 block floating-point structure (§IV-A).

Vanilla GPTQ quantizes a weight matrix one contraction-index at a time,
compensating the not-yet-quantized rows through the inverse Hessian of the
layer's calibration activations. The HiF4 adaptations ("minor changes" per
the paper):

  * the quantization grid is HiF4's: at each 64-row group boundary the
    three-level scaling metadata (E6M2 + micro-exponents) is derived from
    the CURRENT error-compensated weights of that group, then frozen;
  * within the group, each row is rounded onto its element's effective
    grid quantum = E6M2 * 2^(E1_8 + E1_16) * 0.25, clamped at +-7 quanta
    (the S1P2 bound), with the rounding error propagated GPTQ-style.

Orientation: W is (K, N) with K the contraction dim (HiF4 groups along K,
matching how a 64-length PE dot consumes the data); X is (n_samples, K).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core import rounding as R
from repro.core.metrics import rel_output_error

GROUP = hif4.GROUP_SIZE


def hessian_from_activations(x: jnp.ndarray, damp: float = 0.01) -> jnp.ndarray:
    """H = X^T X / n + damp * mean(diag) * I  (f64-free, f32)."""
    x = x.astype(jnp.float32)
    h = x.T @ x / x.shape[0]
    d = jnp.mean(jnp.diag(h))
    return h + damp * jnp.maximum(d, 1e-8) * jnp.eye(h.shape[0], dtype=jnp.float32)


def _group_grid(wg: jnp.ndarray):
    """HiF4 metadata for one group. wg (64, N) -> quantum (64, N) f32.

    Reuses Algorithm 1's scale derivation (stages 1-2) on the transposed
    group so the grid is bit-identical to direct-cast HiF4.
    """
    g = hif4.quantize_groups(wg.T.astype(jnp.float32))   # (N, 64) groups
    shift = jnp.repeat(g.e1_8, 8, axis=-1) + jnp.repeat(g.e1_16, 4, axis=-1)
    quantum = g.e6m2[:, None] * jnp.exp2(shift.astype(jnp.float32)) * R.S1P2_STEP
    return quantum.T                                      # (64, N)


def _quantize_row(w_row: jnp.ndarray, quantum: jnp.ndarray) -> jnp.ndarray:
    q = jnp.round(w_row / quantum)
    return jnp.clip(q, -7.0, 7.0) * quantum


def higptq_quantize(
    w: jnp.ndarray,           # (K, N) weight, contraction-major
    x_calib: jnp.ndarray,     # (n_samples, K) calibration activations
    *,
    damp: float = 0.01,
) -> jnp.ndarray:
    """GPTQ-compensated HiF4 weights (same dtype/shape as ``w``)."""
    K, N = w.shape
    assert K % GROUP == 0, f"contraction dim {K} not a multiple of {GROUP}"
    wq = w.astype(jnp.float32)

    h = hessian_from_activations(x_calib, damp)
    # GPTQ uses the upper Cholesky factor of H^-1
    hinv = jnp.linalg.inv(h)
    u = jnp.linalg.cholesky(hinv, upper=True)             # (K, K), upper

    out = jnp.zeros_like(wq)
    for k0 in range(0, K, GROUP):
        grid = _group_grid(jax.lax.dynamic_slice_in_dim(wq, k0, GROUP, 0))

        def row_step(i, carry):
            wq_c, out_c = carry
            k = k0 + i
            w_row = jax.lax.dynamic_slice_in_dim(wq_c, k, 1, 0)[0]
            quant = jax.lax.dynamic_slice_in_dim(grid, i, 1, 0)[0]
            q_row = _quantize_row(w_row, quant)
            d = u[k, k]
            err = (w_row - q_row) / d
            # compensate all later rows: w[j] -= U[k, j] * err  (j > k)
            col = jnp.where(jnp.arange(K) > k, u[k, :], 0.0)
            wq_c = wq_c - col[:, None] * err[None, :]
            out_c = jax.lax.dynamic_update_slice_in_dim(
                out_c, q_row[None, :], k, 0
            )
            return wq_c, out_c

        wq, out = jax.lax.fori_loop(0, GROUP, row_step, (wq, out))
    return out.astype(w.dtype)


def quantize_stacked(
    w_stacked: jnp.ndarray,   # (L, K, ...) stacked block weight
    x_layers,                 # per-layer calib: (L, n, K) or [x_l (n, K)]
    *,
    n_samples: int = 512,
    damp: float = 0.01,
) -> jnp.ndarray:
    """HiGPTQ over a stacked block weight, one layer at a time with that
    layer's own calibration rows. Shared by the Tables III-V proxy
    (``benchmarks/llm_accuracy.py``) and the calibration probe
    (``repro.calibrate.probe``) so the per-layer flatten/round/restack
    dance exists once. Trailing output dims are flattened to N and
    restored."""
    L = w_stacked.shape[0]
    out = []
    for i in range(L):
        w_l = w_stacked[i]
        shape = w_l.shape
        w2 = jnp.asarray(w_l, jnp.float32).reshape(shape[0], -1)
        x_l = jnp.asarray(x_layers[i][:n_samples])
        out.append(higptq_quantize(w2, x_l, damp=damp)
                   .reshape(shape).astype(w_stacked.dtype))
    return jnp.stack(out)


def layer_output_error(w_ref: jnp.ndarray, w_q: jnp.ndarray,
                       x: jnp.ndarray) -> float:
    """||X (W - W_q)||_F / ||X W||_F — the metric GPTQ minimizes (shared
    spelling: ``repro.core.metrics.rel_output_error``)."""
    return rel_output_error(w_ref, w_q, x)
