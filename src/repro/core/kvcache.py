"""HiF4-packed KV cache: the paper's 64-element groups applied to K/V.

The KV cache is the dominant memory consumer at serving scale (weights are
amortized across slots; cache bytes grow with slots x capacity x layers).
This module stores each cached token's K (and V) vector in the HiF4 packed
layout so the resident bytes drop from 2 B/value (bf16) to 0.5625 B/value —
~3.56x more continuous-batching slots per device for the same HBM.

Two layouts carry the same bits (see docs/FORMATS.md for the bit layout);
with token features F = n_kv_heads * d_head flattened per token,
G = F // 64 whole HiF4 groups and T = F % 64 tail features:

* artifact (token-major — what :func:`quantize_kv` writes, the natural
  shape for per-token appends and interchange):

      codes (..., S, G, 32) uint8    two 4-bit S1P2 codes per byte
      meta  (..., S, G)     uint32   E6M2<<24 | E1_8<<16 | E1_16
      tail  (..., S, T)     bf16     partial-group staging buffer

* kernel-tile (feature-major — what the fused decode-attention kernel
  tiles over, :func:`to_kernel_layout`; the resident serving layout):

      codes (..., G*32, S) uint8     row f holds features 2f (low nibble)
                                     and 2f+1 (high nibble) of each token
      meta  (..., G, S)     uint32   one group record per 64 feature rows
      tail  (..., T, S)     bf16

  A (features, kv-tile) VMEM block of the kernel-tile buffers is exactly
  the K-major operand of :func:`repro.core.hif4.dequantize_km`, so the
  kernel expands 4.5-bit tiles to bf16 K/V columns inside VMEM with the
  same bit helpers the fused matmul uses. The two layouts are pure bit
  moves of each other (:func:`is_kernel_layout` discriminates by rank:
  artifact codes carry one trailing 32-byte axis, kernel-tile codes do
  not).

Grouping is **per token along the flattened head axis** — never across
tokens — so appending one decoded token re-quantizes nothing: each append
writes exactly its own G groups + T tail features. That independence is
what makes continuous-batching serving bit-identical to solo serving (a
token's packed bits depend only on its own K/V vector, not on its slot,
neighbours, or cache capacity). Features that do not fill a whole 64-group
stay bf16 in the ``tail`` staging buffer (exact, 2 B/value) instead of
forcing a padded, mostly-empty group whose metadata would be garbage.

Dequantize-on-read is exact in bf16 (the HiF4 reconstruction product
carries <= 6 significant bits; see :func:`repro.core.hif4.dequantize_groups`),
so a packed cache decodes exactly like a bf16 cache holding the quantized
values.

Paged pool
----------

On top of the contiguous kernel-tile cache this module provides a PAGED
pool (docs/FORMATS.md "Paged KV-cache pool"): pages are ``page_tokens``-
wide blocks of the token axis of the kernel-tile layout, so one page is a
self-contained run of packed 64-groups + meta + tail columns for
``page_tokens`` tokens of one sequence, across all layers:

    codes (L, n_pages, G*32, P) uint8
    meta  (L, n_pages, G,    P) uint32
    tail  (L, n_pages, T,    P) bf16

Per-token grouping means a page's bytes depend only on its own tokens'
K/V vectors — two sequences with the same token prefix produce the SAME
page bytes, which is what makes copy-on-write prefix sharing exact
(shared prefixes are shared bytes, verified byte-for-byte at share time).
Device-side helpers (:func:`init_page_pool`, :func:`split_pages`,
:func:`gather_pages`, :func:`scatter_pages`, :func:`copy_page`,
:func:`append_token_paged`) are pure jit-safe array ops; the host-side
:class:`PagePool` tracks allocation, refcounts, the full-page token-hash
index, the partial-tail registry, and the LRU cache of retired prefix
pages. Page id 0 is RESERVED as a scratch page: retired decode slots keep
a zero page table, so their (masked, never read) appends land in page 0
instead of corrupting reallocated pages.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hif4

KV_FORMATS = ("bf16", "hif4")


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """How the decode KV cache is stored.

    kv_format: 'bf16' (dense cache, 2 B/value) | 'hif4' (packed cache,
    4.5 bits/value + bf16 tail). Frozen/hashable so it can ride on
    :class:`repro.core.qlinear.QuantConfig` into jit cache keys.
    """

    kv_format: str = "bf16"

    def __post_init__(self):
        assert self.kv_format in KV_FORMATS, self.kv_format

    @property
    def packed(self) -> bool:
        return self.kv_format == "hif4"


KV_BF16 = KVCacheConfig("bf16")
KV_HIF4 = KVCacheConfig("hif4")


def split_features(n_kv_heads: int, d_head: int) -> tuple[int, int]:
    """(whole 64-groups, bf16 tail features) per token."""
    return divmod(n_kv_heads * d_head, hif4.GROUP_SIZE)


def kv_bytes_per_token(n_kv_heads: int, d_head: int,
                       kv_format: str = "bf16") -> int:
    """Resident cache bytes per token PER LAYER (K and V together)."""
    f = n_kv_heads * d_head
    if kv_format == "hif4":
        g, t = divmod(f, hif4.GROUP_SIZE)
        per_tensor = g * (32 + 4) + t * 2      # codes + meta, bf16 tail
    else:
        per_tensor = f * 2
    return 2 * per_tensor                      # K + V


def is_packed_kv(cache) -> bool:
    """True for the packed per-tensor dict {"codes","meta","tail"}."""
    return isinstance(cache, dict) and "codes" in cache


def is_kernel_layout(pk: dict) -> bool:
    """True when ``pk`` is in the feature-major kernel-tile layout.

    Artifact codes carry one trailing 32-byte axis beyond meta's rank
    ((..., S, G, 32) vs (..., S, G)); kernel-tile codes and meta have the
    same rank ((..., G*32, S) and (..., G, S)). Rank, not shape values,
    so the discriminator is static under jit/vmap/scan.
    """
    return pk["codes"].ndim == pk["meta"].ndim


def to_kernel_layout(pk: dict) -> dict:
    """Artifact leaves -> kernel-tile leaves (a pure bit move, idempotent).

    codes (..., S, G, 32) -> (..., G*32, S); meta (..., S, G) ->
    (..., G, S); tail (..., S, T) -> (..., T, S). The nibble pairing is
    unchanged: artifact byte (g, b) holds features g*64 + 2b / g*64 + 2b+1,
    which lands on kernel-tile row g*32 + b — exactly the K-major code row
    convention of :func:`repro.core.hif4.expand_codes_km`.
    """
    if is_kernel_layout(pk):
        return pk
    codes = pk["codes"]
    lead, s, g = codes.shape[:-3], codes.shape[-3], codes.shape[-2]
    return {
        "codes": jnp.swapaxes(codes.reshape(lead + (s, g * 32)), -1, -2),
        "meta": jnp.swapaxes(pk["meta"], -1, -2),
        "tail": jnp.swapaxes(pk["tail"], -1, -2),
    }


def seq_capacity(pk: dict) -> int:
    """Token capacity S of a packed tensor, in either layout."""
    if is_kernel_layout(pk):
        return pk["meta"].shape[-1]
    return pk["meta"].shape[-2]


def slice_tokens(pk: dict, start, count: int) -> dict:
    """Take ``count`` token slots beginning at ``start`` (same layout).

    ``start`` may be a traced index (tile loaders inside a scan); shapes
    stay static. Token slots are independent (per-token grouping), so
    slicing commutes bitwise with quantize/dequantize.
    """
    def sl(a, axis):
        return jax.lax.dynamic_slice_in_dim(a, start, count, axis=axis)

    if is_kernel_layout(pk):
        return {key: sl(a, a.ndim - 1) for key, a in pk.items()}
    return {
        "codes": sl(pk["codes"], pk["codes"].ndim - 3),
        "meta": sl(pk["meta"], pk["meta"].ndim - 2),
        "tail": sl(pk["tail"], pk["tail"].ndim - 2),
    }


def pad_tokens(pk: dict, capacity: int) -> dict:
    """Zero-pad the token axis to ``capacity`` slots (either layout).

    Zero padding of packed leaves is inert under the length mask — zero
    codes/meta decode to values that masked positions never read.
    """
    def pad(a, axis):
        if a.shape[axis] >= capacity:
            return a
        pads = [(0, 0)] * a.ndim
        pads[axis] = (0, capacity - a.shape[axis])
        return jnp.pad(a, pads)

    if is_kernel_layout(pk):
        return {key: pad(a, a.ndim - 1) for key, a in pk.items()}
    return {
        "codes": pad(pk["codes"], pk["codes"].ndim - 3),
        "meta": pad(pk["meta"], pk["meta"].ndim - 2),
        "tail": pad(pk["tail"], pk["tail"].ndim - 2),
    }


# ---------------------------------------------------------------------------
# Quantize / dequantize (leading dims arbitrary: works per token, per
# sequence, and on (L, B, S, ...) stacked whole caches alike)
# ---------------------------------------------------------------------------


def quantize_kv(kv: jnp.ndarray) -> dict:
    """(..., Hkv, Dh) K or V values -> packed leaves {codes, meta, tail}.

    Each trailing (Hkv, Dh) vector is flattened and cut into 64-element
    HiF4 groups; the F % 64 remainder stays bf16 in ``tail``. Group bits
    depend only on the vector itself (Algorithm 1 is per-group), so
    quantizing token-by-token equals quantizing the whole sequence.
    """
    lead = kv.shape[:-2]
    f = kv.shape[-2] * kv.shape[-1]
    g, t = divmod(f, hif4.GROUP_SIZE)
    flat = kv.reshape(lead + (f,))
    body = flat[..., : g * hif4.GROUP_SIZE].reshape(
        lead + (g, hif4.GROUP_SIZE)
    )
    packed = hif4.quantize_packed(body.astype(jnp.bfloat16))
    return {
        "codes": packed.codes,
        "meta": packed.meta,
        "tail": flat[..., g * hif4.GROUP_SIZE :].astype(jnp.bfloat16),
    }


def dequantize_kv(pk: dict, n_kv_heads: int, d_head: int) -> jnp.ndarray:
    """Packed leaves (either layout) -> (..., S, Hkv, Dh) bf16 values.

    ONE shared codes->values decode for the whole KV path: the leaves are
    viewed K-major (a bit move for the artifact layout, free for the
    kernel-tile layout) and expanded by
    :func:`repro.core.hif4.dequantize_km` — the same bit helper the fused
    kernels tile over, with the exact power-of-two scale construction and
    E6M2 0xFF NaN parity tested once in ``tests/test_fused_matmul.py``.
    Reconstruction is exact in bf16 (<= 6 significant bits); the tail
    returns bit-identical.
    """
    pk = to_kernel_layout(pk)
    codes, meta, tail = pk["codes"], pk["meta"], pk["tail"]
    lead = codes.shape[:-2]
    n = math.prod(lead)
    s = codes.shape[-1]
    body = jax.vmap(hif4.dequantize_km)(
        codes.reshape((n,) + codes.shape[-2:]),
        meta.reshape((n,) + meta.shape[-2:]),
    )                                                     # (N, G*64, S) bf16
    flat = jnp.concatenate(
        [body, tail.reshape((n,) + tail.shape[-2:]).astype(jnp.bfloat16)],
        axis=-2,
    )                                                     # (N, F, S)
    flat = jnp.swapaxes(flat, -1, -2)                     # (N, S, F)
    return flat.reshape(lead + (s, n_kv_heads, d_head))


# ---------------------------------------------------------------------------
# Append-one-token (the decode hot path)
# ---------------------------------------------------------------------------


def append_token(pcache: dict, kv_new: jnp.ndarray, pos: jnp.ndarray) -> dict:
    """Quantize kv_new (B, 1, Hkv, Dh) and write it at sequence slot ``pos``.

    ``pos`` is a scalar (whole batch in lockstep) or (B,) per-slot offsets
    (continuous batching: a freshly admitted request sits at its prompt
    length while its slot neighbours are deep into decode). Cache leaves
    are (B, S, ...) artifact or (B, ..., S) kernel-tile; the token's bytes
    are written in the cache's own layout (one column per token in kernel
    order), so bulk packing + re-layout stays bitwise identical to
    token-at-a-time appends. Only the G + tail bytes of the one token are
    written.
    """
    new = quantize_kv(kv_new)
    per_slot = jnp.ndim(pos) == 1
    if is_kernel_layout(pcache):
        new = to_kernel_layout(new)            # (B, F/2, 1) / (B, G, 1) / ...
        # lockstep (scalar) pos takes the same per-batch write as per-slot
        # pos: one column per batch row. Writing the (B, ..., 1) slab in a
        # single batched dynamic_update_slice was measured ~6x slower on
        # CPU (XLA copies the whole buffer); the result is identical.
        posv = pos if per_slot else jnp.full(
            (new["meta"].shape[0],), pos, jnp.int32)

        def write(full, one):
            return jax.vmap(
                lambda c, n, p: jax.lax.dynamic_update_slice(
                    c, n.astype(c.dtype), (0,) * (c.ndim - 1) + (p,)
                )
            )(full, one, posv)
    else:

        def write(full, one):
            if per_slot:
                return jax.vmap(
                    lambda c, n, p: jax.lax.dynamic_update_slice(
                        c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1)
                    )
                )(full, one, pos)
            idx = (0, pos) + (0,) * (full.ndim - 2)
            return jax.lax.dynamic_update_slice(full, one.astype(full.dtype), idx)

    return {key: write(pcache[key], new[key]) for key in ("codes", "meta", "tail")}


# ---------------------------------------------------------------------------
# Paged pool: device-side helpers (pure array ops, jit-safe)
# ---------------------------------------------------------------------------

DEFAULT_PAGE_TOKENS = 64


def pages_for_tokens(n_tokens: int, page_tokens: int) -> int:
    """Pages needed to hold ``n_tokens`` token columns."""
    return -(-n_tokens // page_tokens)


def page_nbytes(n_kv_heads: int, d_head: int, page_tokens: int,
                n_layers: int) -> int:
    """Resident bytes of ONE pool page (K + V, all layers)."""
    return n_layers * page_tokens * kv_bytes_per_token(
        n_kv_heads, d_head, "hif4")


def init_page_pool(n_layers: int, n_kv_heads: int, d_head: int,
                   n_pages: int, page_tokens: int) -> dict:
    """Zero-initialized fixed-size page pool {"k","v"} of packed leaves.

    Leaves are kernel-tile blocks with a leading page axis:
    codes (L, n_pages, G*32, P), meta (L, n_pages, G, P),
    tail (L, n_pages, T, P). Page 0 is the reserved scratch page
    (:class:`PagePool` never allocates it); zero pages decode to zeros,
    which masked positions never read.
    """
    g, t = split_features(n_kv_heads, d_head)

    def leaves():
        return {
            "codes": jnp.zeros((n_layers, n_pages, g * 32, page_tokens),
                               jnp.uint8),
            "meta": jnp.zeros((n_layers, n_pages, g, page_tokens),
                              jnp.uint32),
            "tail": jnp.zeros((n_layers, n_pages, t, page_tokens),
                              jnp.bfloat16),
        }

    return {"k": leaves(), "v": leaves()}


def pool_page_tokens(pool_t: dict) -> int:
    """Tokens per page P of pool leaves (any leading axes, tokens last)."""
    return pool_t["meta"].shape[-1]


def pool_n_pages(pool_t: dict) -> int:
    """Total pages in a (L, n_pages, ..., P) pool tensor."""
    return pool_t["meta"].shape[1]


def split_pages(pk: dict, page_tokens: int) -> dict:
    """Contiguous kernel-layout leaves (L, 1, F, S) -> pages (L, n, F, P).

    The single-sequence packed cache a prefill produces, cut into
    page-pool blocks (token axis padded to a page multiple with zeros —
    inert under the length mask). A pure bit move: page j holds exactly
    token columns [j*P, (j+1)*P).
    """
    pk = to_kernel_layout(pk)

    def cut(a):
        l, b, f, s = a.shape
        assert b == 1, "split_pages takes a single-sequence (B=1) cache"
        n = pages_for_tokens(s, page_tokens)
        pad = n * page_tokens - s
        a = a[:, 0]
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        return jnp.moveaxis(
            a.reshape(l, f, n, page_tokens), 2, 1)       # (L, n, F, P)

    return {key: cut(pk[key]) for key in ("codes", "meta", "tail")}


def gather_pages(pool_t: dict, page_ids: jnp.ndarray) -> dict:
    """Pool leaves (L, NP, F, P) -> the selected pages (L, n, F, P)."""
    return {key: jnp.take(a, page_ids, axis=1)
            for key, a in pool_t.items()}


def scatter_pages(pool_t: dict, pages: dict, page_ids: jnp.ndarray) -> dict:
    """Write page blocks (L, n, F, P) into the pool at ``page_ids``."""
    return {key: pool_t[key].at[:, page_ids].set(
        pages[key].astype(pool_t[key].dtype))
        for key in ("codes", "meta", "tail")}


def copy_page(pool_t: dict, src: int, dst) -> dict:
    """Duplicate one page's bytes (the copy-on-write primitive)."""
    return {key: a.at[:, dst].set(a[:, src]) for key, a in pool_t.items()}


def append_token_paged(pool_t: dict, kv_new: jnp.ndarray, pos: jnp.ndarray,
                       pages: jnp.ndarray) -> dict:
    """Quantize kv_new (B, 1, Hkv, Dh) and write one token column through
    the page table.

    ``pool_t`` is the PER-LAYER pool view (NP, F, P) the layer scan sees;
    ``pages`` (B, max_pages) maps each slot's logical page index to a pool
    page id; ``pos`` (B,) is the slot's token count. The write lands at
    (pages[b, pos_b // P], :, pos_b % P). Logical indices beyond the table
    clamp to its last entry — retired slots keep an all-zero table, so
    their (masked, never read) writes land in the reserved scratch page 0.
    The scheduler guarantees every ACTIVE slot appends into a page it
    exclusively owns (copy-on-write happens before the chunk), so scatter
    indices of live slots never collide.
    """
    p = pool_page_tokens(pool_t)
    maxp = pages.shape[1]
    new = to_kernel_layout(quantize_kv(kv_new))          # (B, F, 1) leaves
    idx = jnp.minimum(pos // p, maxp - 1)
    pids = jnp.take_along_axis(pages, idx[:, None], axis=1)[:, 0]   # (B,)
    offs = pos % p

    def write(full, one):
        return full.at[pids, :, offs].set(one[..., 0].astype(full.dtype))

    return {key: write(pool_t[key], new[key])
            for key in ("codes", "meta", "tail")}


def scrub_pages(pool_t: dict, page_ids: jnp.ndarray) -> dict:
    """Zero every byte of the selected pages (all layers).

    Quarantine support: when a fault audit evicts a poisoned slot, its
    freed pages are scrubbed so stale corruption (e.g. a 0xFF NaN
    sentinel) cannot leak into the next sequence the allocator hands the
    page to. Zero pages decode to zeros, identical to freshly
    pool-initialized pages.
    """
    return {key: a.at[:, page_ids].set(0) for key, a in pool_t.items()}


# Odd multipliers decorrelate the three leaf sums. Any SINGLE bit flip in
# one leaf element changes that leaf's modular sum by ±2^j (j < 32), and an
# odd multiple of ±2^j is never 0 mod 2^32 — so one flipped bit anywhere in
# a page provably changes the page checksum.
_CKSUM_META_MULT = 0x9E3779B1
_CKSUM_TAIL_MULT = 0x85EBCA77


def page_checksums(pool_t: dict) -> jnp.ndarray:
    """(n_pages,) uint32 content checksum of each pool page (one tensor).

    A modular byte/word sum over codes + meta + tail, reduced on device in
    one pass so the per-chunk audit ships n_pages words to the host
    instead of the pool's bytes. Detection guarantee: any single bit flip
    in a page changes its checksum (see the multiplier note above);
    multi-bit corruption is caught with probability ~1 - 2^-32.
    """
    sums = jnp.sum(pool_t["codes"].astype(jnp.uint32), axis=(0, 2, 3))
    sums = sums + jnp.uint32(_CKSUM_META_MULT) * jnp.sum(
        pool_t["meta"].astype(jnp.uint32), axis=(0, 2, 3))
    if pool_t["tail"].shape[2]:
        bits = jax.lax.bitcast_convert_type(pool_t["tail"], jnp.uint16)
        sums = sums + jnp.uint32(_CKSUM_TAIL_MULT) * jnp.sum(
            bits.astype(jnp.uint32), axis=(0, 2, 3))
    return sums


def page_meta_nan_counts(pool_t: dict) -> jnp.ndarray:
    """(n_pages,) int32 count of E6M2 NaN-sentinel meta words per page.

    Algorithm 1 never emits the 0xFF scale code
    (:data:`repro.core.hif4.META_NAN`), so any nonzero count marks a
    corrupted page — including the hot partial page whose checksum is
    legitimately changing every append.
    """
    return jnp.sum(hif4.meta_nan_mask(pool_t["meta"]).astype(jnp.int32),
                   axis=(0, 2, 3))


# ---------------------------------------------------------------------------
# Paged pool: host-side allocator / sharing metadata
# ---------------------------------------------------------------------------


class PagePool:
    """Host-side bookkeeping for the fixed-size device page pool.

    Tracks, per pool page id:

    * a free list and per-page refcounts (``alloc`` / ``retain`` /
      ``release``);
    * ``owner`` — the one holder allowed to append IN PLACE (appends by
      any other holder, or into any page with refcount > 1 it does not
      own, must copy-on-write first);
    * the FULL-page token-hash index (``register_full`` /
      ``lookup_full``): key = the cumulative token tuple through the end
      of the page, so equal keys imply equal page bytes (per-token
      grouping) and chained prefixes dedup page-by-page;
    * the partial-tail registry (``register_partial`` /
      ``lookup_partial``): live, still-appendable tail pages keyed by
      their cumulative prefix + current contents, so a new prompt whose
      tail is a prefix of a live page's contents can share it (and COW
      on its first divergent append);
    * the LRU cache of retired hashed pages (``cached``): a released
      full page parks here instead of freeing, is revived by a later
      prefix hit, and is evicted least-recently-used when ``alloc`` runs
      dry.

    Page id 0 is reserved as the scratch page retired decode slots write
    into (their page tables are zeroed); it is never handed out.
    """

    def __init__(self, n_pages: int, page_tokens: int):
        assert n_pages >= 2, "pool needs the scratch page + 1 usable page"
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.free: list[int] = list(range(n_pages - 1, 0, -1))
        self.ref: dict[int, int] = {}
        self.owner: dict[int, object] = {}
        self.full_hash: dict[tuple, int] = {}
        self.key_of: dict[int, tuple] = {}
        self.partials: dict[int, dict] = {}      # pid -> {"key", "toks"}
        self.cached: "OrderedDict[int, None]" = OrderedDict()
        self.evictions = 0
        self.shared_hits = 0

    # -- capacity -----------------------------------------------------------

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1                  # minus the scratch page

    def available(self) -> int:
        """Pages an alloc() could return right now (free + evictable)."""
        return len(self.free) + len(self.cached)

    def live_pages(self) -> int:
        return len(self.ref)

    # -- alloc / refcount ---------------------------------------------------

    def alloc(self, owner=None) -> Optional[int]:
        """Take a page: free list first, else evict the LRU cached page."""
        if self.free:
            pid = self.free.pop()
        elif self.cached:
            pid, _ = self.cached.popitem(last=False)
            key = self.key_of.pop(pid, None)
            if key is not None:
                self.full_hash.pop(key, None)
            self.evictions += 1
        else:
            return None
        self.ref[pid] = 1
        self.partials.pop(pid, None)
        if owner is not None:
            self.owner[pid] = owner
        return pid

    def retain(self, pid: int):
        """Add a holder; revives a page parked in the retired-LRU cache."""
        if pid in self.cached:
            del self.cached[pid]
            self.ref[pid] = 1
        else:
            self.ref[pid] += 1

    def release(self, pid: int, keep_cached: bool = True):
        """Drop a holder. A hashed full page with no holders parks in the
        LRU cache (still shareable, evictable); anything else frees."""
        self.ref[pid] -= 1
        if self.ref[pid] > 0:
            return
        del self.ref[pid]
        self.owner.pop(pid, None)
        self.partials.pop(pid, None)
        if keep_cached and pid in self.key_of:
            self.cached[pid] = None
        else:
            key = self.key_of.pop(pid, None)
            if key is not None:
                self.full_hash.pop(key, None)
            self.free.append(pid)

    # -- sharing indexes ----------------------------------------------------

    def register_full(self, pid: int, key: tuple):
        """Index an immutable full page by its cumulative token key
        (first writer wins; duplicates simply stay unshared)."""
        self.partials.pop(pid, None)
        if key in self.full_hash or pid in self.key_of:
            return
        self.full_hash[key] = pid
        self.key_of[pid] = key

    def lookup_full(self, key: tuple) -> Optional[int]:
        return self.full_hash.get(key)

    def register_partial(self, pid: int, prefix_key: tuple, toks: list):
        """(Re)index a live tail page: ``prefix_key`` is the cumulative
        token tuple before the page, ``toks`` its current contents."""
        if pid not in self.key_of:
            self.partials[pid] = {"key": prefix_key, "toks": list(toks)}

    def lookup_partial(self, prefix_key: tuple,
                       seg: list) -> Optional[int]:
        """A live page whose prefix matches and whose contents start with
        ``seg`` (the new prompt's tail) — shareable with COW on append."""
        for pid, ent in self.partials.items():
            if (ent["key"] == prefix_key and len(seg) <= len(ent["toks"])
                    and ent["toks"][: len(seg)] == list(seg)):
                return pid
        return None

    # -- invariants ---------------------------------------------------------

    def audit(self, holders: Optional[dict] = None) -> dict:
        """Check every cross-structure invariant; raise AssertionError
        naming ALL violations, so a pool leak fails loudly instead of
        silently shrinking capacity. Called by the paged scheduler after
        every serve (and by recovery); returns occupancy counters on
        success.

        Invariants: the free list, the refcounted live set, and the LRU
        cache PARTITION pages ``1..n_pages-1`` exactly (no page leaked,
        none double-tracked, scratch page 0 never handed out); refcounts
        are positive; owners and partial-tail entries only exist on live
        pages; cached pages are always hash-indexed; the full-page hash
        index is a bijection onto live-or-cached pages, disjoint from the
        partial registry. ``holders`` (optional) maps holder name -> list
        of page ids it retains; the per-page holder counts must then
        equal the refcounts exactly.
        """
        errs = []
        free, live, cached = set(self.free), set(self.ref), set(self.cached)
        if len(free) != len(self.free):
            errs.append(f"free list has duplicates: {sorted(self.free)}")
        for name, a, b in (("free/live", free, live),
                           ("free/cached", free, cached),
                           ("live/cached", live, cached)):
            both = a & b
            if both:
                errs.append(f"pages tracked twice ({name}): {sorted(both)}")
        expected = set(range(1, self.n_pages))
        tracked = free | live | cached
        leaked = expected - tracked
        if leaked:
            errs.append(f"leaked pages (in no structure): {sorted(leaked)}")
        bogus = tracked - expected
        if bogus:
            errs.append(f"out-of-range or scratch page ids tracked: "
                        f"{sorted(bogus)}")
        for pid, n in self.ref.items():
            if n <= 0:
                errs.append(f"page {pid}: non-positive refcount {n}")
        for pid in self.owner:
            if pid not in self.ref:
                errs.append(f"page {pid}: owned but not live")
        for pid in self.partials:
            if pid not in self.ref:
                errs.append(f"page {pid}: in the partial registry but "
                            "not live")
            if pid in self.key_of:
                errs.append(f"page {pid}: both partial and full-hashed")
        for pid in cached:
            if pid not in self.key_of:
                errs.append(f"page {pid}: cached without a full-page hash "
                            "(unshareable — should have freed)")
        if len(self.full_hash) != len(self.key_of):
            errs.append(f"full_hash ({len(self.full_hash)}) and key_of "
                        f"({len(self.key_of)}) disagree on size")
        for pid, key in self.key_of.items():
            if self.full_hash.get(key) != pid:
                errs.append(f"page {pid}: key_of/full_hash mismatch")
            if pid not in self.ref and pid not in self.cached:
                errs.append(f"page {pid}: hash-indexed but neither live "
                            "nor cached")
        if holders is not None:
            counts: dict[int, int] = {}
            for ids in holders.values():
                for pid in ids:
                    counts[pid] = counts.get(pid, 0) + 1
            if counts != dict(self.ref):
                errs.append(f"refcounts {dict(sorted(self.ref.items()))} != "
                            f"holder counts {dict(sorted(counts.items()))}")
        assert not errs, (
            "PagePool.audit failed:\n  - " + "\n  - ".join(errs))
        return {"free": len(free), "live": len(live), "cached": len(cached),
                "hashed": len(self.key_of), "partials": len(self.partials)}


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


def packed_kv_nbytes(pk: dict) -> int:
    """Resident bytes of one packed K or V tensor (codes + meta + tail)."""
    return (
        int(pk["codes"].size)
        + 4 * int(pk["meta"].size)
        + 2 * int(pk["tail"].size)
    )
