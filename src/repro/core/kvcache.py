"""HiF4-packed KV cache: the paper's 64-element groups applied to K/V.

The KV cache is the dominant memory consumer at serving scale (weights are
amortized across slots; cache bytes grow with slots x capacity x layers).
This module stores each cached token's K (and V) vector in the HiF4 packed
layout so the resident bytes drop from 2 B/value (bf16) to 0.5625 B/value —
~3.56x more continuous-batching slots per device for the same HBM.

Layout (per layer, per tensor; see docs/FORMATS.md for the bit layout):

    token features F = n_kv_heads * d_head, flattened per token
    G = F // 64 whole HiF4 groups, T = F % 64 tail features

    codes (..., S, G, 32) uint8    two 4-bit S1P2 codes per byte
    meta  (..., S, G)     uint32   E6M2<<24 | E1_8<<16 | E1_16
    tail  (..., S, T)     bf16     partial-group staging buffer

Grouping is **per token along the flattened head axis** — never across
tokens — so appending one decoded token re-quantizes nothing: each append
writes exactly its own G groups + T tail features. That independence is
what makes continuous-batching serving bit-identical to solo serving (a
token's packed bits depend only on its own K/V vector, not on its slot,
neighbours, or cache capacity). Features that do not fill a whole 64-group
stay bf16 in the ``tail`` staging buffer (exact, 2 B/value) instead of
forcing a padded, mostly-empty group whose metadata would be garbage.

Dequantize-on-read is exact in bf16 (the HiF4 reconstruction product
carries <= 6 significant bits; see :func:`repro.core.hif4.dequantize_groups`),
so a packed cache decodes exactly like a bf16 cache holding the quantized
values.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hif4

KV_FORMATS = ("bf16", "hif4")


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """How the decode KV cache is stored.

    kv_format: 'bf16' (dense cache, 2 B/value) | 'hif4' (packed cache,
    4.5 bits/value + bf16 tail). Frozen/hashable so it can ride on
    :class:`repro.core.qlinear.QuantConfig` into jit cache keys.
    """

    kv_format: str = "bf16"

    def __post_init__(self):
        assert self.kv_format in KV_FORMATS, self.kv_format

    @property
    def packed(self) -> bool:
        return self.kv_format == "hif4"


KV_BF16 = KVCacheConfig("bf16")
KV_HIF4 = KVCacheConfig("hif4")


def split_features(n_kv_heads: int, d_head: int) -> tuple[int, int]:
    """(whole 64-groups, bf16 tail features) per token."""
    return divmod(n_kv_heads * d_head, hif4.GROUP_SIZE)


def kv_bytes_per_token(n_kv_heads: int, d_head: int,
                       kv_format: str = "bf16") -> int:
    """Resident cache bytes per token PER LAYER (K and V together)."""
    f = n_kv_heads * d_head
    if kv_format == "hif4":
        g, t = divmod(f, hif4.GROUP_SIZE)
        per_tensor = g * (32 + 4) + t * 2      # codes + meta, bf16 tail
    else:
        per_tensor = f * 2
    return 2 * per_tensor                      # K + V


def is_packed_kv(cache) -> bool:
    """True for the packed per-tensor dict {"codes","meta","tail"}."""
    return isinstance(cache, dict) and "codes" in cache


# ---------------------------------------------------------------------------
# Quantize / dequantize (leading dims arbitrary: works per token, per
# sequence, and on (L, B, S, ...) stacked whole caches alike)
# ---------------------------------------------------------------------------


def quantize_kv(kv: jnp.ndarray) -> dict:
    """(..., Hkv, Dh) K or V values -> packed leaves {codes, meta, tail}.

    Each trailing (Hkv, Dh) vector is flattened and cut into 64-element
    HiF4 groups; the F % 64 remainder stays bf16 in ``tail``. Group bits
    depend only on the vector itself (Algorithm 1 is per-group), so
    quantizing token-by-token equals quantizing the whole sequence.
    """
    lead = kv.shape[:-2]
    f = kv.shape[-2] * kv.shape[-1]
    g, t = divmod(f, hif4.GROUP_SIZE)
    flat = kv.reshape(lead + (f,))
    body = flat[..., : g * hif4.GROUP_SIZE].reshape(
        lead + (g, hif4.GROUP_SIZE)
    )
    packed = hif4.quantize_packed(body.astype(jnp.bfloat16))
    return {
        "codes": packed.codes,
        "meta": packed.meta,
        "tail": flat[..., g * hif4.GROUP_SIZE :].astype(jnp.bfloat16),
    }


def dequantize_kv(pk: dict, n_kv_heads: int, d_head: int) -> jnp.ndarray:
    """Packed leaves -> (..., Hkv, Dh) bf16 values (exact reconstruction
    of the quantized grid; the tail returns bit-identical)."""
    lead = pk["codes"].shape[:-2]
    g = pk["codes"].shape[-2]
    body = hif4.dequantize_packed(
        hif4.HiF4Packed(pk["codes"], pk["meta"])
    ).astype(jnp.bfloat16)
    flat = jnp.concatenate(
        [body.reshape(lead + (g * hif4.GROUP_SIZE,)),
         pk["tail"].astype(jnp.bfloat16)],
        axis=-1,
    )
    return flat.reshape(lead + (n_kv_heads, d_head))


# ---------------------------------------------------------------------------
# Append-one-token (the decode hot path)
# ---------------------------------------------------------------------------


def append_token(pcache: dict, kv_new: jnp.ndarray, pos: jnp.ndarray) -> dict:
    """Quantize kv_new (B, 1, Hkv, Dh) and write it at sequence slot ``pos``.

    ``pos`` is a scalar (whole batch in lockstep) or (B,) per-slot offsets
    (continuous batching: a freshly admitted request sits at its prompt
    length while its slot neighbours are deep into decode). Cache leaves
    are (B, S, ...); only the G + tail bytes of the one token are written.
    """
    new = quantize_kv(kv_new)
    per_slot = jnp.ndim(pos) == 1

    def write(full, one):
        if per_slot:
            return jax.vmap(
                lambda c, n, p: jax.lax.dynamic_update_slice(
                    c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1)
                )
            )(full, one, pos)
        idx = (0, pos) + (0,) * (full.ndim - 2)
        return jax.lax.dynamic_update_slice(full, one.astype(full.dtype), idx)

    return {key: write(pcache[key], new[key]) for key in ("codes", "meta", "tail")}


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


def packed_kv_nbytes(pk: dict) -> int:
    """Resident bytes of one packed K or V tensor (codes + meta + tail)."""
    return (
        int(pk["codes"].size)
        + 4 * int(pk["meta"].size)
        + 2 * int(pk["tail"].size)
    )
