"""Error metrics used across the experiment suite.

One shared surface for the per-format quantization-error scores: the
Fig. 3 Gaussian sweep (``benchmarks/quant_error.py``), the tiny-LM
accuracy proxy (``benchmarks/llm_accuracy.py``) and the calibration
probe (``repro.calibrate.probe``) all import from here instead of
carrying their own MSE/SQNR/output-error spellings.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

# the cross-format comparison set the paper sweeps (Fig. 3) and the
# calibrator scores per site
QDQ_FORMATS = ("hif4", "nvfp4", "nvfp4_pts", "mxfp4")


def mse(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    d = x.astype(jnp.float32) - x_hat.astype(jnp.float32)
    return jnp.mean(d * d)


def rel_mse(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return mse(x, x_hat) / jnp.maximum(jnp.mean(jnp.square(x.astype(jnp.float32))), 1e-30)


def sqnr_db(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return 10.0 * jnp.log10(1.0 / jnp.maximum(rel_mse(x, x_hat), 1e-30))


def max_abs_err(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(x.astype(jnp.float32) - x_hat.astype(jnp.float32)))


METRICS = {"mse": mse, "rel_mse": rel_mse, "sqnr_db": sqnr_db,
           "max_abs_err": max_abs_err}


def qdq_error(x: jnp.ndarray, fmt: str, metric: str = "mse",
              axis: int = -1) -> float:
    """Direct-cast error of quantizing ``x`` to ``fmt`` (grouped along
    ``axis``), under one of the named :data:`METRICS`. ``fmt='none'``
    scores exactly zero error (except sqnr_db, which saturates)."""
    if fmt in (None, "none", "bf16"):
        return float(METRICS[metric](x, x))
    from repro.core.formats import get_format

    return float(METRICS[metric](x, get_format(fmt).qdq(x, axis=axis)))


def format_error_table(x: jnp.ndarray,
                       formats: Sequence[str] = QDQ_FORMATS,
                       metric: str = "mse", axis: int = -1) -> dict:
    """``{fmt: error}`` over the comparison set — the Fig. 3 inner loop
    and the calibrator's per-site score row share this helper."""
    return {f: qdq_error(x, f, metric=metric, axis=axis) for f in formats}


def rel_output_error(w_ref: jnp.ndarray, w_q: jnp.ndarray,
                     x: jnp.ndarray) -> float:
    """``||X (W - W_q)||_F / ||X W||_F`` — the layer-output error GPTQ
    minimizes, and the per-site score the calibration frontier ranks by.
    ``w`` is (K, N) contraction-major, ``x`` is (n_samples, K)."""
    x = x.astype(jnp.float32)
    num = jnp.linalg.norm(
        x @ (w_ref.astype(jnp.float32) - w_q.astype(jnp.float32)))
    den = jnp.linalg.norm(x @ w_ref.astype(jnp.float32))
    return float(num / jnp.maximum(den, 1e-30))


def agreement(preds: jnp.ndarray, ref_preds: Optional[jnp.ndarray]) -> float:
    """Fraction of predictions agreeing with a reference run (1.0 when no
    reference is supplied — the bf16 row agrees with itself)."""
    if ref_preds is None:
        return 1.0
    return float(jnp.mean(preds == ref_preds))
