"""Error metrics used across the experiment suite."""
from __future__ import annotations

import jax.numpy as jnp


def mse(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    d = x.astype(jnp.float32) - x_hat.astype(jnp.float32)
    return jnp.mean(d * d)


def rel_mse(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return mse(x, x_hat) / jnp.maximum(jnp.mean(jnp.square(x.astype(jnp.float32))), 1e-30)


def sqnr_db(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return 10.0 * jnp.log10(1.0 / jnp.maximum(rel_mse(x, x_hat), 1e-30))


def max_abs_err(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(x.astype(jnp.float32) - x_hat.astype(jnp.float32)))
