"""OCP-MXFP4 baseline format (paper SS I; OCP MX spec / arXiv:2310.10537).

Group of 32 E2M1 elements + one shared power-of-two E8M0 scale
= 4.25 bits/value. Shared exponent = floor(log2(amax)) - emax(E2M1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import rounding as R
from repro.core.grouping import apply_grouped

GROUP_SIZE = 32
BITS_PER_VALUE = 4.25


class MXFP4Groups(NamedTuple):
    scale: jnp.ndarray   # (...,)    f32, power of two
    e2m1: jnp.ndarray    # (..., 32) f32 on E2M1 grid


def quantize_groups(v: jnp.ndarray) -> MXFP4Groups:
    v = v.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=-1)
    scale = R.e8m0_scale_from_amax(amax, element_emax=2)
    e2m1 = R.quantize_e2m1(v / scale[..., None])
    return MXFP4Groups(scale=scale, e2m1=e2m1)


def dequantize_groups(g: MXFP4Groups) -> jnp.ndarray:
    return g.scale[..., None] * g.e2m1


def qdq(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return apply_grouped(
        lambda v: dequantize_groups(quantize_groups(v)), x, axis, GROUP_SIZE
    )
