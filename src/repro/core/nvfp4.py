"""NVFP4 baseline format (paper SS I, SS III).

Group of 16 E2M1 elements + one FP8-E4M3 per-group scale = 4.5 bits/value.
Scale normalizes each group's peak magnitude to 6 (E2M1 max). Because E4M3
covers only ~22 binades, direct-cast fails on wide-distribution tensors; the
"+PTS" variant first applies a software per-tensor scale mapping the tensor
peak to 2688 = 448 * 6 (NVIDIA's published inference recipe, paper [15]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import rounding as R
from repro.core.grouping import apply_grouped

GROUP_SIZE = 16
BITS_PER_VALUE = 4.5
MAX_POS = 448.0 * 6.0          # = 2^11 * 1.3125 (Table II)
MIN_POS = 2.0 ** -10           # min subnormal scale * min element (Table II)
PTS_TARGET = 2688.0            # per-tensor scaling target (448 * 6)


class NVFP4Groups(NamedTuple):
    scale: jnp.ndarray   # (...,)    f32 on E4M3 grid
    e2m1: jnp.ndarray    # (..., 16) f32 on E2M1 grid


def quantize_groups(v: jnp.ndarray) -> NVFP4Groups:
    v = v.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=-1)
    scale = R.round_e4m3(amax / R.E2M1_MAX)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    e2m1 = R.quantize_e2m1(v * inv[..., None])
    return NVFP4Groups(scale=scale, e2m1=e2m1)


def dequantize_groups(g: NVFP4Groups) -> jnp.ndarray:
    return g.scale[..., None] * g.e2m1


def to_absorbed_int(g: NVFP4Groups) -> tuple[jnp.ndarray, jnp.ndarray]:
    """S3P1 integer view (paper Fig. 4): halves in [-12, 12], scale/4."""
    ints = R.e2m1_to_int(g.e2m1)
    return ints, g.scale * 0.5


def qdq(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return apply_grouped(
        lambda v: dequantize_groups(quantize_groups(v)), x, axis, GROUP_SIZE
    )


def qdq_pts(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """NVFP4 with software per-tensor scaling (paper's NVFP4+PTS)."""
    amax = jnp.max(jnp.abs(x))
    s = jnp.where(amax > 0, PTS_TARGET / amax, 1.0).astype(jnp.float32)
    y = qdq(x.astype(jnp.float32) * s, axis)
    return (y / s).astype(x.dtype)
