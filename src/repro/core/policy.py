"""Per-site quantization policy: WHAT gets quantized, decided in one place.

The paper quantizes the transformer body to HiF4 while keeping sensitive
tensors (embedding, LM head, MoE router — §IV) in high precision, and its
headline result is a cross-format comparison. Before this module, that
placement was scattered: one global :class:`~repro.core.qlinear.QuantConfig`
applied uniformly, and the *site set* was hardcoded three times (a
``PACKABLE_KEYS`` lookup, a ``parent == "moe"`` exclusion, inline
``NO_QUANT`` at the embed/head/router call sites).

A :class:`QuantPolicy` is an ordered list of :class:`QuantRule`s matching
parameter-tree paths (glob patterns over dotted paths, e.g.
``blocks.*.wq``, ``moe.*``, ``lm_head``) to per-site settings (``fmt``,
``impl``, ``weights_only``). **Later rules win.** The KV-cache format
(``kv``) stays cache-global on the policy. Resolving a policy against a
model's param specs (:func:`QuantPolicy.resolve`, usually via
``repro.models.lm.quant_plan``) produces an explicit :class:`QuantPlan`:
one :class:`SitePlan` per quantizable weight site, carrying the site's
resolved :class:`QuantConfig` and whether the serving artifact packs it to
a 4.5-bit ``PackedW`` (``prepare_params_for_serving`` packs exactly the
sites the plan marks packed — there is no other packing predicate).

Path/pattern semantics:

* A site path is the dotted parameter-tree path with stacked layers
  collapsed (layers share one config because they run under one
  ``lax.scan``): ``blocks.attn.wq``, ``blocks.moe.router``, ``lm_head``.
* A pattern matches a path if it globs the full path **or any trailing
  sub-path** (``attn.wq`` and ``*.attn.wq`` are equivalent; ``moe.*``
  matches ``blocks.moe.wg``). ``*`` is ``fnmatch``-style and crosses
  dots.

Presets (``get_policy``): ``uniform:<fmt>`` (the back-compat shim —
bitwise-identical to the old global config, including the §IV
exclusions), ``paper-iv`` (the paper's placement spelled out as rules),
``nvfp4-baseline`` (cross-format comparison), ``sensitive-fallback``
(mixed hif4/bf16: the outlier-sensitive down/output projections stay
high-precision — the per-site fallback "Unleashing Low-Bit Inference on
Ascend NPUs" shows 4-bit deployment needs). Policies serialize to JSON
(``to_json_dict``/``from_json_dict``) and ride inside serving artifacts
(``repro.runtime.serve_loop.save_serving_artifact``) so a checkpoint can
never be served under a different placement than it was packed with.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
from typing import Optional

import numpy as np

from repro.core.formats import get_format
from repro.core.kvcache import KVCacheConfig
from repro.core.qlinear import QuantConfig, packable_contract_axes


# Block-weight keys eligible for offline PTQ / 4.5-bit packing (the old
# qlinear.PACKABLE_KEYS, now a DEFAULT RULE of policy resolution rather
# than a predicate model code consults). Biases, norms, router and scalar
# state are excluded (paper §IV placement).
PACKABLE_WEIGHT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wg", "wu", "wi",
    "w_z", "w_x", "w_b", "w_c", "w_dt", "w_out",
})

# Every weight key that is a quantization SITE (a dense()/qbmm call site
# reads its config from the plan). embed is listed for the plan table but
# clamped to fmt='none' at resolution: the embedding lookup is a gather,
# not a matmul (and §IV keeps it high-precision anyway).
SITE_KEYS = PACKABLE_WEIGHT_KEYS | {"router", "embed", "lm_head"}

# The paper-§IV sensitive sites, as patterns. Appended (LAST, so they win)
# by the uniform shim and the presets that follow the paper's placement.
SENSITIVE_SITE_PATTERNS = ("embed", "lm_head", "*.router")

# Stacked-layer collections whose weights can carry offline artifacts
# (QDQ'd bf16 or PackedW). Top-level sites (embed/lm_head) are handled
# separately; hybrid's doubly-stacked blocks never pack (PackedW assumes
# one leading layer axis).
STACKED_COLLECTIONS = ("blocks", "shared", "enc_blocks")


def default_offline_axes(key: str, ndim: int) -> Optional[tuple]:
    """Structural eligibility for offline PTQ/packing of a STACKED block
    weight: the legacy predicate (`key in PACKABLE_KEYS and ndim >= 2`),
    now shared between plan resolution and the legacy
    ``quantize_params_offline`` path so the two can never drift. Returns
    the contraction axes, or None if the key is not a packable weight.
    (The K % 64 gate is shape-dependent and applied by the caller.)
    """
    if key not in PACKABLE_WEIGHT_KEYS or ndim < 2:
        return None
    return packable_contract_axes(key, ndim)


@dataclasses.dataclass(frozen=True)
class QuantRule:
    """One policy rule: sites matching ``pattern`` take the given settings.

    ``None`` fields are inherited from whatever earlier rules (or the
    unquantized default) decided — a rule can flip just ``fmt`` without
    restating ``impl``.
    """

    pattern: str
    fmt: Optional[str] = None
    impl: Optional[str] = None
    weights_only: Optional[bool] = None

    def matches(self, path: str) -> bool:
        return (fnmatch.fnmatchcase(path, self.pattern)
                or fnmatch.fnmatchcase(path, "*." + self.pattern))

    def apply(self, cfg: QuantConfig) -> QuantConfig:
        updates = {}
        if self.fmt is not None:
            updates["fmt"] = self.fmt
        if self.impl is not None:
            updates["impl"] = self.impl
        if self.weights_only is not None:
            updates["weights_only"] = self.weights_only
        return dataclasses.replace(cfg, **updates) if updates else cfg


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """One resolved site: the explicit record of what serving will do.

    packed           : the serving artifact stores this site as 4.5-bit
                       PackedW buffers (and prepare_params_for_serving
                       packs exactly these sites)
    quantize_offline : offline weight PTQ (QDQ along contract_axes) is
                       structurally possible — key is a packable block
                       weight, ndim >= 2, and K is whole 64-groups
    contract_axes    : contraction axes of the (stacked) weight
    """

    path: str
    cfg: QuantConfig
    packed: bool
    quantize_offline: bool
    contract_axes: tuple
    shape: tuple
    n_values: int


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """A policy resolved against one model's param specs.

    ``base`` is the policy evaluated at the attention site — decode
    attention over the (cache-global) packed KV cache dispatches on it,
    and it is what legacy single-config code paths see as "the" config.
    Frozen/hashable: rides into jit cache keys exactly like QuantConfig.
    """

    policy: "QuantPolicy"
    family: str
    base: QuantConfig
    sites: tuple  # tuple[SitePlan, ...]

    @functools.cached_property
    def _by_path(self) -> dict:
        return {s.path: s for s in self.sites}

    def site(self, path: str) -> SitePlan:
        try:
            return self._by_path[path]
        except KeyError:
            raise KeyError(
                f"no quantization site {path!r} in the resolved plan "
                f"(family={self.family!r}; sites: {sorted(self._by_path)})"
            ) from None

    def get(self, path: str) -> Optional[SitePlan]:
        """The SitePlan at ``path``, or None for a non-site leaf (what the
        packing/PTQ walks probe with every param path)."""
        return self._by_path.get(path)

    def at(self, path: str) -> QuantConfig:
        """The resolved QuantConfig a dense() call site executes under."""
        return self.site(path).cfg

    @property
    def kv(self) -> KVCacheConfig:
        return self.policy.kv

    @property
    def packed_paths(self) -> frozenset:
        return frozenset(s.path for s in self.sites if s.packed)

    @property
    def enabled(self) -> bool:
        """Does serving need any artifact conversion at all?"""
        return any(s.packed or s.cfg.enabled for s in self.sites)

    def with_offline_weights(self) -> "QuantPlan":
        """The serving-time plan: every site cfg gets offline_weights=True
        (the blanket flip the legacy serving context applied). Sites whose
        structure admits no offline artifact (e.g. batched-expert weights
        with K not a whole number of 64-groups) therefore serve their
        weights unquantized while activations still quantize — exactly the
        legacy behavior, now visible in the plan instead of implicit.
        """
        flip = lambda c: dataclasses.replace(c, offline_weights=True)
        sites = tuple(dataclasses.replace(s, cfg=flip(s.cfg))
                      for s in self.sites)
        return dataclasses.replace(self, base=flip(self.base), sites=sites)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered per-site quantization rules + the cache-global KV format.

    ``provenance`` records WHERE a policy came from when it was not
    hand-written — the calibration emitter (``repro.calibrate``) stamps
    the search that produced it (arch, calibration set, target budget,
    achieved bytes/value) so a searched policy file is auditable and the
    serving artifact it rides in says how its placement was chosen. It is
    stored as a canonical JSON string (policies are frozen/hashable and
    ride into jit cache keys; a dict field would break that) — read it
    via :meth:`provenance_dict`, attach via :meth:`with_provenance`.
    """

    rules: tuple = ()  # tuple[QuantRule, ...]
    kv: KVCacheConfig = KVCacheConfig()
    name: str = "custom"
    provenance: Optional[str] = None

    def with_provenance(self, meta: dict) -> "QuantPolicy":
        return dataclasses.replace(
            self, provenance=json.dumps(meta, sort_keys=True))

    def provenance_dict(self) -> Optional[dict]:
        return None if self.provenance is None else json.loads(self.provenance)

    @classmethod
    def uniform(cls, cfg: QuantConfig, name: Optional[str] = None
                ) -> "QuantPolicy":
        """Back-compat shim: the policy equivalent of the old global
        config — one catch-all rule plus the §IV exclusions the call
        sites used to hardcode. Bitwise-identical to the pre-policy
        paths on all three impls (tested in tests/test_policy.py).
        """
        rules = (QuantRule("*", fmt=cfg.fmt, impl=cfg.impl,
                           weights_only=cfg.weights_only),)
        rules += tuple(QuantRule(p, fmt="none")
                       for p in SENSITIVE_SITE_PATTERNS)
        return cls(rules=rules, kv=cfg.kv,
                   name=name or f"uniform:{cfg.fmt}")

    def config_at(self, path: str) -> QuantConfig:
        """Fold the rules over one site path (later rules win)."""
        cfg = QuantConfig(fmt="none", impl="qdq", kv=self.kv)
        for rule in self.rules:
            if rule.matches(path):
                cfg = rule.apply(cfg)
        return cfg

    # -- resolution ---------------------------------------------------------

    def resolve(self, specs: dict, family: str) -> QuantPlan:
        """Resolve against a param-spec tree (``lm.abstract_params(cfg)``;
        use ``lm.quant_plan(cfg, policy)`` for the one-liner).

        Site enumeration walks every PSpec leaf whose key is a weight
        site; packing eligibility reproduces the legacy structural rules
        (packable key, ndim >= 2, K a whole number of 64-groups, not a
        batched MoE expert, not hybrid's doubly-stacked blocks) — but the
        DECISION is now ``structural AND the site's resolved config says
        impl packed/pallas on fmt hif4``, so a rule flipping one site to
        bf16 also un-packs exactly that site.
        """
        sites = []
        tied = not any(_leaf_key(k) == "lm_head" for k in specs)

        def walk(node, path_parts):
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, path_parts + (k,))
                return
            if not hasattr(node, "shape"):
                return
            key = path_parts[-1]
            if key not in SITE_KEYS:
                return
            path = ".".join(path_parts)
            sites.append(self._site(path, key, tuple(node.shape), family))

        walk(specs, ())
        if tied:
            # tied embeddings: lm_logits still queries the "lm_head" site
            # (it contracts embed.T). No separate tensor exists, so no
            # offline artifact — the site is dense-time-QDQ only.
            d_v = next(tuple(s.shape) for k, s in specs.items()
                       if k == "embed")
            sites.append(self._site("lm_head", "lm_head",
                                    (d_v[1], d_v[0]), family,
                                    force_no_offline=True))
        return QuantPlan(policy=self, family=family,
                         base=self.config_at("blocks.attn.wq"),
                         sites=tuple(sorted(sites, key=lambda s: s.path)))

    def _site(self, path: str, key: str, shape: tuple, family: str,
              *, force_no_offline: bool = False) -> SitePlan:
        cfg = self.config_at(path)
        parts = path.split(".")
        in_stacked = parts[0] in STACKED_COLLECTIONS
        under_moe = "moe" in parts[:-1]
        ndim = len(shape)

        ca: tuple = ()
        offline = False
        if in_stacked:
            axes = default_offline_axes(key, ndim)
            if axes is not None:
                ca = axes
                k = int(np.prod([shape[a] for a in ca]))
                offline = k % 64 == 0
        elif key == "lm_head" and ndim == 2 and shape[0] % 64 == 0:
            # top-level untied head: offline QDQ is possible (axis 0)
            ca, offline = (0,), True
        if force_no_offline:
            ca, offline = (), False
        if key == "embed":
            # the embedding lookup is a gather, not a matmul: clamp.
            cfg = dataclasses.replace(cfg, fmt="none")

        packed = (
            offline
            and in_stacked
            and not under_moe          # batched-expert einsum, no packed op
            and family != "hybrid"     # doubly-stacked blocks don't fit
            and cfg.impl in ("packed", "pallas")
            and cfg.fmt == "hif4"      # PackedW is an HiF4 container
        )
        return SitePlan(path=path, cfg=cfg, packed=packed,
                        quantize_offline=offline, contract_axes=ca,
                        shape=shape, n_values=int(np.prod(shape)))

    # -- serialization ------------------------------------------------------

    # every top-level key a policy JSON may carry; from_json_dict rejects
    # anything else loudly (a typo'd "rulse" must not silently yield the
    # default policy)
    JSON_KEYS = frozenset({"name", "kv_format", "rules", "provenance"})
    _RULE_JSON_KEYS = frozenset({"pattern", "fmt", "impl", "weights_only"})

    def to_json_dict(self) -> dict:
        rules = []
        for r in self.rules:
            d = {"pattern": r.pattern}
            if r.fmt is not None:
                d["fmt"] = r.fmt
            if r.impl is not None:
                d["impl"] = r.impl
            if r.weights_only is not None:
                d["weights_only"] = r.weights_only
            rules.append(d)
        out = {"name": self.name, "kv_format": self.kv.kv_format,
               "rules": rules}
        if self.provenance is not None:
            out["provenance"] = json.loads(self.provenance)
        return out

    @classmethod
    def from_json_dict(cls, d: dict) -> "QuantPolicy":
        unknown = set(d) - cls.JSON_KEYS
        if unknown:
            raise ValueError(
                f"policy JSON has unknown top-level key(s) "
                f"{sorted(unknown)} (expected a subset of "
                f"{sorted(cls.JSON_KEYS)}) — a typo here would otherwise "
                f"silently yield the default policy")
        rules = []
        for r in d.get("rules", ()):
            bad = set(r) - cls._RULE_JSON_KEYS
            if bad:
                raise ValueError(
                    f"policy rule {r.get('pattern', r)!r} has unknown "
                    f"key(s) {sorted(bad)} (expected a subset of "
                    f"{sorted(cls._RULE_JSON_KEYS)})")
            rules.append(QuantRule(pattern=r["pattern"], fmt=r.get("fmt"),
                                   impl=r.get("impl"),
                                   weights_only=r.get("weights_only")))
        prov = d.get("provenance")
        return cls(rules=tuple(rules),
                   kv=KVCacheConfig(d.get("kv_format", "bf16")),
                   name=d.get("name", "custom"),
                   provenance=None if prov is None
                   else json.dumps(prov, sort_keys=True))


def _leaf_key(k) -> str:
    return k if isinstance(k, str) else str(k)


@functools.lru_cache(maxsize=None)
def uniform_site_config(quant: QuantConfig, path: str) -> QuantConfig:
    """Per-site config of a plan-less ModelCtx: the uniform shim evaluated
    at ``path``. This is where the old hardcoded NO_QUANT call sites went —
    embed/lm_head/router resolve to fmt='none' through the same rule
    machinery every explicit policy uses.
    """
    cfg = QuantPolicy.uniform(quant).config_at(path)
    return dataclasses.replace(cfg, offline_weights=quant.offline_weights)


# ---------------------------------------------------------------------------
# Preset registry
# ---------------------------------------------------------------------------


def _sensitive_none() -> tuple:
    return tuple(QuantRule(p, fmt="none") for p in SENSITIVE_SITE_PATTERNS)


def _paper_iv(impl: str) -> tuple:
    """§IV placement: HiF4 body, high-precision embed / LM head / router."""
    return (QuantRule("*", fmt="hif4", impl=impl),) + _sensitive_none()


def _nvfp4_baseline(impl: str) -> tuple:
    """Cross-format baseline: NVFP4 (per-tensor-scaled recipe) on the body.
    NVFP4 has no packed container, so no site packs regardless of impl —
    the engine serves it fake-quant (see docs/EXECUTION.md)."""
    return (QuantRule("*", fmt="nvfp4_pts", impl=impl),) + _sensitive_none()


def _sensitive_fallback(impl: str) -> tuple:
    """Mixed hif4/bf16: the outlier-sensitive output/down projections
    (attention wo, MLP down wo) stay bf16 dense while the rest of the body
    packs — the per-site fallback that makes 4-bit deployment robust."""
    return (
        QuantRule("*", fmt="hif4", impl=impl),
        QuantRule("*.attn.wo", fmt="none"),
        QuantRule("*.xattn.wo", fmt="none"),
        QuantRule("*.mlp.wo", fmt="none"),
    ) + _sensitive_none()


PRESETS = {
    "paper-iv": _paper_iv,
    "nvfp4-baseline": _nvfp4_baseline,
    "sensitive-fallback": _sensitive_fallback,
}


def known_policy_spec(spec: str) -> bool:
    """Is ``spec`` a resolvable preset name? (``uniform:<fmt>`` is dynamic
    over the format registry; used by the docs lint.)"""
    if spec in PRESETS:
        return True
    if spec.startswith("uniform:"):
        fmt = spec.split(":", 1)[1]
        if fmt == "none":
            return True
        try:
            get_format(fmt)
        except ValueError:
            return False
        return True
    return False


def get_policy(spec: str, *, impl: str = "packed",
               kv: KVCacheConfig = KVCacheConfig()) -> QuantPolicy:
    """Resolve ``--policy`` spellings: a preset name, ``uniform:<fmt>``,
    or a path to a policy JSON file.

    ``impl``/``kv`` fill in what the spelling leaves unspecified: presets
    take them directly; for a JSON file, ``impl`` is prepended as a base
    catch-all rule (the file's own ``impl`` fields still win — standard
    later-rules-win inheritance) and ``kv`` applies only when the file has
    no ``kv_format`` key. So ``--impl``/``--kv-format`` behave the same
    for file policies as for presets.
    """
    if spec.endswith(".json"):
        with open(spec) as f:
            d = json.load(f)
        pol = QuantPolicy.from_json_dict(d)
        rules = (QuantRule("*", impl=impl),) + pol.rules
        return dataclasses.replace(
            pol, rules=rules,
            kv=pol.kv if "kv_format" in d else kv)
    if spec.startswith("uniform:"):
        fmt = spec.split(":", 1)[1]
        assert fmt == "none" or get_format(fmt) is not None, (
            f"uniform:{fmt}: unknown format")
        return QuantPolicy.uniform(QuantConfig(fmt=fmt, impl=impl, kv=kv))
    if spec in PRESETS:
        return QuantPolicy(rules=PRESETS[spec](impl), kv=kv, name=spec)
    raise ValueError(
        f"unknown policy {spec!r}: expected a JSON file, 'uniform:<fmt>', "
        f"or one of {sorted(PRESETS)}")
