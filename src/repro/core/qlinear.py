"""Quantized linear algebra: the integration point between formats and models.

Three execution paths, all numerically anchored to the same format modules
and dispatched by :mod:`repro.core.engine` (``QuantConfig.impl``):

* ``qdq``     — fake-quant both operands, matmul in bf16/f32. Lowers on any
                backend; used for accuracy experiments and the dry-run.
* ``packed``  — weights stored as HiF4 bit-packed buffers (4.5 bits/value in
                HBM), contracted by the fused dequantize-in-kernel matmul
                (repro.kernels.fused_matmul: the payload expands to absorbed
                int8 inside VMEM). The deployment artifact that shrinks the
                memory roofline term AND the serving hot path.
* ``pallas``  — repro.kernels.bfp_matmul: the paper's SS III.B fixed-point
                flow on the MXU int8 path (TPU target; interpret-mode on CPU);
                PackedW weights take the same fused kernel as ``packed``.

Quantization always happens along the contraction dimension (each 64-element
HiF4 group lies along K), matching how a 64-length PE dot consumes the data.
This module owns the format plumbing (configs, fake-quant ops, packed-weight
containers); the engine owns execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core.formats import BFPFormat, get_format
from repro.core.kvcache import KVCacheConfig


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How matmuls inside models are quantized.

    fmt             : 'hif4' | 'nvfp4' | 'nvfp4_pts' | 'mxfp4' | 'none'
    weights_only    : quantize only the weight operand (GPT-OSS style)
    offline_weights : weights were already quantized once offline (PTQ
                      deployment); skip the in-graph weight QDQ and only
                      cast activations dynamically. Inference graphs use
                      this — re-quantizing static weights every serve step
                      would be pure waste on hardware too.
    impl            : 'qdq' | 'packed' | 'pallas'
    kv              : how the decode KV cache is stored ('bf16' dense or
                      'hif4' packed at 4.5 bits/value) — orthogonal to
                      ``impl``; see repro.core.kvcache / docs/FORMATS.md.
    """

    fmt: str = "none"
    weights_only: bool = False
    offline_weights: bool = False
    impl: str = "qdq"
    kv: KVCacheConfig = KVCacheConfig()

    @property
    def enabled(self) -> bool:
        return get_format(self.fmt) is not None

    def format(self) -> Optional[BFPFormat]:
        return get_format(self.fmt)


NO_QUANT = QuantConfig()


def _ste(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward = x_hat, backward = identity.

    The rounding inside QDQ has zero gradient almost everywhere; without STE
    the entire backward pass through a quantized matmul is DCE'd to zero
    (observed in the dry-run HLO). STE is the standard fake-quant training
    rule and is a no-op for inference-only graphs.
    """
    return x + jax.lax.stop_gradient(x_hat - x)


def quantize_activation(x: jnp.ndarray, cfg: QuantConfig, axis: int = -1) -> jnp.ndarray:
    fmt = cfg.format()
    if fmt is None or cfg.weights_only:
        return x
    return _ste(x, fmt.qdq(x, axis=axis))


def quantize_weight(w: jnp.ndarray, cfg: QuantConfig, axis: int = 0) -> jnp.ndarray:
    fmt = cfg.format()
    if fmt is None or cfg.offline_weights:
        return w
    return _ste(w, fmt.qdq(w, axis=axis))


def packable_contract_axes(key: str, ndim: int):
    """Contraction axes of a STACKED block weight (leading axis = layers).

    attn wo is (L, H, Dh, d) -> contract (H, Dh); every other weight
    (incl. the 3-D stacked mlp wo (L, f, d)) contracts its axis 1.
    """
    if key == "wo" and ndim == 4:
        return (1, 2)
    return (1,) if ndim >= 3 else (0,)


def _qdq_along(w, fmt, ca: tuple):
    """QDQ ``w`` along contraction axes ``ca`` (multi-axis = attn wo:
    flatten, qdq, restore). Returns ``w`` unchanged when the contraction
    is not a whole number of 64-groups."""
    import numpy as np

    if len(ca) == 1:
        if w.shape[ca[0]] % hif4.GROUP_SIZE:
            return w
        return fmt.qdq(w, axis=ca[0])
    lead = w.shape[: ca[0]]
    k_flat = int(np.prod([w.shape[a] for a in ca]))
    if k_flat % hif4.GROUP_SIZE:
        return w
    w2 = w.reshape(lead + (k_flat,) + w.shape[ca[-1] + 1 :])
    out = fmt.qdq(w2, axis=len(lead))
    return out.reshape(w.shape)


def quantize_params_offline(params, cfg: QuantConfig, *, contract_axis: int = 0,
                            plan=None, prefix: str = ""):
    """One-time offline weight PTQ: QDQ exactly the matmul weights, along
    their true contraction axes. Use with ``offline_weights=True`` at
    serve time.

    With ``plan`` (a resolved :class:`repro.core.policy.QuantPlan`) and
    ``prefix`` (the collection this subtree sits under, e.g. "blocks"),
    per-site decisions — WHICH sites quantize, to WHAT format, along
    which axes — come from the plan; this is the same resolution
    ``prepare_params_for_serving`` packs from, so the two predicates can
    never drift. Without a plan, the legacy global-config behavior: the
    default packable-site rules (``repro.core.policy.default_offline_axes``)
    with ``cfg.fmt`` everywhere. ``PackedW`` leaves pass through untouched
    (packing already IS the offline quantization).
    """
    from repro.core.policy import default_offline_axes

    fmt = cfg.format()
    if fmt is None and plan is None:
        return params

    def q(path, w):
        if isinstance(w, PackedW):
            return w
        key = None
        for part in reversed(path):
            k = getattr(part, "key", None)
            if isinstance(k, str):
                key = k
                break
        if plan is not None:
            parts = [getattr(p, "key") for p in path
                     if isinstance(getattr(p, "key", None), str)]
            site_path = ".".join(([prefix] if prefix else []) + parts)
            site = plan.get(site_path)
            if site is None or site.packed or not site.quantize_offline:
                return w
            site_fmt = site.cfg.format()
            if site_fmt is None:
                return w
            return _qdq_along(w, site_fmt, site.contract_axes)
        ca = default_offline_axes(key, w.ndim)
        if ca is None:
            return w
        return _qdq_along(w, fmt, ca)

    return jax.tree_util.tree_map_with_path(
        q, params, is_leaf=lambda x: isinstance(x, PackedW))


def qmatmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: QuantConfig = NO_QUANT,
    *,
    contract_x: int = -1,
    contract_w: int = 0,
    precision=None,
    accum_dtype=None,
    shard=None,
) -> jnp.ndarray:
    """``x @ w`` with both operands cast to ``cfg.fmt`` along contraction.

    Routes through :func:`repro.core.engine.matmul`, so ``cfg.impl`` picks
    the execution path (qdq / packed / pallas) and ``w`` may be a dense
    array or a :class:`PackedW`. Shapes: x (..., K) contracted with
    w (K, ...); arbitrary contract axes via ``contract_x`` / ``contract_w``.
    WHICH sites quantize (embedding/LM-head/router excluded by default —
    paper SS IV) is per-site policy, resolved by repro.core.policy and
    passed in as ``cfg``. ``shard`` is the ShardCtx packed dequantization
    gathers under (None = unsharded).

    ``accum_dtype`` is the dot OUTPUT dtype (default: x.dtype). The MXU
    accumulates f32 internally either way; emitting bf16 makes the
    TP partial-sum all-reduce move bf16 on the wire (measured 2x wire
    reduction per layer; the cross-shard rounding noise is the standard
    Megatron-TP trade). lm_logits requests f32 explicitly.
    """
    from repro.core import engine

    ectx = engine.EngineCtx(quant=cfg) if shard is None else engine.EngineCtx(
        quant=cfg, shard=shard
    )
    return engine.matmul(x, w, ectx, contract_x=contract_x,
                         contract_w=contract_w, precision=precision,
                         accum_dtype=accum_dtype)


# ---------------------------------------------------------------------------
# Fixed-point dot product (paper Eq. 3 / Fig. 4) — reference-level
# ---------------------------------------------------------------------------


def hif4_dot_fixed_point(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """64-length dot via the paper's integer compute flow.

    Quantizes both 64-vectors to HiF4, absorbs micro-exponents into int8
    elements, accumulates in int32, and applies the single floating-point
    scale multiply at the end. Bit-identical to the dequantized dot
    (verified in tests): the hardware flow loses nothing.
    """
    ga = hif4.quantize_groups(a.reshape(-1, hif4.GROUP_SIZE))
    gb = hif4.quantize_groups(b.reshape(-1, hif4.GROUP_SIZE))
    ia, sa = hif4.to_absorbed_int(ga)
    ib, sb = hif4.to_absorbed_int(gb)
    acc = jnp.sum(ia.astype(jnp.int32) * ib.astype(jnp.int32), axis=-1)
    return jnp.sum(sa * sb * acc.astype(jnp.float32))


# ---------------------------------------------------------------------------
# In-graph packed weights (serving deployment artifact, 4.5 bits/value)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedW:
    """A weight stored as HiF4 packed buffers, usable wherever the models
    pass a dense weight: ``dense(x, packed_w)`` dequantizes in-graph.

    Two layouts carry the same bits (docs/FORMATS.md):

    * artifact (``kernel_layout=False``) — output-major, the on-disk /
      checkpoint shape:
          codes (N, K/64, 32) uint8    two 4-bit S1P2 codes per byte
          meta  (N, K/64)     uint32   E6M2<<24 | E1_8<<16 | E1_16
    * kernel (``kernel_layout=True``) — K-major 2-D, what the fused
      dequantize-in-kernel matmul tiles over (contraction rows innermost):
          codes (K/2, N)  uint8        meta (K/64, N) uint32

    Either way = 0.5625 bytes/value vs 2 (bf16): 3.56x less HBM residency
    AND 3.56x less wire when FSDP-sharded weights are all-gathered at use —
    the paper's 4.5-bit storage applied to the serving memory/collective
    roofline terms. ``to_kernel_layout`` transposes the payload ONCE
    (serving prep), so the decode hot path never re-lays-out per step.

    Stacked-layer weights carry one extra leading L axis on both buffers
    (``lax.scan`` over layers slices it off before any matmul sees them).

    ``shape2d`` = (K, N). ``reshape`` validates-and-passes-through so the
    models' ``w.reshape(d, -1)`` call sites work unchanged.
    """

    codes: jnp.ndarray
    meta: jnp.ndarray
    shape2d: tuple
    dtype: Any = jnp.bfloat16
    axes2d: tuple = (None, None)     # (out logical axis, contract logical axis)
    kernel_layout: bool = False

    def tree_flatten(self):
        return (self.codes, self.meta), (self.shape2d, self.dtype, self.axes2d,
                                         self.kernel_layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def to_kernel_layout(self) -> "PackedW":
        """One-time re-layout artifact -> K-major kernel buffers (same bits).

        Accepts 2-D weights and stacked-layer weights (one leading L axis).
        """
        if self.kernel_layout:
            return self
        k, n = self.shape2d
        lead = self.codes.shape[:-3]
        codes = jnp.swapaxes(
            self.codes.reshape(lead + (n, k // 2)), -1, -2)      # (.., K/2, N)
        meta = jnp.swapaxes(self.meta, -1, -2)                   # (.., K/64, N)
        return PackedW(codes, meta, self.shape2d, self.dtype, self.axes2d,
                       kernel_layout=True)

    def kernel_operands(self, shard=None):
        """(codes_km (K/2, N) uint8, meta_km (K/64, N) uint32) for the fused
        matmul. Kernel-layout weights hand over their resident buffers;
        artifact-layout weights re-layout in-graph (correct but per-call —
        serving pre-converts via :meth:`to_kernel_layout`). ``shard``
        constrains the gather to move the 4.5-bit payload, as in
        :meth:`dequantize`."""
        kw = self.to_kernel_layout()
        codes, meta = kw.codes, kw.meta
        assert codes.ndim == 2, (
            f"kernel_operands needs a per-layer slice, got codes {codes.shape}")
        if shard is not None and shard.mesh is not None:
            out_name = self.axes2d[0]
            codes = shard.constrain(codes, None, out_name)
            meta = shard.constrain(meta, None, out_name)
        return codes, meta

    def reshape(self, *shape):
        """Validate-and-pass-through: the models' ``w.reshape(d, -1)`` /
        ``w.reshape(-1, d)`` call sites must resolve to exactly the packed
        layout (K, N); anything else would silently contract wrong axes."""
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        k, n = self.shape2d
        assert len(shape) == 2, f"PackedW.reshape{shape}: packed layout is 2-D"
        assert sum(1 for s in shape if s == -1) <= 1, shape
        known = 1
        for s in shape:
            if s != -1:
                known *= s
        if -1 in shape:
            assert known != 0 and (k * n) % known == 0, (shape, self.shape2d)
            resolved = tuple(s if s != -1 else (k * n) // known for s in shape)
        else:
            resolved = tuple(shape)
        assert resolved == (k, n), (
            f"PackedW.reshape{shape} resolved to {resolved}, "
            f"but the packed layout is (K, N) = {self.shape2d}"
        )
        return self

    @property
    def ndim(self):
        return 2

    @classmethod
    def from_dense(cls, w: jnp.ndarray, contract_axes=(0,)) -> "PackedW":
        """Quantize + pack a dense weight (offline PTQ)."""
        import numpy as np

        nd = w.ndim
        contract_axes = tuple(a % nd for a in contract_axes)
        out_axes = tuple(a for a in range(nd) if a not in contract_axes)
        k = int(np.prod([w.shape[a] for a in contract_axes]))
        n = int(np.prod([w.shape[a] for a in out_axes])) if out_axes else 1
        wt = jnp.transpose(w, out_axes + contract_axes).reshape(n, k)
        assert k % hif4.GROUP_SIZE == 0, (w.shape, contract_axes)
        groups = wt.reshape(n, k // hif4.GROUP_SIZE, hif4.GROUP_SIZE)
        packed = hif4.pack_groups(hif4.quantize_groups(groups.astype(jnp.float32)))
        return cls(packed.codes, packed.meta, (k, n), w.dtype)

    def dequantize(self, shard=None) -> jnp.ndarray:
        """Expand to the (K, N) dense weight in-graph.

        ``shard`` is the ShardCtx of the enclosing computation (threaded by
        the execution engine from the model context); with a mesh attached
        it constrains the gather to move the 4.5-bit payload.
        """
        k, n = self.shape2d
        if self.kernel_layout:
            # K-major buffers reconstruct straight to (K, N): integer
            # shifts instead of per-element exp2, and no final transpose.
            codes, meta = self.kernel_operands(shard=shard)
            return hif4.dequantize_km(codes, meta, self.dtype)
        codes, meta = self.codes, self.meta
        if shard is not None and shard.mesh is not None:
            # Gather the 4.5-bit payload, not the dequantized bf16 weight:
            # replicate the contract-group axis (the FSDP axis) while
            # keeping the out axis TP-sharded, THEN dequantize locally.
            # Without this XLA dequantizes on-shard and all-gathers the
            # 16/32-bit result (measured: no wire saving at all).
            out_name = self.axes2d[0]
            codes = shard.constrain(codes, out_name, None, None)
            meta = shard.constrain(meta, out_name, None)
        vals = hif4.dequantize_groups(
            hif4.unpack_groups(hif4.HiF4Packed(codes, meta))
        )
        return vals.reshape(n, k).T.astype(self.dtype)       # (K, N)

    @property
    def nbytes_packed(self) -> int:
        """Bytes of 4.5-bit payload actually resident (codes + meta)."""
        import numpy as np

        return int(np.prod(self.codes.shape)) + 4 * int(np.prod(self.meta.shape))

    @property
    def n_values(self) -> int:
        import numpy as np

        # total code bytes = lead * N * K/2 in either layout
        return int(np.prod(self.codes.shape)) * 2
