"""Quantized linear algebra: the integration point between formats and models.

Three execution paths, all numerically anchored to the same format modules:

* ``qdq``     — fake-quant both operands, matmul in bf16/f32. Lowers on any
                backend; used for accuracy experiments and the dry-run.
* ``packed``  — weights stored as HiF4 bit-packed buffers (4.5 bits/value in
                HBM); dequantized group-wise inside the jitted graph. This is
                the deployment artifact that shrinks the memory roofline term.
* ``pallas``  — repro.kernels.bfp_matmul: the paper's SS III.B fixed-point
                flow on the MXU int8 path (TPU target; interpret-mode on CPU).

Quantization always happens along the contraction dimension (each 64-element
HiF4 group lies along K), matching how a 64-length PE dot consumes the data.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import hif4
from repro.core.formats import BFPFormat, get_format
from repro.core.grouping import from_groups, to_groups


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How matmuls inside models are quantized.

    fmt             : 'hif4' | 'nvfp4' | 'nvfp4_pts' | 'mxfp4' | 'none'
    weights_only    : quantize only the weight operand (GPT-OSS style)
    offline_weights : weights were already quantized once offline (PTQ
                      deployment); skip the in-graph weight QDQ and only
                      cast activations dynamically. Inference graphs use
                      this — re-quantizing static weights every serve step
                      would be pure waste on hardware too.
    impl            : 'qdq' | 'packed' | 'pallas'
    """

    fmt: str = "none"
    weights_only: bool = False
    offline_weights: bool = False
    impl: str = "qdq"

    @property
    def enabled(self) -> bool:
        return get_format(self.fmt) is not None

    def format(self) -> Optional[BFPFormat]:
        return get_format(self.fmt)


NO_QUANT = QuantConfig()


def _ste(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward = x_hat, backward = identity.

    The rounding inside QDQ has zero gradient almost everywhere; without STE
    the entire backward pass through a quantized matmul is DCE'd to zero
    (observed in the dry-run HLO). STE is the standard fake-quant training
    rule and is a no-op for inference-only graphs.
    """
    return x + jax.lax.stop_gradient(x_hat - x)


def quantize_activation(x: jnp.ndarray, cfg: QuantConfig, axis: int = -1) -> jnp.ndarray:
    fmt = cfg.format()
    if fmt is None or cfg.weights_only:
        return x
    return _ste(x, fmt.qdq(x, axis=axis))


def quantize_weight(w: jnp.ndarray, cfg: QuantConfig, axis: int = 0) -> jnp.ndarray:
    fmt = cfg.format()
    if fmt is None or cfg.offline_weights:
        return w
    return _ste(w, fmt.qdq(w, axis=axis))


# Block-weight keys eligible for offline PTQ / packing, and their
# contraction axes (leading axis = stacked layers). Biases, norms, router
# and scalar state are excluded (paper §IV placement).
PACKABLE_KEYS = {"wq", "wk", "wv", "wo", "wg", "wu", "wi",
                 "w_z", "w_x", "w_b", "w_c", "w_dt", "w_out"}


def packable_contract_axes(key: str, ndim: int):
    """Contraction axes of a STACKED block weight (leading axis = layers).

    attn wo is (L, H, Dh, d) -> contract (H, Dh); every other weight
    (incl. the 3-D stacked mlp wo (L, f, d)) contracts its axis 1.
    """
    if key == "wo" and ndim == 4:
        return (1, 2)
    return (1,) if ndim >= 3 else (0,)


def quantize_params_offline(params, cfg: QuantConfig, *, contract_axis: int = 0):
    """One-time offline weight PTQ: QDQ exactly the matmul weights, along
    their true contraction axes (same rules as the packed path). Use with
    ``offline_weights=True`` at serve time.
    """
    fmt = cfg.format()
    if fmt is None:
        return params

    def q(path, w):
        key = None
        for part in reversed(path):
            k = getattr(part, "key", None)
            if isinstance(k, str):
                key = k
                break
        if key not in PACKABLE_KEYS or w.ndim < 2:
            return w
        ca = packable_contract_axes(key, w.ndim)
        if len(ca) == 1:
            if w.shape[ca[0]] % hif4.GROUP_SIZE:
                return w
            return fmt.qdq(w, axis=ca[0])
        # multi-axis contraction (attn wo): flatten, qdq, restore
        import numpy as np

        lead = w.shape[: ca[0]]
        k_flat = int(np.prod([w.shape[a] for a in ca]))
        if k_flat % hif4.GROUP_SIZE:
            return w
        w2 = w.reshape(lead + (k_flat,) + w.shape[ca[-1] + 1 :])
        out = fmt.qdq(w2, axis=len(lead))
        return out.reshape(w.shape)

    return jax.tree_util.tree_map_with_path(q, params)


def qmatmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: QuantConfig = NO_QUANT,
    *,
    contract_x: int = -1,
    contract_w: int = 0,
    precision=None,
    accum_dtype=None,
) -> jnp.ndarray:
    """``x @ w`` with both operands cast to ``cfg.fmt`` along contraction.

    Shapes: x (..., K) contracted with w (K, ...); arbitrary contract axes
    via ``contract_x`` / ``contract_w``. Embedding/LM-head/router callers
    simply pass cfg=NO_QUANT (paper SS IV exclusions).

    ``accum_dtype`` is the dot OUTPUT dtype (default: x.dtype). The MXU
    accumulates f32 internally either way; emitting bf16 makes the
    TP partial-sum all-reduce move bf16 on the wire (measured 2x wire
    reduction per layer; the cross-shard rounding noise is the standard
    Megatron-TP trade). lm_logits requests f32 explicitly.
    """
    out_dtype = x.dtype
    if cfg.enabled:
        x = quantize_activation(x, cfg, axis=contract_x)
        w = quantize_weight(w, cfg, axis=contract_w)
    cx = contract_x % x.ndim
    cw = contract_w % w.ndim
    y = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((cx,), (cw,)), ((), ())),
        precision=precision,
        preferred_element_type=accum_dtype or out_dtype,
    )
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# Packed-weight path: real 4.5 bits/value residency
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedHiF4Weight:
    """A weight matrix stored as HiF4 packed buffers.

    ``codes`` (G, 32) uint8 and ``meta`` (G,) uint32 where G = prod(shape
    with K replaced by K/64); logical shape + contraction axis retained so
    the weight can be dequantized back in-graph.
    """

    codes: jnp.ndarray
    meta: jnp.ndarray
    shape: tuple
    contract_axis: int
    dtype: jnp.dtype

    def tree_flatten(self):
        return (self.codes, self.meta), (self.shape, self.contract_axis, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, meta = children
        return cls(codes, meta, *aux)

    @classmethod
    def from_dense(cls, w: jnp.ndarray, contract_axis: int = 0) -> "PackedHiF4Weight":
        groups, orig = to_groups(w.astype(jnp.float32), contract_axis, hif4.GROUP_SIZE)
        assert orig == w.shape[contract_axis], "contraction dim must be padded-free"
        packed = hif4.pack_groups(hif4.quantize_groups(groups))
        return cls(
            codes=packed.codes,
            meta=packed.meta,
            shape=tuple(w.shape),
            contract_axis=contract_axis % w.ndim,
            dtype=w.dtype,
        )

    def dequantize(self) -> jnp.ndarray:
        vals = hif4.dequantize_groups(
            hif4.unpack_groups(hif4.HiF4Packed(self.codes, self.meta))
        )
        w = from_groups(vals, self.contract_axis, self.shape[self.contract_axis])
        return w.astype(self.dtype)

    @property
    def nbytes_packed(self) -> int:
        import numpy as np

        return int(np.prod(self.codes.shape)) + 4 * int(np.prod(self.meta.shape))


def packed_matmul(
    x: jnp.ndarray,
    w_packed: PackedHiF4Weight,
    cfg: QuantConfig,
    *,
    contract_x: int = -1,
) -> jnp.ndarray:
    """Activation (dynamically quantized) x packed HiF4 weight."""
    w = w_packed.dequantize()
    x = quantize_activation(x, cfg, axis=contract_x)
    cx = contract_x % x.ndim
    y = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((cx,), (w_packed.contract_axis,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Fixed-point dot product (paper Eq. 3 / Fig. 4) — reference-level
# ---------------------------------------------------------------------------


def hif4_dot_fixed_point(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """64-length dot via the paper's integer compute flow.

    Quantizes both 64-vectors to HiF4, absorbs micro-exponents into int8
    elements, accumulates in int32, and applies the single floating-point
    scale multiply at the end. Bit-identical to the dequantized dot
    (verified in tests): the hardware flow loses nothing.
    """
    ga = hif4.quantize_groups(a.reshape(-1, hif4.GROUP_SIZE))
    gb = hif4.quantize_groups(b.reshape(-1, hif4.GROUP_SIZE))
    ia, sa = hif4.to_absorbed_int(ga)
    ib, sb = hif4.to_absorbed_int(gb)
    acc = jnp.sum(ia.astype(jnp.int32) * ib.astype(jnp.int32), axis=-1)
    return jnp.sum(sa * sb * acc.astype(jnp.float32))


# ---------------------------------------------------------------------------
# In-graph packed weights (serving deployment artifact, 4.5 bits/value)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedW:
    """A weight stored as HiF4 packed buffers, usable wherever the models
    pass a dense weight: ``dense(x, packed_w)`` dequantizes in-graph.

    Layout: contraction flattened to K (64-groups), outputs flattened to N:
        codes (N, K/64, 32) uint8    two 4-bit S1P2 codes per byte
        meta  (N, K/64)     uint32   E6M2<<24 | E1_8<<16 | E1_16
    = 0.5625 bytes/value vs 2 (bf16): 3.56x less HBM residency AND 3.56x
    less wire when FSDP-sharded weights are all-gathered at use — the
    paper's 4.5-bit storage applied to the serving memory/collective
    roofline terms.

    ``shape2d`` = (K, N). ``reshape`` validates-and-passes-through so the
    models' ``w.reshape(d, -1)`` call sites work unchanged.
    """

    codes: jnp.ndarray
    meta: jnp.ndarray
    shape2d: tuple
    dtype: Any = jnp.bfloat16
    axes2d: tuple = (None, None)     # (out logical axis, contract logical axis)

    def tree_flatten(self):
        return (self.codes, self.meta), (self.shape2d, self.dtype, self.axes2d)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def reshape(self, *shape):
        if len(shape) == 1:
            shape = shape[0]
        k, n = self.shape2d
        import numpy as np

        want = [k if s != -1 else -1 for s in shape]
        assert int(np.prod([s for s in shape if s != -1])) in (k, n, k * n) or True
        return self

    @property
    def ndim(self):
        return 2

    @classmethod
    def from_dense(cls, w: jnp.ndarray, contract_axes=(0,)) -> "PackedW":
        """Quantize + pack a dense weight (offline PTQ)."""
        import numpy as np

        nd = w.ndim
        contract_axes = tuple(a % nd for a in contract_axes)
        out_axes = tuple(a for a in range(nd) if a not in contract_axes)
        k = int(np.prod([w.shape[a] for a in contract_axes]))
        n = int(np.prod([w.shape[a] for a in out_axes])) if out_axes else 1
        wt = jnp.transpose(w, out_axes + contract_axes).reshape(n, k)
        assert k % hif4.GROUP_SIZE == 0, (w.shape, contract_axes)
        groups = wt.reshape(n, k // hif4.GROUP_SIZE, hif4.GROUP_SIZE)
        packed = hif4.pack_groups(hif4.quantize_groups(groups.astype(jnp.float32)))
        return cls(packed.codes, packed.meta, (k, n), w.dtype)

    def dequantize(self) -> jnp.ndarray:
        k, n = self.shape2d
        codes, meta = self.codes, self.meta
        shard = _PACKED_SHARD[0]
        if shard is not None and shard.mesh is not None:
            # Gather the 4.5-bit payload, not the dequantized bf16 weight:
            # replicate the contract-group axis (the FSDP axis) while
            # keeping the out axis TP-sharded, THEN dequantize locally.
            # Without this XLA dequantizes on-shard and all-gathers the
            # 16/32-bit result (measured: no wire saving at all).
            out_name = self.axes2d[0]
            codes = shard.constrain(codes, out_name, None, None)
            meta = shard.constrain(meta, out_name, None)
        vals = hif4.dequantize_groups(
            hif4.unpack_groups(hif4.HiF4Packed(codes, meta))
        )
        return vals.reshape(n, k).T.astype(self.dtype)       # (K, N)


# ShardCtx hook for PackedW.dequantize (set by launch/runtime code before
# tracing; module-level because dense() call sites don't thread ShardCtx)
_PACKED_SHARD = [None]
