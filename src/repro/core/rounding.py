"""Low-level rounding/encoding primitives for BFP formats.

Everything here is pure jnp, jit-able, and uses round-to-nearest-even (RNE)
as the paper prescribes ("round-half-to-even or round-half-away-from-zero";
we standardize on RNE, which is what ``jnp.round`` implements).

Value-level convention: quantizers take float32 arrays and return float32
arrays holding the *exact representable value* of the target format
("fake quant" / QDQ). Separate encode/decode helpers map values <-> bit
patterns for the packed-storage path.
"""
from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Generic helpers
# ---------------------------------------------------------------------------


def round_bf16(x: jnp.ndarray) -> jnp.ndarray:
    """Round float32 -> nearest bfloat16 (RNE), returned as float32."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _binade_exponent(ax: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(ax)) computed exactly via frexp; ax must be > 0 where used."""
    _, e = jnp.frexp(ax)  # ax = m * 2**e, m in [0.5, 1)
    return e - 1


def _rne_on_quantum(ax: jnp.ndarray, quantum: jnp.ndarray) -> jnp.ndarray:
    """Round |x| to the nearest multiple of ``quantum`` (RNE)."""
    return jnp.round(ax / quantum) * quantum


# ---------------------------------------------------------------------------
# S1P2  (HiF4 in-group element: sign-magnitude, 1 integer + 2 fraction bits)
# grid: +-{0.00, 0.25, ..., 1.75}
# ---------------------------------------------------------------------------

S1P2_MAX = 1.75
S1P2_STEP = 0.25


def quantize_s1p2(x: jnp.ndarray) -> jnp.ndarray:
    q = jnp.round(x / S1P2_STEP) * S1P2_STEP
    return jnp.clip(q, -S1P2_MAX, S1P2_MAX)


def encode_s1p2(v: jnp.ndarray) -> jnp.ndarray:
    """Value on the S1P2 grid -> 4-bit code (uint8): sign<<3 | quarters."""
    sign = (v < 0) | ((v == 0) & (jnp.signbit(v)))
    mag = jnp.round(jnp.abs(v) / S1P2_STEP).astype(jnp.uint8)
    return (sign.astype(jnp.uint8) << 3) | mag


def decode_s1p2(code: jnp.ndarray) -> jnp.ndarray:
    sign = jnp.where((code >> 3) & 1, -1.0, 1.0)
    mag = (code & 0x7).astype(jnp.float32) * S1P2_STEP
    return sign * mag


def s1p2_to_int(v: jnp.ndarray) -> jnp.ndarray:
    """Value on the S1P2 grid -> signed integer quarters in [-7, 7]."""
    return jnp.round(v / S1P2_STEP).astype(jnp.int8)


# ---------------------------------------------------------------------------
# E2M1  (MXFP4 / NVFP4 in-group element)
# grid: +-{0, 0.5, 1, 1.5, 2, 3, 4, 6}
# ---------------------------------------------------------------------------

E2M1_MAX = 6.0
E2M1_VALUES = (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0)


def quantize_e2m1(x: jnp.ndarray) -> jnp.ndarray:
    ax = jnp.abs(x)
    safe = jnp.maximum(ax, 2.0 ** -20)  # avoid frexp(0); result unaffected
    eb = jnp.clip(_binade_exponent(safe), 0, 2)
    quantum = jnp.ldexp(jnp.float32(1.0), eb - 1)
    q = jnp.minimum(_rne_on_quantum(ax, quantum), E2M1_MAX)
    return jnp.where(x < 0, -q, q)


def encode_e2m1(v: jnp.ndarray) -> jnp.ndarray:
    """Value on E2M1 grid -> 4-bit code: sign<<3 | 3-bit (e,m) code 0..7."""
    av = jnp.abs(v)
    idx = jnp.zeros(v.shape, jnp.uint8)
    for i, val in enumerate(E2M1_VALUES):
        idx = jnp.where(av == val, jnp.uint8(i), idx)
    sign = (v < 0).astype(jnp.uint8)
    return (sign << 3) | idx


def decode_e2m1(code: jnp.ndarray) -> jnp.ndarray:
    table = jnp.asarray(E2M1_VALUES, jnp.float32)
    mag = table[(code & 0x7).astype(jnp.int32)]
    return jnp.where((code >> 3) & 1, -mag, mag)


def e2m1_to_int(v: jnp.ndarray) -> jnp.ndarray:
    """Value on E2M1 grid -> signed integer halves in [-12, 12] (S3P1 flow)."""
    return jnp.round(v / 0.5).astype(jnp.int8)


# ---------------------------------------------------------------------------
# FP8 E4M3 (OCP "FN" variant used by NVFP4 scales)
# bias 7, normals 2^-6..448, subnormals down to 2^-9, no inf, NaN = S.1111.111
# ---------------------------------------------------------------------------

E4M3_MAX = 448.0
E4M3_MIN_NORMAL = 2.0 ** -6
E4M3_MIN_SUBNORMAL = 2.0 ** -9


def round_e4m3(x: jnp.ndarray, saturate: bool = True) -> jnp.ndarray:
    ax = jnp.abs(x)
    safe = jnp.maximum(ax, 2.0 ** -40)
    eb = jnp.clip(_binade_exponent(safe), -6, 8)
    quantum = jnp.ldexp(jnp.float32(1.0), eb - 3)
    q = _rne_on_quantum(ax, quantum)
    q = jnp.minimum(q, E4M3_MAX) if saturate else q
    return jnp.where(x < 0, -q, q)


# ---------------------------------------------------------------------------
# Unsigned FP8 E6M2 (HiF4 level-1 scale)
# bias 48, exponent in [-48, 15], hidden bit 1, no zero/inf/subnormals.
# Encoding 0b111111_11 is NaN, so the max *value* is 2^15 * 1.50.
# ---------------------------------------------------------------------------

E6M2_BIAS = 48
E6M2_MIN = 2.0 ** -48            # 000000_00
E6M2_MAX = (2.0 ** 15) * 1.50    # 111111_10 (111111_11 is NaN)
E6M2_NAN_BITS = 0xFF


def round_e6m2(x: jnp.ndarray) -> jnp.ndarray:
    """Round positive float32 -> nearest representable E6M2 value.

    Values below the minimum clamp to 2^-48 (format has no zero); values
    above the max clamp to 2^15*1.5 (the all-ones pattern is NaN, never
    produced here).
    """
    ax = jnp.maximum(jnp.abs(x), E6M2_MIN)
    eb = jnp.clip(_binade_exponent(ax), -E6M2_BIAS, 15)
    quantum = jnp.ldexp(jnp.float32(1.0), eb - 2)
    q = _rne_on_quantum(ax, quantum)
    return jnp.clip(q, E6M2_MIN, E6M2_MAX)


def encode_e6m2(v: jnp.ndarray) -> jnp.ndarray:
    """Value on the E6M2 grid -> 8-bit code (uint8): (e+48)<<2 | m."""
    eb = _binade_exponent(v)
    m = jnp.round((v / jnp.ldexp(jnp.float32(1.0), eb) - 1.0) * 4.0)
    return ((eb + E6M2_BIAS).astype(jnp.uint8) << 2) | m.astype(jnp.uint8)


def decode_e6m2(code: jnp.ndarray) -> jnp.ndarray:
    eb = (code >> 2).astype(jnp.int32) - E6M2_BIAS
    m = (code & 0x3).astype(jnp.float32)
    val = jnp.ldexp(jnp.float32(1.0), eb) * (1.0 + m * 0.25)
    return jnp.where(code == E6M2_NAN_BITS, jnp.nan, val)


def e6m2_reciprocal_bf16(v: jnp.ndarray) -> jnp.ndarray:
    """The paper's E6M2_REC_to_BF16 instruction.

    Hardware realizes it as a 4-entry LUT on the mantissa plus exponent
    subtraction; numerically identical to RNE(1/v) in bf16 because 1/1.M
    has the same bf16 rounding for all four mantissas (verified in tests).
    """
    return round_bf16(1.0 / v)


# ---------------------------------------------------------------------------
# E8M0 power-of-two scale (MXFP4 shared exponent, OCP MX spec)
# ---------------------------------------------------------------------------

E8M0_EXP_MIN = -127
E8M0_EXP_MAX = 127


def e8m0_scale_from_amax(amax: jnp.ndarray, element_emax: int = 2) -> jnp.ndarray:
    """OCP MX shared scale: 2^(floor(log2(amax)) - emax_elem), clamped.

    ``element_emax`` is the exponent of the element format's max value
    (E2M1 max = 6 -> emax 2). amax == 0 maps to scale 1.
    """
    safe = jnp.maximum(amax, 2.0 ** -126)
    e = _binade_exponent(safe) - element_emax
    e = jnp.clip(e, E8M0_EXP_MIN, E8M0_EXP_MAX)
    scale = jnp.ldexp(jnp.float32(1.0), e)
    return jnp.where(amax > 0, scale, 1.0)
