"""Per-site activation tap: capture the inputs quantized matmuls consume.

The calibration probe (``repro.calibrate.probe``) needs, for every
quantization site the policy governs, the REAL activation rows that
site's contraction reads — GPTQ Hessians and output-error scores are
only meaningful for the layer's true input distribution. Rather than
re-implementing each family's forward with capture plumbing (the route
``benchmarks/llm_accuracy.py`` took for the dense transformer), the tap
rides the existing per-site config path:

* :meth:`repro.models.common.ModelCtx.site_quant` MARKS the tap with the
  resolved site path (it is evaluated as an argument of the very dense()/
  qbmm call whose input we want);
* the engine funnel (``repro.core.engine.matmul`` / ``qdq_einsum``)
  CONSUMES the pending mark and records the activation operand, flattened
  to ``(rows, K)`` along the contraction axis.

Because every model-side linear goes through the funnel, the same two
hooks cover dense, MoE (batched-expert einsums), and Mamba projections
without touching a single call site.

Capture is host-side and CONCRETE-ONLY: the probe runs its forward under
``jax.disable_jit()`` so ``lax.scan`` executes eagerly and the stacked
block sites record one entry per layer, in layer order (entry ``b*L + l``
of a site's record list is batch ``b``, layer ``l``). A tap reached by a
tracer raises instead of silently recording nothing. Expected contraction
widths (``expect_k``) guard against mis-attribution from a stale mark: a
``site_quant`` call with no following matmul (e.g. a dispatch probe)
leaves a pending path that the next funnel entry would otherwise adopt.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import numpy as np

_ACTIVE: Optional["ActivationTap"] = None


class ActivationTap:
    """Accumulates per-site activation rows during an eager forward.

    ``expect_k`` maps site path -> contraction width K; records whose
    flattened row width disagrees are dropped (stale-mark guard).
    ``max_rows`` caps the rows kept per record (deterministic stride
    subsample) so long prompts don't balloon host memory.
    """

    def __init__(self, expect_k: Optional[dict] = None, max_rows: int = 512):
        self.expect_k = dict(expect_k or {})
        self.max_rows = max_rows
        self.records: dict = {}      # path -> [np.ndarray (rows, K), ...]
        self._pending: Optional[str] = None

    # -- mark/consume handshake (trace-order, eager-only capture) ----------

    def mark(self, path: str) -> None:
        self._pending = path

    def consume(self, x, contract_axis: int) -> None:
        path, self._pending = self._pending, None
        if path is None:
            return
        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                "ActivationTap reached by a tracer — run the probe forward "
                "under jax.disable_jit() (capture is host-side and eager)")
        rows = np.moveaxis(np.asarray(x, np.float32), contract_axis, -1)
        rows = rows.reshape(-1, rows.shape[-1])
        want = self.expect_k.get(path)
        if want is not None and rows.shape[1] != want:
            return                     # stale mark: widths disagree, drop
        if rows.shape[0] > self.max_rows:
            stride = -(-rows.shape[0] // self.max_rows)
            rows = rows[::stride]
        self.records.setdefault(path, []).append(rows)

    # -- probe-side accessors ---------------------------------------------

    def paths(self) -> list:
        return sorted(self.records)

    def rows(self, path: str, layer: Optional[int] = None,
             n_layers: int = 1) -> np.ndarray:
        """Pooled ``(n, K)`` rows for ``path``. Stacked sites record one
        entry per layer per forward (layer-major within a forward, see
        module docstring); ``layer``/``n_layers`` select one layer's
        entries, ``layer=None`` pools all of them."""
        recs = self.records[path]
        if layer is not None:
            recs = recs[layer::n_layers]
        return np.concatenate(recs, axis=0)


def active() -> Optional[ActivationTap]:
    return _ACTIVE


def mark_site(path: str) -> None:
    """no-op unless a tap is installed (the ModelCtx.site_quant hook)."""
    if _ACTIVE is not None:
        _ACTIVE.mark(path)


def consume_pending(x, contract_axis: int) -> None:
    """no-op unless a tap is installed (the engine-funnel hook)."""
    if _ACTIVE is not None:
        _ACTIVE.consume(x, contract_axis)


@contextlib.contextmanager
def capture(t: ActivationTap):
    """Install ``t`` for the duration of a probe forward (not reentrant)."""
    global _ACTIVE
    assert _ACTIVE is None, "an ActivationTap is already installed"
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = None
