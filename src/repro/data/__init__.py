from repro.data.synthetic import SyntheticLMDataset  # noqa: F401
