"""Deterministic synthetic LM data with checkpointable iterator state.

Tokens follow a noisy affine recurrence over the vocabulary, so a language
model can actually learn the stream (loss decreases) while every batch is a
pure function of (seed, step) — which is what makes fault-tolerant resume
EXACTLY reproducible: restoring ``state_dict()`` replays the same stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    step: int = 0                     # iterator state (checkpointable)

    # -- checkpointable state -------------------------------------------------

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        assert int(d["seed"]) == self.seed, "dataset seed mismatch on restore"

    # -- generation -----------------------------------------------------------

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): {"tokens": (B, S) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k0, kn = jax.random.split(key)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        t0 = jax.random.randint(k0, (b,), 0, v)
        # affine recurrence with occasional random jumps
        a, c = 31, 17
        jumps = jax.random.bernoulli(kn, self.noise, (b, s))
        rnd = jax.random.randint(kn, (b, s), 0, v)

        def body(t, i):
            nxt = (a * t + c) % v
            nxt = jnp.where(jumps[:, i], rnd[:, i], nxt)
            return nxt, nxt

        _, toks = jax.lax.scan(body, t0, jnp.arange(s))
        return {"tokens": jnp.moveaxis(toks, 0, 1).astype(jnp.int32)}

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def __iter__(self):
        return self
