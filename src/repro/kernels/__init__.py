# Pallas TPU kernels for the paper's two compute hot-spots:
#   hif4_quant  — BF16 -> HiF4 conversion (Algorithm 1), VPU-tiled
#   bfp_matmul  — 64-group fixed-point dot product (§III.B), MXU int8
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
from repro.kernels import ops, ref  # noqa: F401
