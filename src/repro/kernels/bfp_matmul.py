"""Pallas TPU kernel: HiF4 group-scaled fixed-point matmul (paper §III.B).

The paper's core hardware insight: micro-exponents are left shifts, so a
64-length HiF4 dot is pure integer work with ONE float multiply at the end
(Eq. 3). TPU mapping (DESIGN.md §3): contract each 64-group on the MXU in
int8 (absorbed-shift elements, |q| <= 28; int8 x int8 -> int32 runs at 2x
the bf16 rate on v5e — the same 2x the paper claims for 4-bit PEs), then
apply the single f32 ``a_scale * b_scale`` rescale per (row, col, group)
while accumulating. All 64-groups of a VMEM tile contract in ONE
``dot_general`` with the group axis batched (``_tile_group_dot``) — not a
per-group Python loop of 64-wide dots.

Grid (M/bm, N/bn, K/bk); each VMEM tile holds whole 64-groups (bk % 64 ==
0). The f32 accumulator lives in VMEM across the K-steps of one (i, j)
tile (standard revisiting-output pattern). That revisit pattern silently
relies on K being the INNERMOST grid axis — consecutive grid steps must
revisit the same out_ref block — so the K position is a named module
invariant (``K_GRID_AXIS``) asserted by every host wrapper, not a
convention.

Block sizes default to a per-regime selection (``select_block_sizes``):
decode calls have tiny M (a batch of single tokens) and want all of M with
deep K / wide N tiles; prefill calls have large M and want square-ish MXU
tiles. Pass explicit ``block_*`` to override.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hif4_quant import _fit

GROUP = 64

# The output-revisit accumulator requires the K grid axis to be LAST
# (innermost): pallas iterates the grid in row-major order, so only the
# last axis advances between consecutive steps of one (i, j) output tile.
K_GRID_AXIS = 2

# Decode M (a batch of single-token rows) vs prefill M (batch x seq)
# regime boundary for block selection.
_DECODE_M_MAX = 32


def select_block_sizes(M: int, N: int, K: int) -> tuple[int, int, int]:
    """(bm, bn, bk) per execution regime.

    decode (M <= 32): M doesn't tile — take all of it — and the weight is
    the whole HBM traffic, so deep-K / wide-N tiles maximize payload per
    grid step (fewer revisits, better DMA pipelining).
    prefill (M large): square-ish 256/256/512 MXU tiles, the classic
    compute-bound shape.
    """
    if M <= _DECODE_M_MAX:
        return M, _fit(N, min(512, N), 1), _fit(K, min(1024, K), GROUP)
    return (_fit(M, min(256, M), 1), _fit(N, min(256, N), 1),
            _fit(K, min(512, K), GROUP))


def _tile_group_dot(a, asc, b, bsc):
    """All 64-groups of one VMEM tile in a single batched MXU contraction.

    a (bm, bk) int8, asc (bm, bk/64) f32, b (bk, bn) int8,
    bsc (bk/64, bn) f32 -> (bm, bn) f32: integer dot per group batched over
    the group axis, then the ONE f32 ``a_scale * b_scale`` rescale per
    (row, col, group) while summing groups (Eq. 3 flow).
    """
    bm, bk = a.shape
    bn = b.shape[1]
    g = bk // GROUP
    a3 = a.reshape(bm, g, GROUP)
    b3 = b.reshape(g, GROUP, bn)
    part = jax.lax.dot_general(
        a3, b3,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32,
    )                                                   # (g, bm, bn)
    scaled = part.astype(jnp.float32) * jnp.transpose(asc)[:, :, None] \
        * bsc[:, None, :]
    return jnp.sum(scaled, axis=0)


def _bfp_matmul_kernel(a_ref, as_ref, b_ref, bs_ref, o_ref):
    k_step = pl.program_id(K_GRID_AXIS)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _tile_group_dot(a_ref[...], as_ref[...],
                                  b_ref[...], bs_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def bfp_matmul_quantized(
    a_ints: jax.Array,     # (M, K) int8
    a_scales: jax.Array,   # (M, K/64) f32
    b_ints: jax.Array,     # (K, N) int8
    b_scales: jax.Array,   # (K/64, N) f32
    *,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Group-scaled integer matmul on pre-quantized HiF4 operands -> f32."""
    M, K = a_ints.shape
    K2, N = b_ints.shape
    assert K == K2 and K % GROUP == 0
    abm, abn, abk = select_block_sizes(M, N, K)
    bm = _fit(M, min(block_m, M), 1) if block_m else abm
    bn = _fit(N, min(block_n, N), 1) if block_n else abn
    bk = _fit(K, min(block_k, K), GROUP) if block_k else abk
    grid = (M // bm, N // bn, K // bk)
    # documented invariant: the accumulator revisit pattern needs K innermost
    assert K_GRID_AXIS == len(grid) - 1 and grid[K_GRID_AXIS] == K // bk

    return pl.pallas_call(
        _bfp_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a_ints, a_scales, b_ints, b_scales)
