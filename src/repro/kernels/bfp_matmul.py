"""Pallas TPU kernel: HiF4 group-scaled fixed-point matmul (paper §III.B).

The paper's core hardware insight: micro-exponents are left shifts, so a
64-length HiF4 dot is pure integer work with ONE float multiply at the end
(Eq. 3). TPU mapping (DESIGN.md §3): contract each 64-group on the MXU in
int8 (absorbed-shift elements, |q| <= 28; int8 x int8 -> int32 runs at 2x
the bf16 rate on v5e — the same 2x the paper claims for 4-bit PEs), then
apply the single f32 ``a_scale * b_scale`` rescale per (row, col, group)
while accumulating.

Grid (M/bm, N/bn, K/bk); each VMEM tile holds whole 64-groups (bk % 64 ==
0). The f32 accumulator lives in VMEM across the K-steps of one (i, j)
tile (standard revisiting-output pattern; K must be the innermost grid
axis so out_ref revisits are consecutive).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 64


def _bfp_matmul_kernel(a_ref, as_ref, b_ref, bs_ref, o_ref, *, n_k_steps):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                      # (bm, bk) int8
    b = b_ref[...]                      # (bk, bn) int8
    asc = as_ref[...]                   # (bm, bk/64) f32
    bsc = bs_ref[...]                   # (bk/64, bn) f32
    bm, bk = a.shape
    bn = b.shape[1]
    g = bk // GROUP

    acc = o_ref[...]
    # per 64-group: integer MXU dot + ONE float rescale (Eq. 3 flow)
    for gi in range(g):
        sl = slice(gi * GROUP, (gi + 1) * GROUP)
        part = jax.lax.dot_general(
            a[:, sl], b[sl, :],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + part.astype(jnp.float32) * asc[:, gi][:, None] * bsc[gi, :][None, :]
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def bfp_matmul_quantized(
    a_ints: jax.Array,     # (M, K) int8
    a_scales: jax.Array,   # (M, K/64) f32
    b_ints: jax.Array,     # (K, N) int8
    b_scales: jax.Array,   # (K/64, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Group-scaled integer matmul on pre-quantized HiF4 operands -> f32."""
    from repro.kernels.hif4_quant import _fit

    M, K = a_ints.shape
    K2, N = b_ints.shape
    assert K == K2 and K % GROUP == 0
    bm = _fit(M, min(block_m, M), 1)
    bn = _fit(N, min(block_n, N), 1)
    bk = _fit(K, min(block_k, K), GROUP)
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(_bfp_matmul_kernel, n_k_steps=K // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a_ints, a_scales, b_ints, b_scales)
