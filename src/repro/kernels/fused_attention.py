"""Fused HiF4 flash decode-attention: stream the 4.5-bit KV cache into MXU.

The serving KV cache is resident as HiF4 packed leaves (4.5 bits/value,
``repro.core.kvcache``). Before this kernel, every decode step dequantized
the ENTIRE per-layer cache to a (B, S, Hkv, Dh) bf16 array in HBM
(``repro.models.attention.decode_attention_packed`` before its bounded
rewrite), so the packed cache bought residency but paid bf16 HBM traffic on
the decode hot path. Here the kernel consumes the KERNEL-TILE cache layout
(``codes`` (B, F/2, S) uint8, ``meta`` (B, G, S) uint32 — see
docs/FORMATS.md "Packed KV-cache layout") **directly**: each grid step DMAs
one 4.5-bit KV tile into VMEM, expands codes+meta to bf16 K/V columns
*inside* VMEM with the same K-major bit helpers the fused matmul uses
(``repro.core.hif4.dequantize_km``), and folds the tile into an online-
softmax recurrence. HBM reads per decode step are the packed payload — the
bf16 working set is one (features, kv-tile) block, never the cache.

Grid: (batch-slot, kv-head block, KV tile), KV innermost so the softmax
state (m, l, normalized accumulator) lives in VMEM scratch across the
tiles of one (slot, head) cell. A head block covers
``lcm(d_head, 64) // d_head`` heads so every codes/meta block holds whole
HiF4 groups even when a 64-group spans heads (d_head < 64). Per-slot
``length`` masks the cache tail exactly like
``repro.models.attention.decode_attention``.

Two executions of the same contraction:

* :func:`fused_decode_attention` — the Pallas kernel (TPU;
  ``interpret=True`` runs it anywhere for tests).
* :func:`fused_decode_attention_xla` — the identical recurrence as
  straight-line XLA (a tightened Sq=1 form of the
  ``repro.models.attention.flash_mha_vec_packed`` chunked-loader
  recurrence), used by the engine off-TPU and for cache layouts the kernel
  cannot tile (artifact layout, partial-group staging tail).

The recurrence keeps the accumulator NORMALIZED at every step
(``acc <- acc * (l*corr/l_new) + (e/l_new)_bf16 @ V``), so with a single
KV tile it degenerates to exactly the flat masked softmax of
``decode_attention`` — max, exp, sum, divide, bf16 probabilities, f32 PV
dot, in that order — and the three paths are BITWISE equal there
(``tests/test_fused_attention.py``; multi-tile runs reassociate the f32
sums and are float-close, mirroring the single-K-step anchor of
``tests/test_fused_matmul.py``). NaN metadata (E6M2 0xFF) propagates
identically on every path.

PAGED variant: when the KV cache lives in the fixed-size page pool of
``repro.core.kvcache`` (leaves (n_pages, F, P), per-slot page table — see
docs/FORMATS.md "Paged KV-cache pool"), the same recurrence runs with the
KV-tile grid axis walking the page table instead of a contiguous token
axis. :func:`fused_paged_decode_attention` prefetches the (B, max_pages)
table as a scalar-prefetch operand and gathers each tile's pool page in
the BlockSpec index map; :func:`fused_paged_decode_attention_xla` is its
bitwise twin (a scan whose tile loader is a page gather instead of a
token slice). Because a fully masked tile is an exact no-op of the
recurrence (``exp(NEG_INF - m)`` underflows to f32 zero and the
correction factor is exactly 1.0), paged attention over pages of P
tokens is BITWISE equal to the contiguous kernel/twin run with
``block_kv=P`` on a capacity padded to a page multiple — the parity
``tests/test_paged_kv.py`` pins.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hif4, kvcache
from repro.kernels.hif4_quant import _fit

NEG_INF = -1e30   # matches repro.models.attention.NEG_INF (masked-score value)

# The softmax-state revisit pattern requires the KV-tile grid axis to be
# LAST (innermost): scratch carries (m, l, acc) across consecutive grid
# steps of one (slot, head-block) cell.
KV_GRID_AXIS = 2

# Decode KV tiles: deep tiles maximize packed payload per grid step; small
# caches take a single tile (the regime where the recurrence IS the flat
# softmax, bitwise).
_KV_TILE = 256


def select_kv_block(seq: int, block_kv: Optional[int] = None) -> int:
    """Per-regime KV tile size: whole cache when it fits one tile
    (<= ``_KV_TILE`` slots), else a divisor of ``seq`` near the tile
    target — every tile holds whole token slots, groups never split
    (grouping is per token).

    Awkward capacities (e.g. a prime 509 = prompt 381 + budget 128) have
    no useful divisor below the target; the largest one can be 1, which
    would silently turn decode attention into an S-step scan per layer.
    When the best divisor below the target is degenerate (< 1/4 of it),
    take the SMALLEST divisor at or above the target instead — at worst
    one tile spanning the whole cache, never a 1-token tile storm.
    """
    want = min(block_kv or _KV_TILE, seq)
    best = _fit(seq, want, 1)
    if best * 4 < want:
        best = next(d for d in range(want, seq + 1) if seq % d == 0)
    return best


def heads_per_block(d_head: int) -> int:
    """KV heads per grid step so head blocks hold whole 64-groups.

    d_head % 64 == 0 -> 1; d_head = 32 -> 2; etc. (lcm(d_head, 64)/d_head).
    """
    return math.lcm(d_head, 64) // d_head


def kernel_compatible(k_cache: dict, n_kv_heads: int, d_head: int) -> bool:
    """Can the Pallas kernel tile this cache?  Needs the kernel-tile layout,
    no partial-group staging tail (the tail is bf16 prose the kernel has no
    bit helper for), and head blocks that divide the head count. The last
    condition is implied by a tail-free F (64 | Hkv*Dh forces
    64/gcd(Dh, 64) | Hkv) — kept as a cheap structural guard."""
    return (
        kvcache.is_kernel_layout(k_cache)
        and k_cache["tail"].shape[-2] == 0
        and n_kv_heads % heads_per_block(d_head) == 0
    )


def _fused_decode_kernel(q_ref, len_ref, kc_ref, km_ref, vc_ref, vm_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, d_head: int,
                         n_tiles: int, block_kv: int):
    ki = pl.program_id(KV_GRID_AXIS)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hb, rep, _ = q_ref.shape[1:]
    q = q_ref[0]                                         # (hb, rep, D) bf16
    # expand the 4.5-bit tile to bf16 K/V columns IN VMEM (K-major helpers)
    kT = hif4.dequantize_km(kc_ref[0], km_ref[0]).reshape(hb, d_head, block_kv)
    vT = hif4.dequantize_km(vc_ref[0], vm_ref[0]).reshape(hb, d_head, block_kv)
    s = jax.lax.dot_general(
        q, kT, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) / (d_head ** 0.5)                                  # (hb, rep, ck)
    kp = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, block_kv), 2)
    s = jnp.where(kp < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[..., :1]
    l_prev = l_ref[..., :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)
    l_new = l_prev * corr + jnp.sum(e, axis=-1, keepdims=True)
    p = (e / l_new).astype(vT.dtype)                     # normalized, bf16
    pv = jax.lax.dot_general(
        p, vT, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                    # (hb, rep, D)
    acc_ref[...] = acc_ref[...] * (l_prev * corr / l_new) + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_tiles - 1)
    def _fin():
        o_ref[0] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_kv_heads", "d_head", "block_kv", "interpret"),
)
def fused_decode_attention(
    q: jax.Array,            # (B, H, D) bf16 — the single query token
    k_cache: dict,           # kernel-tile packed leaves {codes, meta, tail}
    v_cache: dict,
    length: jax.Array,       # (B,) valid cache prefix per slot
    *,
    n_kv_heads: int,
    d_head: int,
    block_kv: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Flash decode-attention straight off the 4.5-bit KV cache -> (B, H, D).

    Requires :func:`kernel_compatible` geometry (the engine routes
    everything else to :func:`fused_decode_attention_xla`).
    """
    B, H, D = q.shape
    assert D == d_head and kernel_compatible(k_cache, n_kv_heads, d_head)
    S = kvcache.seq_capacity(k_cache)
    rep = H // n_kv_heads
    hb = heads_per_block(d_head)
    ck = select_kv_block(S, block_kv)
    n_tiles = S // ck
    grid = (B, n_kv_heads // hb, n_tiles)
    assert KV_GRID_AXIS == len(grid) - 1 and grid[KV_GRID_AXIS] == n_tiles

    qf = q.reshape(B, n_kv_heads, rep, D)
    len2 = length.astype(jnp.int32).reshape(B, 1)
    kernel = functools.partial(_fused_decode_kernel, d_head=d_head,
                               n_tiles=n_tiles, block_kv=ck)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hb, rep, D), lambda b, h, k: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, k: (b, 0)),
            pl.BlockSpec((1, hb * D // 2, ck), lambda b, h, k: (b, h, k)),
            pl.BlockSpec((1, hb * D // 64, ck), lambda b, h, k: (b, h, k)),
            pl.BlockSpec((1, hb * D // 2, ck), lambda b, h, k: (b, h, k)),
            pl.BlockSpec((1, hb * D // 64, ck), lambda b, h, k: (b, h, k)),
        ],
        out_specs=pl.BlockSpec((1, hb, rep, D), lambda b, h, k: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_kv_heads, rep, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hb, rep, 128), jnp.float32),     # running max
            pltpu.VMEM((hb, rep, 128), jnp.float32),     # running denom
            pltpu.VMEM((hb, rep, D), jnp.float32),       # normalized acc
        ],
        interpret=interpret,
    )(qf, len2, k_cache["codes"], k_cache["meta"],
      v_cache["codes"], v_cache["meta"])
    return out.reshape(B, H, D).astype(q.dtype)


def fused_decode_attention_xla(
    q: jax.Array,            # (B, H, D)
    k_cache: dict,           # packed leaves, either layout
    v_cache: dict,
    length: jax.Array,       # (B,)
    n_kv_heads: int,
    d_head: int,
    *,
    block_kv: Optional[int] = None,
) -> jax.Array:
    """The kernel's recurrence as straight-line XLA: the off-TPU serving
    twin, and the executable form for artifact-layout / staging-tail caches.

    A ``lax.scan`` over KV tiles; each tile is sliced from the packed
    leaves, dequantized through the shared K-major decode
    (``repro.core.kvcache.dequantize_kv``), masked, and folded into the
    normalized online-softmax state. The bf16 working set is one
    (B, block_kv, Hkv, Dh) tile — never the whole cache — and the per-tile
    ops mirror the kernel blocks exactly, so interpret-mode kernel and twin
    agree bitwise at every tiling.
    """
    B, H, D = q.shape
    assert D == d_head
    S = kvcache.seq_capacity(k_cache)
    rep = H // n_kv_heads
    ck = select_kv_block(S, block_kv)
    n_tiles = S // ck
    qf = q.reshape(B, n_kv_heads, rep, D)
    positions = jnp.arange(ck)

    def tile(carry, ki):
        m, l, acc = carry
        kblk = kvcache.dequantize_kv(
            kvcache.slice_tokens(k_cache, ki * ck, ck), n_kv_heads, d_head)
        vblk = kvcache.dequantize_kv(
            kvcache.slice_tokens(v_cache, ki * ck, ck), n_kv_heads, d_head)
        s = jnp.einsum("bgrd,bkgd->bgrk", qf, kblk,
                       preferred_element_type=jnp.float32) / (d_head ** 0.5)
        valid = (ki * ck + positions)[None, :] < length[:, None]     # (B, ck)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)
        e = jnp.exp(s - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1, keepdims=True)
        p = (e / l_new).astype(vblk.dtype)
        pv = jnp.einsum("bgrk,bkgd->bgrd", p, vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * (l * corr / l_new) + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, n_kv_heads, rep, 1), NEG_INF, jnp.float32),
        jnp.zeros((B, n_kv_heads, rep, 1), jnp.float32),
        jnp.zeros((B, n_kv_heads, rep, D), jnp.float32),
    )
    if n_tiles == 1:
        (_, _, acc), _ = tile(init, 0)
    else:
        (_, _, acc), _ = jax.lax.scan(tile, init, jnp.arange(n_tiles))
    return acc.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged variant: the KV-tile grid axis walks a per-slot page table
# ---------------------------------------------------------------------------


def _fused_paged_kernel(pt_ref, q_ref, len_ref, kc_ref, km_ref, vc_ref,
                        vm_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        d_head: int, n_tiles: int, block_kv: int):
    # Scalar-prefetch kernels receive the prefetched operand first; the
    # page-table gather happened in the BlockSpec index maps, so the body
    # is EXACTLY the contiguous kernel (same ops, same order -> bitwise).
    del pt_ref
    _fused_decode_kernel(q_ref, len_ref, kc_ref, km_ref, vc_ref, vm_ref,
                         o_ref, m_ref, l_ref, acc_ref, d_head=d_head,
                         n_tiles=n_tiles, block_kv=block_kv)


@functools.partial(
    jax.jit, static_argnames=("n_kv_heads", "d_head", "interpret"),
)
def fused_paged_decode_attention(
    q: jax.Array,            # (B, H, D) bf16 — the single query token
    k_pool: dict,            # page-pool packed leaves (n_pages, F, P)
    v_pool: dict,
    pages: jax.Array,        # (B, max_pages) int32 per-slot page table
    length: jax.Array,       # (B,) valid cache prefix per slot
    *,
    n_kv_heads: int,
    d_head: int,
    interpret: bool = False,
) -> jax.Array:
    """Flash decode-attention off the PAGED 4.5-bit pool -> (B, H, D).

    Grid (slot, head block, logical page): the page table rides in as a
    scalar-prefetch operand and the KV BlockSpec index maps read
    ``pages[b, k]`` to pick tile k's pool page, so each grid step DMAs
    one page's packed payload — a gather walk over the table instead of
    a contiguous token axis. The tile width IS the page size, logical
    page index k supplies the positions for the length mask, and unused
    trailing table entries (zeros -> the scratch page) are fully masked
    exact no-ops, so the result is bitwise equal to the contiguous
    kernel at ``block_kv=P`` on a page-multiple capacity.
    """
    B, H, D = q.shape
    assert D == d_head and kernel_compatible(k_pool, n_kv_heads, d_head)
    P = kvcache.pool_page_tokens(k_pool)
    n_tiles = pages.shape[1]
    rep = H // n_kv_heads
    hb = heads_per_block(d_head)
    grid = (B, n_kv_heads // hb, n_tiles)
    assert KV_GRID_AXIS == len(grid) - 1

    qf = q.reshape(B, n_kv_heads, rep, D)
    len2 = length.astype(jnp.int32).reshape(B, 1)
    kernel = functools.partial(_fused_paged_kernel, d_head=d_head,
                               n_tiles=n_tiles, block_kv=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hb, rep, D), lambda b, h, k, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, k, pt: (b, 0)),
            pl.BlockSpec((1, hb * D // 2, P),
                         lambda b, h, k, pt: (pt[b, k], h, 0)),
            pl.BlockSpec((1, hb * D // 64, P),
                         lambda b, h, k, pt: (pt[b, k], h, 0)),
            pl.BlockSpec((1, hb * D // 2, P),
                         lambda b, h, k, pt: (pt[b, k], h, 0)),
            pl.BlockSpec((1, hb * D // 64, P),
                         lambda b, h, k, pt: (pt[b, k], h, 0)),
        ],
        out_specs=pl.BlockSpec((1, hb, rep, D),
                               lambda b, h, k, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hb, rep, 128), jnp.float32),     # running max
            pltpu.VMEM((hb, rep, 128), jnp.float32),     # running denom
            pltpu.VMEM((hb, rep, D), jnp.float32),       # normalized acc
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_kv_heads, rep, D), jnp.float32),
        interpret=interpret,
    )(pages.astype(jnp.int32), qf, len2, k_pool["codes"], k_pool["meta"],
      v_pool["codes"], v_pool["meta"])
    return out.reshape(B, H, D).astype(q.dtype)


def fused_paged_decode_attention_xla(
    q: jax.Array,            # (B, H, D)
    k_pool: dict,            # page-pool packed leaves (n_pages, F, P)
    v_pool: dict,
    pages: jax.Array,        # (B, max_pages) int32 per-slot page table
    length: jax.Array,       # (B,)
    n_kv_heads: int,
    d_head: int,
) -> jax.Array:
    """The paged kernel's recurrence as straight-line XLA: the off-TPU
    serving twin, and the executable form for staging-tail pools.

    Identical to :func:`fused_decode_attention_xla` except the tile
    loader: each scan step GATHERS tile k's pool page per slot
    (``pool[pages[:, k]]``) instead of slicing a contiguous token axis.
    The gathered bytes feed the same shared K-major decode and the same
    per-tile ops, so kernel (interpret) and twin agree bitwise, and both
    agree bitwise with the contiguous paths at ``block_kv=P``.
    """
    B, H, D = q.shape
    assert D == d_head
    P = kvcache.pool_page_tokens(k_pool)
    n_tiles = pages.shape[1]
    rep = H // n_kv_heads
    qf = q.reshape(B, n_kv_heads, rep, D)
    positions = jnp.arange(P)

    def gather(pool_t, pids):
        return {key: jnp.take(a, pids, axis=0) for key, a in pool_t.items()}

    def tile(carry, ki):
        m, l, acc = carry
        pids = jax.lax.dynamic_index_in_dim(pages, ki, axis=1,
                                            keepdims=False)       # (B,)
        kblk = kvcache.dequantize_kv(gather(k_pool, pids),
                                     n_kv_heads, d_head)
        vblk = kvcache.dequantize_kv(gather(v_pool, pids),
                                     n_kv_heads, d_head)
        s = jnp.einsum("bgrd,bkgd->bgrk", qf, kblk,
                       preferred_element_type=jnp.float32) / (d_head ** 0.5)
        valid = (ki * P + positions)[None, :] < length[:, None]    # (B, P)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)
        e = jnp.exp(s - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1, keepdims=True)
        p = (e / l_new).astype(vblk.dtype)
        pv = jnp.einsum("bgrk,bkgd->bgrd", p, vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * (l * corr / l_new) + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, n_kv_heads, rep, 1), NEG_INF, jnp.float32),
        jnp.zeros((B, n_kv_heads, rep, 1), jnp.float32),
        jnp.zeros((B, n_kv_heads, rep, D), jnp.float32),
    )
    if n_tiles == 1:
        (_, _, acc), _ = tile(init, 0)
    else:
        (_, _, acc), _ = jax.lax.scan(tile, init, jnp.arange(n_tiles))
    return acc.reshape(B, H, D).astype(q.dtype)
