"""Fused dequantize-in-kernel packed matmul: the 4.5-bit serving hot path.

The serving deployment stores weights as :class:`repro.core.qlinear.PackedW`
(HiF4, 0.5625 B/value). Before this kernel, every matmul on the decode hot
path re-materialized a (K, N) bf16 or int8 weight in HBM from those buffers
— so the packed path was 3.56x smaller but paid MORE memory traffic per
token than bf16 serving. Here the kernel consumes the K-major packed
buffers (``codes_km`` (K/2, N) uint8, ``meta_km`` (K/64, N) uint32 — see
docs/FORMATS.md "kernel-tile layout") **directly**: each grid step DMAs a
4.5-bit tile into VMEM, expands two-codes-per-byte + metadata to the
absorbed-shift int8 operand of paper §III.B *inside* VMEM
(:func:`repro.core.hif4.absorbed_int_km`), and contracts all 64-groups of
the tile in one batched MXU ``dot_general``. HBM reads per output tile are
the packed payload plus the activation tile — no (K, N)-sized intermediate
ever exists in HBM.

Two executions of the same contraction:

* :func:`fused_packed_matmul` — the Pallas kernel (TPU; ``interpret=True``
  runs it anywhere for tests).
* :func:`fused_packed_matmul_xla` — the identical math as straight-line
  XLA ops, used by the engine off-TPU where interpret-mode Pallas is a
  correctness vehicle, not a serving path. The integer group dots are
  computed in f32 (every |product| <= 28*28 and every 64-term group sum
  < 2^24, so f32 is exact) which hits the fast batched-GEMM path on CPU.

Both are bit-exact against each other and against expanding the packed
buffer first (``tests/test_fused_matmul.py``): in-kernel dequantization
changes WHERE the bits expand, never what is computed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hif4
from repro.kernels.bfp_matmul import (
    GROUP,
    K_GRID_AXIS,
    _fit,
    _tile_group_dot,
    select_block_sizes,
)


def _fused_packed_kernel(a_ref, as_ref, codes_ref, meta_ref, o_ref):
    k_step = pl.program_id(K_GRID_AXIS)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # unpack the 4.5-bit tile to absorbed int8 + group scales IN VMEM
    b_ints, b_scales = hif4.absorbed_int_km(codes_ref[...], meta_ref[...])
    o_ref[...] += _tile_group_dot(a_ref[...], as_ref[...], b_ints, b_scales)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def fused_packed_matmul(
    a_ints: jax.Array,     # (M, K)    int8   absorbed activation
    a_scales: jax.Array,   # (M, K/64) f32
    codes_km: jax.Array,   # (K/2, N)  uint8  K-major packed weight payload
    meta_km: jax.Array,    # (K/64, N) uint32
    *,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Packed-operand group-scaled matmul -> (M, N) f32.

    Block sizes default to :func:`select_block_sizes` (decode vs prefill
    regime). The codes/meta BlockSpecs tile the SAME logical (bk, bn)
    window at 1/2 and 1/64 granularity along K, so ``bk`` stays a multiple
    of 64 and every VMEM tile holds whole HiF4 groups.
    """
    M, K = a_ints.shape
    half, N = codes_km.shape
    assert 2 * half == K and K % GROUP == 0, (a_ints.shape, codes_km.shape)
    assert meta_km.shape == (K // GROUP, N), meta_km.shape
    abm, abn, abk = select_block_sizes(M, N, K)
    bm = _fit(M, min(block_m, M), 1) if block_m else abm
    bn = _fit(N, min(block_n, N), 1) if block_n else abn
    bk = _fit(K, min(block_k, K), GROUP) if block_k else abk
    grid = (M // bm, N // bn, K // bk)
    # documented invariant: the accumulator revisit pattern needs K innermost
    assert K_GRID_AXIS == len(grid) - 1 and grid[K_GRID_AXIS] == K // bk

    return pl.pallas_call(
        _fused_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a_ints, a_scales, codes_km, meta_km)


def fused_packed_matmul_xla(a_ints, a_scales, codes_km, meta_km):
    """The fused contraction as straight-line XLA: bit-for-bit the single-
    K-step kernel, without a Pallas lowering requirement.

    Unpack (integer shifts, no exp2 over (K, N)), ONE group-batched f32
    GEMM of the exact integer values, then the per-(row, col, group)
    rescale summed over groups — the same op sequence the kernel runs on a
    full-K tile, so outputs match the interpret-mode kernel bitwise.
    """
    M, K = a_ints.shape
    b_ints, b_scales = hif4.absorbed_int_km(codes_km, meta_km)
    g = K // GROUP
    a3 = a_ints.reshape(M, g, GROUP).astype(jnp.float32)
    b3 = b_ints.reshape(g, GROUP, -1).astype(jnp.float32)
    part = jax.lax.dot_general(
        a3, b3,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                   # (g, M, N) exact ints
    scaled = part * jnp.transpose(a_scales)[:, :, None] * b_scales[:, None, :]
    return jnp.sum(scaled, axis=0)


def absorbed_activation(x2d: jax.Array):
    """Dynamic activation quantization for the XLA twin: (M, K) bf16/f32 ->
    (ints (M, K) int8, scales (M, K/64) f32), bitwise identical to the
    Algorithm-1 Pallas kernel (``repro.kernels.hif4_quant.hif4_quantize``,
    property-tested) but as plain jnp ops."""
    M, K = x2d.shape
    assert K % GROUP == 0, x2d.shape
    g = hif4.quantize_groups(x2d.reshape(M, K // GROUP, GROUP))
    ints, scales = hif4.to_absorbed_int(g)
    return ints.reshape(M, K), scales
