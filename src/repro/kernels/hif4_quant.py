"""Pallas TPU kernel: tiled BF16 -> HiF4 conversion (paper Algorithm 1).

Hardware adaptation (DESIGN.md §3): the paper's bespoke scalar instructions
(BF16->E6M2, E6M2 reciprocal LUT, multiply-compare) become VPU vector ops on
VMEM tiles. Each grid step loads a (block_m, block_k) tile of the source
into VMEM, runs the three-stage conversion (tree max -> hierarchical scales
-> scale+round), and writes the deployment layout:

  ints   (block_m, block_k)      int8  — S1P2 quarters shifted by the two
                                          micro-exponent levels (|q| <= 28)
  scales (block_m, block_k//64)  f32   — E6M2 / 4 per 64-group

``scales[m, g] * ints[m, 64g:64g+64]`` reconstructs Eq. 2 exactly (tested
against repro.core.hif4). block_k must be a multiple of 64 so every VMEM
tile holds whole HiF4 groups; MXU-friendly multiples of 128 recommended.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import rounding as R

GROUP = 64
_RECIP7_BF16 = float(jnp.asarray(1.0 / 7.0, jnp.bfloat16))


def _fit(dim: int, want: int, quantum: int) -> int:
    """Largest block <= want that divides dim and is a multiple of quantum."""
    b = (want // quantum) * quantum
    while b > quantum and dim % b != 0:
        b -= quantum
    b = max(b, quantum)
    assert dim % b == 0, (dim, want, quantum)
    return b


def _quant_kernel(x_ref, ints_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                 # (bm, bk)
    bm, bk = x.shape
    g = bk // GROUP
    v = x.reshape(bm, g, GROUP)
    av = jnp.abs(v)

    # Stage 1: three-level tree max (Alg. 1 lines 1-7)
    v16 = jnp.max(av.reshape(bm, g, 16, 4), axis=-1)
    v8 = jnp.max(v16.reshape(bm, g, 8, 2), axis=-1)
    vmax = jnp.max(v8, axis=-1)                        # (bm, g)

    # Stage 2: hierarchical scaling metadata (lines 8-14)
    sf = R.round_bf16(R.round_bf16(vmax) * _RECIP7_BF16)
    e6m2 = R.round_e6m2(sf)
    rec = R.e6m2_reciprocal_bf16(e6m2)
    e1_8 = (R.round_bf16(v8 * rec[..., None]) > 4.0).astype(jnp.int32)
    shift2 = jnp.repeat(e1_8, 2, axis=-1)
    t16 = R.round_bf16(v16 * rec[..., None]) * jnp.exp2(-shift2.astype(jnp.float32))
    e1_16 = (t16 >= 2.0).astype(jnp.int32)

    # Stage 3: scale, round to S1P2 quarters, absorb shifts (lines 15-18)
    shift8 = jnp.repeat(e1_8, 8, axis=-1)
    shift4 = jnp.repeat(e1_16, 4, axis=-1)
    shift = shift8 + shift4                            # (bm, g, 64)
    scaled = R.round_bf16(v * rec[..., None]) * jnp.exp2(-shift.astype(jnp.float32))
    q = jnp.clip(jnp.round(scaled / 0.25), -7, 7).astype(jnp.int32)
    ints = (q << shift).astype(jnp.int8)               # |q| <= 28

    ints_ref[...] = ints.reshape(bm, bk)
    scale_ref[...] = e6m2 * 0.25


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def hif4_quantize(
    x: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    """x (M, K) bf16/f32 -> (ints (M, K) int8, scales (M, K/64) f32)."""
    M, K = x.shape
    assert K % GROUP == 0, f"K={K} must be a multiple of {GROUP}"
    bm = _fit(M, min(block_m, M), 1)
    bk = _fit(K, min(block_k, K), GROUP)
    grid = (M // bm, K // bk)

    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, K // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(x)
