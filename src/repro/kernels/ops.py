"""Jit'd user-facing wrappers over the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes as python/jnp, validating the exact tiling + compute flow the TPU
would run). On a real TPU backend set interpret=False (the default picks
automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bfp_matmul import bfp_matmul_quantized
from repro.kernels.hif4_quant import hif4_quantize


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def quantize(x: jax.Array, *, block_m: int = 256, block_k: int = 512,
             interpret=None):
    """BF16/FP32 (M, K) -> HiF4 absorbed layout (ints int8, scales f32)."""
    if interpret is None:
        interpret = _interpret_default()
    return hif4_quantize(x, block_m=block_m, block_k=block_k,
                         interpret=interpret)


def matmul(x: jax.Array, w: jax.Array, *, block_m: int = 256,
           block_n: int = 256, block_k: int = 512, interpret=None) -> jax.Array:
    """HiF4 A-W quantized matmul: quantize both operands (Alg. 1 kernel),
    contract with the fixed-point kernel (§III.B). x (M, K) @ w (K, N)."""
    if interpret is None:
        interpret = _interpret_default()
    ai, ascale = hif4_quantize(x, block_m=block_m, block_k=block_k,
                               interpret=interpret)
    wi, wscale = hif4_quantize(w.T, block_m=block_n, block_k=block_k,
                               interpret=interpret)
    return bfp_matmul_quantized(
        ai, ascale, wi.T, wscale.T,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def matmul_prequantized(x: jax.Array, wi: jax.Array, wscale: jax.Array,
                        **kw) -> jax.Array:
    """Serving path: dynamic activation quant x offline-quantized weight."""
    interpret = kw.pop("interpret", None)
    if interpret is None:
        interpret = _interpret_default()
    ai, ascale = hif4_quantize(x, interpret=interpret)
    return bfp_matmul_quantized(ai, ascale, wi, wscale, interpret=interpret, **kw)
