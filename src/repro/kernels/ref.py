"""Pure-jnp oracles for the Pallas kernels.

Anchored to ``repro.core.hif4`` (the bit-exact Algorithm 1 implementation)
so kernel == ref == paper. The kernels use the "absorbed integer" layout of
paper §III.B: micro-exponents folded into int8 elements (|q| <= 28), one
f32 scale per 64-group (= E6M2/16 for a dot of two operands, E6M2/4 each).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import hif4


def hif4_quantize_ref(x: jnp.ndarray):
    """x (M, K) float -> (ints (M, K) int8, scales (M, K/64) f32).

    K must be a multiple of 64. ``scales[m, g] * ints[m, 64g:64(g+1)]``
    reconstructs the dequantized values exactly.
    """
    M, K = x.shape
    assert K % hif4.GROUP_SIZE == 0, K
    g = hif4.quantize_groups(x.reshape(M, K // hif4.GROUP_SIZE, hif4.GROUP_SIZE))
    ints, scale = hif4.to_absorbed_int(g)
    return ints.reshape(M, K), scale


def hif4_dequantize_ref(ints: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    M, K = ints.shape
    G = scales.shape[-1]
    vals = ints.reshape(M, G, K // G).astype(jnp.float32) * scales[..., None]
    return vals.reshape(M, K)


def bfp_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """HiF4 A-W quantized matmul oracle: x (M, K) @ w (K, N) -> (M, N) f32.

    Both operands quantized along K in 64-groups; per-group integer dot then
    one float multiply by the two group scales (paper Eq. 3 compute flow).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % hif4.GROUP_SIZE == 0
    G = K // hif4.GROUP_SIZE

    ai, ascale = hif4_quantize_ref(x)                      # (M,K), (M,G)
    bi, bscale = hif4_quantize_ref(w.T)                    # (N,K), (N,G)

    a = ai.reshape(M, G, hif4.GROUP_SIZE).astype(jnp.int32)
    b = bi.reshape(N, G, hif4.GROUP_SIZE).astype(jnp.int32)
    # integer 64-length dots per group: (M, N, G)
    acc = jnp.einsum("mgk,ngk->mng", a, b)
    out = jnp.einsum(
        "mng,mg,ng->mn", acc.astype(jnp.float32), ascale, bscale
    )
    return out


def bfp_matmul_from_quantized_ref(ai, ascale, bi, bscale) -> jnp.ndarray:
    """Same contraction, operands already in absorbed-int layout.

    ai (M, K) int8 with ascale (M, G); bi (K, N) int8 with bscale (G, N).
    """
    M, K = ai.shape
    _, N = bi.shape
    G = ascale.shape[-1]
    a = ai.reshape(M, G, K // G).astype(jnp.int32)
    b = bi.reshape(G, K // G, N).astype(jnp.int32)
    acc = jnp.einsum("mgk,gkn->mgn", a, b).astype(jnp.float32)
    return jnp.einsum("mgn,mg,gn->mn", acc, ascale, bscale)
