import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-computation / per-instruction cost breakdown of a dry-run cell.

The §Perf hillclimb tool: shows where the bytes, FLOPs and collective wire
traffic of a lowered cell actually live (computation x loop-multiplicity,
then the top instructions inside).

  PYTHONPATH=src python -m repro.launch.breakdown --arch qwen3-4b --shape train_4k [--multi-pod] ...
"""
import argparse

import repro.launch.hlo_analysis as H


def computation_table(mod: H.HloModule):
    mults: dict = {}

    def visit(comp, mult):
        if comp not in mod.comps:
            return
        mults[comp] = mults.get(comp, 0) + mult
        for callee, m in mod._edges[comp]:
            visit(callee, mult * m)

    visit(mod.entry, 1.0)
    rows = []
    for comp, mult in mults.items():
        c = mod._local[comp]
        rows.append((c.bytes * mult, c.wire_bytes * mult, c.flops * mult, mult, comp))
    rows.sort(reverse=True)
    return rows


def instruction_table(mod: H.HloModule, comp: str):
    types = {i.name: i.type_str for i in mod.comps[comp]}
    rows = []
    for i in mod.comps[comp]:
        if i.op in H.FREE:
            continue
        b = mod.instr_bytes(i, types)   # the BILLED bytes (slice/DUS-aware)
        rows.append((b, i.op, i.line.split(", metadata")[0].strip()))
    rows.sort(reverse=True)
    return rows


def print_breakdown(compiled, n_comps=8, n_instrs=6):
    mod = H.HloModule(compiled.as_text())
    rows = computation_table(mod)
    print(f"{'GiB':>9} {'wireGiB':>9} {'GFLOP':>10} {'mult':>6}  computation")
    for b, w, f, m, comp in rows[:n_comps]:
        print(f"{b/2**30:9.2f} {w/2**30:9.2f} {f/1e9:10.1f} {m:6.0f}  {comp[:64]}")
    for b, w, f, m, comp in rows[:3]:
        print(f"\n--- {comp[:70]} (mult={m:.0f})")
        for ib, op, line in instruction_table(mod, comp)[:n_instrs]:
            print(f"  {ib/2**20:9.1f}MiB {op:20} {line[:120]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="hif4")
    ap.add_argument("--fsdp", choices=["on", "off"], default="on")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell

    rec, compiled = lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod, quant=args.quant,
        fsdp=args.fsdp != "off",
        seq_shard=False if args.no_seq_shard else None,
        microbatches=args.microbatches,
    )
    r = rec["roofline"]
    print(
        f"{args.arch} x {args.shape}: t_comp={r['t_compute_s']*1e3:.1f}ms "
        f"t_mem={r['t_memory_s']*1e3:.1f}ms t_coll={r['t_collective_s']*1e3:.1f}ms "
        f"dom={r['dominant']} useful={rec['useful_flops_ratio']:.2f} "
        f"peak={rec['memory']['peak_bytes_est']/2**30:.2f}GiB\n"
    )
    print_breakdown(compiled)


if __name__ == "__main__":
    main()
