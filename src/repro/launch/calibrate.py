"""Calibration launcher: search a QuantPolicy on the accuracy-bytes frontier.

    PYTHONPATH=src python -m repro.launch.calibrate --arch qwen1.5-0.5b \
        --reduced --target-bpv 0.7 --out policy.json

(also reachable as ``python -m repro calibrate ...``). Runs the
sensitivity probe (one bf16 forward over the calibration batches with the
per-site activation tap), the greedy frontier search at ``--target-bpv``,
and emits a provenance-stamped QuantPolicy JSON that any serving entry
accepts verbatim:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --policy policy.json ...

``--report`` additionally writes ``calibration_report.json`` — every
per-site per-format score, the full Pareto curve, and the hand-written
preset baselines priced on the same calibration set. ``--measure-bw``
measures stream bandwidth first so the report includes each site's
roofline latency contribution (skipped by default: it costs a few
seconds and the search itself only needs bytes + error).
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser(
        description="search a QuantPolicy from calibration activations")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--target-bpv", type=float, default=0.7,
                    help="byte budget, bytes/value at rest over the "
                         "policy-governed weight sites (hif4 packed = "
                         "0.5625, bf16 = 2.0)")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="calibration batches to probe")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-format", default="bf16",
                    choices=("bf16", "hif4"),
                    help="cache-global KV format stamped into the policy")
    ap.add_argument("--out", default="policy.json",
                    help="searched QuantPolicy JSON (serve with --policy)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the full calibration_report.json")
    ap.add_argument("--measure-bw", action="store_true",
                    help="measure stream bandwidth for roofline columns")
    args = ap.parse_args()

    from repro.calibrate import calibrate

    summary = calibrate(
        args.arch, reduced=args.reduced, target_bpv=args.target_bpv,
        n_batches=args.calib_batches, batch=args.batch,
        seq_len=args.seq_len, seed=args.seed, kv_format=args.kv_format,
        out=args.out, report_out=args.report, measure_bw=args.measure_bw)

    print(f"\n== searched policy: {summary['arch']} @ "
          f"{args.target_bpv} B/value ==")
    print(f"{'site':24} {'fmt':8}")
    for path, fmt in summary["assignment"].items():
        print(f"{path:24} {fmt:8}")
    print(f"\nachieved {summary['achieved_bpv']} B/value "
          f"({summary['total_bytes']} B over {summary['n_sites']} sites, "
          f"{summary['n_packed']} packed; feasible={summary['feasible']})")
    for name, b in summary["baselines"].items():
        print(f"baseline {name:20} {b['achieved_bpv']:.5f} B/value  "
              f"error {b['total_error']:.1f}")
    print(f"searched {'':20} {summary['achieved_bpv']:.5f} B/value  "
          f"error {summary['total_error']:.1f}")
    print(f"\nwrote {args.out}"
          + (f" and {args.report}" if args.report else ""))
    if not summary["feasible"]:
        print(f"WARNING: target {args.target_bpv} B/value is below the "
              f"cheapest assignment — emitted the min-bytes policy "
              f"({summary['achieved_bpv']} B/value)")
        raise SystemExit(2)


if __name__ == "__main__":
    main()
