import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory/cost/collective analysis.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` must succeed for the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh for EVERY cell; failures (sharding mismatch, OOM at
compile, unsupported collective) are bugs in the system.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

Results are written one JSON per cell under --out (default
experiments/dryrun/) and summarized on stdout.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import all_archs, applicable_shapes, get_arch, get_shape
from repro.core.qlinear import QuantConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import lm
from repro.models.params import (
    is_pspec,
    pspecs_from_specs,
    shape_structs,
    shardings_from_specs,
)
from repro.models.common import ModelCtx
from repro.optim.adamw import AdamWConfig, adamw_init_specs
from repro.sharding.rules import ShardCtx

# Gradient-accumulation microbatches for the biggest train cells: bounds the
# remat-saved activation footprint per microbatch (see DESIGN.md §4).
# §Perf iteration: nearly all train wire (FSDP weight regathers + TP
# partial sums) scales with the microbatch count; with sequence-parallel
# saved activations the memory allows far fewer microbatches than the
# conservative initial pick (340B: mb 8->4->2 drove t_coll 302->232->197s).
# mb=4 chosen for 340B (activation headroom on 16 GiB HBM).
TRAIN_MICROBATCHES = {
    "nemotron-4-340b": 4,
    "llava-next-34b": 2,
    "phi3.5-moe-42b-a6.6b": 2,
    "qwen3-4b": 2,
    "qwen1.5-4b": 2,
    "zamba2-2.7b": 2,
    "mamba2-1.3b": 2,
}


# Sequence-parallel residual streams (act_seq over the TP axis) are a
# memory lever for the big models (340B cannot save 96 full layer inputs);
# for small models they cost a per-layer reshard in backward for no benefit.
SEQ_SHARD_MIN_PARAMS = 8e9


def resident_bytes_per_device(spec_tree, shard) -> int:
    """Analytic per-device residency of a PSpec tree under its shardings.

    Computed from shard shapes — unlike ``memory_analysis()`` this is not
    polluted by XLA-CPU's bf16->f32 while-carry widening (a CPU-only
    emulation artifact; TPU holds these buffers natively in bf16)."""
    import numpy as np
    import jax

    total = 0
    for p in jax.tree_util.tree_leaves(spec_tree, is_leaf=is_pspec):
        if not is_pspec(p):
            continue   # packed-overlay markers carry non-PSpec aux leaves
        s = shard.sharding(p.axes, p.shape)
        shape = s.shard_shape(tuple(p.shape)) if s is not None else tuple(p.shape)
        total += int(np.prod(shape)) * jnp_dtype_bytes(p.dtype)
    return total


def jnp_dtype_bytes(dt) -> int:
    import numpy as np
    import jax.numpy as jnp

    return jnp.dtype(dt).itemsize


def make_ctx(mesh, quant: str, *, fsdp: bool, seq_shard: bool = True,
             attn_impl: str = "scan_q") -> ModelCtx:
    shard = ShardCtx(mesh=mesh)
    overrides = {}
    if not fsdp:
        overrides["fsdp"] = ()
    if not seq_shard:
        overrides["act_seq"] = ()
    if overrides:
        shard = shard.with_rules(**overrides)
    return ModelCtx(quant=QuantConfig(fmt=quant), shard=shard,
                    attn_impl=attn_impl)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, quant: str = "hif4",
               fsdp: bool = True, seq_shard=None, microbatches: int = 0,
               attn_mode: str = "auto", packed: bool = False):
    """Lower+compile one cell; returns (record, compiled)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    if seq_shard is None:  # auto: SP only where activation memory demands it
        seq_shard = cfg.n_params() >= SEQ_SHARD_MIN_PARAMS
    # vec_q flash when heads can't shard over the TP axis (§Perf iteration 1)
    tp = mesh.shape["model"]
    attn_impl = (
        "vec_q" if attn_mode == "auto" and cfg.attn is not None
        and cfg.attn.n_heads % tp != 0 else
        ("vec_q" if attn_mode == "vec_q" else "scan_q")
    )
    ctx = make_ctx(mesh, quant, fsdp=fsdp, seq_shard=seq_shard,
                   attn_impl=attn_impl)

    pspecs = lm.abstract_params(cfg)
    if packed and shape.kind != "train":
        # packing set = the uniform packed policy resolved for this arch
        # (the same plan serving packs from; see repro.core.policy). The
        # plan packs nothing for non-hif4 formats or hybrid archs — fall
        # back to dense LOUDLY so the record never claims packed_weights
        # for a dense lowering.
        plan = lm.quant_plan(cfg, QuantConfig(fmt=quant, impl="packed"))
        if not plan.packed_paths:
            print(f"note: --packed has no packable sites for {arch} under "
                  f"fmt={quant} (non-hif4 format or hybrid family); "
                  f"lowering dense weights instead")
            packed = False
    if packed and shape.kind != "train":
        # HiF4 packed serving weights: 4.5 bits/value residency + transport.
        # The ShardCtx the packed dequantization gathers under now travels
        # inside the model context (engine dispatch) — no module-level hook.
        pspecs = lm.packed_overlay(pspecs, plan)

        def leaf(p):
            return jax.ShapeDtypeStruct(
                p.shape, p.dtype, sharding=ctx.shard.sharding(p.axes, p.shape)
            )

        p_structs = lm.realize_packed(pspecs, leaf)
    else:
        packed = False
        p_structs = shape_structs(pspecs, shardings_from_specs(pspecs, ctx.shard))
    resident = {"params": resident_bytes_per_device(pspecs, ctx.shard)}

    t0 = time.time()
    if shape.kind == "train":
        mb = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
        ospecs = adamw_init_specs(pspecs)
        o_structs = shape_structs(ospecs, shardings_from_specs(ospecs, ctx.shard))
        resident["opt_state"] = resident_bytes_per_device(ospecs, ctx.shard)
        bspecs = batch_specs(cfg, shape)
        b_structs = shape_structs(bspecs, shardings_from_specs(bspecs, ctx.shard))
        step = make_train_step(cfg, ctx, AdamWConfig(), num_microbatches=mb,
                               param_pspecs=pspecs_from_specs(pspecs, ctx.shard))
        with mesh:
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                p_structs, o_structs, b_structs
            )
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.n_active_params() * tokens
    elif shape.kind == "prefill":
        mb = 1
        bspecs = batch_specs(cfg, shape)
        b_structs = shape_structs(bspecs, shardings_from_specs(bspecs, ctx.shard))
        # inference: weights are PTQ'd once offline, not re-cast per step
        qcfg = dataclasses.replace(ctx.quant, offline_weights=True)
        ctx = dataclasses.replace(ctx, quant=qcfg, remat=False)
        step = make_prefill_step(cfg, ctx)
        with mesh:
            lowered = jax.jit(step).lower(p_structs, b_structs)
        model_flops = 2.0 * cfg.n_active_params() * shape.global_batch * shape.seq_len
    else:  # decode
        mb = 1
        dspecs = decode_specs(cfg, shape)
        d_structs = shape_structs(dspecs, shardings_from_specs(dspecs, ctx.shard))
        resident["kv_cache"] = resident_bytes_per_device(dspecs["cache"], ctx.shard)
        qcfg = dataclasses.replace(ctx.quant, offline_weights=True)
        ctx = dataclasses.replace(ctx, quant=qcfg, remat=False)
        step = make_serve_step(cfg, ctx)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                p_structs, d_structs["cache"], d_structs["token"]
            )
        model_flops = 2.0 * cfg.n_active_params() * shape.global_batch
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    roof = hlo_analysis.roofline_terms(compiled)
    mem = hlo_analysis.memory_stats(compiled)
    hlo_global_flops = roof["flops_per_device"] * n_dev
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "quant": quant,
        "fsdp": fsdp,
        "seq_shard": seq_shard,
        "attn_impl": attn_impl,
        "packed_weights": packed,
        "microbatches": mb,
        "n_devices": n_dev,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "resident_bytes_per_device": resident,
        "memory": mem,
        "roofline": roof,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(hlo_global_flops, 1.0),
    }
    if shape.kind == "decode":
        # A hif4 serve of this cell may silently narrow to bf16 KV (SSM /
        # audio caches have no packed layout); the record carries the
        # resolution so a fallen-back cell is visible in artifacts.
        from repro.runtime.serve_loop import (ServeConfig, kv_format_fallback,
                                              resolve_kv_format)

        req = ServeConfig(kv_format="hif4" if quant == "hif4" else None)
        record["kv_format"] = resolve_kv_format(cfg, ctx.quant, req)
        record["kv_format_fallback"] = kv_format_fallback(cfg, ctx.quant, req)
    return record, compiled


def run_cell(arch, shape_name, args):
    key = f"{arch} x {shape_name} [{'2x16x16' if args.multi_pod else '16x16'}]"
    try:
        rec, _ = lower_cell(
            arch, shape_name, multi_pod=args.multi_pod, quant=args.quant,
            fsdp=args.fsdp != "off",
            seq_shard=False if args.no_seq_shard else None,
            microbatches=args.microbatches, attn_mode=args.attn,
            packed=args.packed,
        )
    except Exception as e:
        traceback.print_exc()
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if args.multi_pod else "16x16",
            "quant": args.quant, "error": f"{type(e).__name__}: {e}",
        }
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        mesh_tag = "mp" if args.multi_pod else "sp"
        tag = f"{arch}_{shape_name}_{mesh_tag}_{args.quant}"
        if args.fsdp == "off":
            tag += "_nofsdp"
        if args.no_seq_shard:
            tag += "_nosp"
        if args.attn != "auto":
            tag += f"_{args.attn}"
        if args.packed:
            tag += "_packed"
        path = os.path.join(args.out, tag.replace("/", "-") + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if "error" in rec:
        print(f"FAIL {key}: {rec['error']}")
        return False
    r = rec["roofline"]
    print(
        f"OK   {key}: compile={rec['compile_s']}s "
        f"peak={rec['memory']['peak_bytes_est']/2**30:.2f}GiB/dev "
        f"t_comp={r['t_compute_s']*1e3:.2f}ms t_mem={r['t_memory_s']*1e3:.2f}ms "
        f"t_coll={r['t_collective_s']*1e3:.2f}ms dom={r['dominant']} "
        f"useful={rec['useful_flops_ratio']:.2f}"
    )
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default=None)
    ap.add_argument("--quant", default="hif4")
    ap.add_argument("--fsdp", choices=["on", "off"], default="on")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--attn", choices=["auto", "scan_q", "vec_q"], default="auto")
    ap.add_argument("--packed", action="store_true",
                    help="serve cells with 4.5-bit PackedW resident weights")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = [
            (a, s) for a in all_archs() for s in applicable_shapes(get_arch(a))
        ]
    else:
        assert args.arch, "--arch required without --all"
        shapes = [args.shape] if args.shape else applicable_shapes(get_arch(args.arch))
        cells = [(args.arch, s) for s in shapes]

    meshes = {None: [args.multi_pod], "single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok = fail = 0
    for mp in meshes:
        args.multi_pod = mp
        for arch, shape in cells:
            if run_cell(arch, shape, args):
                ok += 1
            else:
                fail += 1
    print(f"\n{ok} cells passed, {fail} failed")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
