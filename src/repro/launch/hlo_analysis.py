"""Loop-aware roofline-term extraction from a compiled (dry-run) executable.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our models
scan over layers / microbatches / attention chunks — so FLOPs, HBM bytes and
collective bytes would all be undercounted by ~n_layers. This module parses
``compiled.as_text()`` (post-SPMD, per-device shapes, is_scheduled HLO) and
walks the call graph, multiplying every computation's cost by its loop
multiplicity (the ``known_trip_count`` backend_config XLA attaches to while
ops).

Per-instruction cost model (mirrors XLA's HloCostAnalysis):
  dot           flops = 2 * prod(result dims) * prod(lhs contracting dims)
                bytes = operands + result
  fusion        bytes = operands + result; flops = elementwise walk of callee
  elementwise   flops = prod(result dims)
  reduce        flops = prod(operand dims)
  collectives   ring model:
                  all-reduce      2F(g-1)/g   F = buffer bytes, g = group
                  all-gather       F(g-1)/g   F = gathered result
                  reduce-scatter  gF(g-1)/g   F = scattered result
                  all-to-all       F(g-1)/g
                  collective-permute  F
  data movers   (copy/slice/dus/gather/...) bytes = operands + result

Roofline terms (seconds, per device; v5e constants in launch/mesh.py):
  compute    = flops / peak_FLOP/s
  memory     = bytes / HBM_bw
  collective = wire_bytes / link_bw
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

from repro.launch import mesh as hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
# NOTE: tuple types can contain `/*index=N*/` comments (with '='), so the
# tuple alternative must be `\(.*?\)` (with backtracking), not `[^=]*`.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\(.*?\)|[a-z]\d*[a-z0-9]*\[[0-9,]*\]\S*))\s+([\w\-]+)\("
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\([^)]*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt", "rsqrt",
    "cbrt", "negate", "abs", "maximum", "minimum", "compare", "select",
    "and", "or", "xor", "not", "floor", "ceil", "round-nearest-even",
    "round-nearest-afz", "sign", "cosine", "sine", "tan", "atan2",
    "remainder", "clamp", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "popcnt", "clz", "erf",
    "logistic", "stochastic-convert",
}
DATA_MOVERS = {
    "copy", "copy-start", "transpose", "dynamic-slice", "dynamic-update-slice",
    "broadcast", "convert", "slice", "concatenate", "pad", "gather",
    "scatter", "reduce", "reduce-window", "sort", "reverse", "select-and-scatter",
    "iota", "rng", "rng-bit-generator", "custom-call", "cholesky",
    "triangular-solve", "fft", "convolution", "dot", "fusion",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
}
FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "add-dependency",
    "opt-barrier", "domain", "call", "while", "conditional", "copy-done",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "async-done", "send", "send-done", "recv", "recv-done",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> float:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_ops: dict = dataclasses.field(default_factory=dict)
    coll_bytes: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.coll_ops.items():
            self.coll_ops[k] = self.coll_ops.get(k, 0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult


class HloModule:
    """Parsed scheduled-HLO text: computations, call graph, loop trips."""

    def __init__(self, text: str):
        self.comps: dict[str, list[Instr]] = {}
        self._parse(text)
        self.entry = self._entry_name
        self._local: dict[str, Cost] = {}
        self._edges: dict[str, list] = {}
        for name in self.comps:
            self._local[name], self._edges[name] = self._cost_one(name)

    # -- parsing ------------------------------------------------------------

    def _parse(self, text: str):
        cur: Optional[str] = None
        self._entry_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HDR_RE.match(line)
                if m and ("->" in line):
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self._entry_name = cur
                continue
            if line == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m is None:
                continue
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            rest = line[m.end():]
            paren = rest.split(")", 1)[0]
            operands = _OPERAND_RE.findall(paren)
            self.comps[cur].append(Instr(name, type_str, op, operands, line))

    # -- per-computation local cost + call edges -----------------------------

    def _types(self, comp: str) -> dict:
        return {i.name: i.type_str for i in self.comps[comp]}

    def _fusion_flops(self, comp: str, seen=None) -> float:
        """Elementwise+dot flops of a fused computation (recursive)."""
        if seen is None:
            seen = set()
        if comp in seen or comp not in self.comps:
            return 0.0
        seen.add(comp)
        types = self._types(comp)
        flops = 0.0
        for i in self.comps[comp]:
            if i.op in ELEMENTWISE:
                flops += _shape_elems(i.type_str)
            elif i.op == "dot":
                flops += self._dot_flops(i, types)
            elif i.op in ("reduce", "reduce-window"):
                if i.operands and i.operands[0] in types:
                    flops += _shape_elems(types[i.operands[0]])
                else:
                    flops += _shape_elems(i.type_str)
            elif i.op == "fusion":
                m = _CALLS_RE.search(i.line)
                if m:
                    flops += self._fusion_flops(m.group(1), seen)
        return flops

    def _dot_flops(self, i: Instr, types: dict) -> float:
        out_elems = _shape_elems(i.type_str)
        k = 1.0
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", i.line)
        if m and i.operands and i.operands[0] in types:
            lhs_dims_m = _SHAPE_RE.search(types[i.operands[0]])
            if lhs_dims_m and lhs_dims_m.group(2):
                lhs_dims = [int(d) for d in lhs_dims_m.group(2).split(",")]
                for idx in (m.group(1) or "").split(","):
                    if idx != "" and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
        return 2.0 * out_elems * k

    def _operand_bytes(self, i: Instr, types: dict) -> float:
        return sum(_shape_bytes(types[o]) for o in i.operands if o in types)

    def _root_op(self, comp: str) -> str:
        if comp not in self.comps or not self.comps[comp]:
            return ""
        return self.comps[comp][-1].op

    def _dus_bytes(self, i: Instr, types: dict) -> float:
        """True traffic of an in-place dynamic-update-slice (fusion).

        XLA aliases the buffer operand with the result: only the updated
        slice is read+written. Counting operands+result would bill the full
        stacked KV cache per decode layer (observed 9 GiB/layer vs the real
        ~100 MiB slice). bytes = 2 * (sum(operands) - result), i.e. twice
        the non-buffer operands (update slice + indices + fused inputs).
        """
        r = _shape_bytes(i.type_str)
        ops = self._operand_bytes(i, types)
        return max(2.0 * (ops - r), r * 0.01)

    def _is_pure_convert(self, callee: str) -> bool:
        """True if the fused computation only converts/bitcasts a parameter.

        XLA CPU's float-normalization widens bf16 while-carries to f32 via
        whole-buffer convert fusions; these don't exist on TPU (native
        bf16), so we bill only the read side.
        """
        if callee not in self.comps:
            return False
        allowed = {"parameter", "convert", "bitcast", "copy", "broadcast",
                   "reshape", "transpose"}
        saw_convert = False
        for instr in self.comps[callee]:
            if instr.op == "convert":
                saw_convert = True
            elif instr.op not in allowed:
                return False
        return saw_convert

    def _fusion_io_bytes(self, i: Instr, callee: str, types: dict) -> float:
        """Fusion traffic with slice-aware operand accounting.

        A fusion operand that the fused computation only reads through
        dynamic-slice / slice ops (possibly behind bitcast/convert/copy
        chains) moves slice-sized bytes, not the whole buffer — this is how
        every lax.scan reads its per-layer xs slice; billing the full
        stacked weights per layer would overcount HBM traffic ~n_layers x.
        """
        if callee not in self.comps:
            return _shape_bytes(i.type_str) + self._operand_bytes(i, types)
        ctypes = self._types(callee)
        # map parameter name -> operand index
        params: dict[str, int] = {}
        for instr in self.comps[callee]:
            if instr.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", instr.line)
                if m:
                    params[instr.name] = int(m.group(1))
        # alias group: names that are pass-through views of a parameter
        alias_of: dict[str, str] = {p: p for p in params}
        passthrough = {"bitcast", "convert", "copy", "reshape", "transpose"}
        for instr in self.comps[callee]:
            if instr.op in passthrough and instr.operands:
                src = instr.operands[0]
                if src in alias_of:
                    alias_of[instr.name] = alias_of[src]
        # classify consumption per root parameter
        sliced_bytes: dict[str, float] = {}
        dus_buffer: set = set()
        full_use: set = set()
        for instr in self.comps[callee]:
            if instr.op == "parameter" or instr.op in passthrough:
                continue
            for j, o in enumerate(instr.operands):
                root = alias_of.get(o)
                if root is None:
                    continue
                if instr.op in ("dynamic-slice", "slice") and j == 0:
                    sliced_bytes[root] = sliced_bytes.get(root, 0.0) + _shape_bytes(
                        instr.type_str
                    )
                elif instr.op == "dynamic-update-slice" and j == 0:
                    # in-place buffer alias: bill read+write of the update
                    upd = instr.operands[1] if len(instr.operands) > 1 else None
                    ub = _shape_bytes(ctypes.get(upd, "")) if upd else 0.0
                    sliced_bytes[root] = sliced_bytes.get(root, 0.0) + 2.0 * ub
                    dus_buffer.add(root)
                else:
                    full_use.add(root)
        total = 0.0
        result_b = _shape_bytes(i.type_str)
        dus_inplace = 0.0
        for pname, idx in params.items():
            if idx >= len(i.operands):
                continue
            oname = i.operands[idx]
            full = _shape_bytes(types.get(oname, ""))
            if pname in full_use or pname not in sliced_bytes:
                total += full
            else:
                total += min(sliced_bytes[pname], full)
                if pname in dus_buffer:
                    dus_inplace = max(dus_inplace, full)
        # a DUS-rooted fusion writes in place: don't bill the full result
        if dus_inplace > 0 and result_b >= 0.5 * dus_inplace:
            pass  # write already billed as 2x update above
        else:
            total += result_b
        return total

    def instr_bytes(self, i: Instr, types: dict) -> float:
        """The billed HBM bytes of one instruction (shared with breakdown)."""
        op = i.op
        if op in FREE or op == "while" or op == "conditional" or op == "call":
            return 0.0
        base = op.replace("-start", "")
        if base in COLLECTIVES:
            f = _shape_bytes(i.type_str)
            if "-start" in op and (op.startswith("all-reduce") or op.startswith("all-gather")):
                f = f / 2.0
            return f + self._operand_bytes(i, types)
        if op == "dynamic-update-slice":
            return self._dus_bytes(i, types)
        if op == "dynamic-slice":
            return 2.0 * _shape_bytes(i.type_str)
        if op == "fusion":
            m = _CALLS_RE.search(i.line)
            if m and self._is_pure_convert(m.group(1)):
                return self._operand_bytes(i, types)
            if m:
                return self._fusion_io_bytes(i, m.group(1), types)
        return _shape_bytes(i.type_str) + self._operand_bytes(i, types)

    def _cost_one(self, comp: str):
        cost = Cost()
        edges: list = []
        types = self._types(comp)
        for i in self.comps[comp]:
            op = i.op
            if op == "while":
                trips = 1
                m = _TRIP_RE.search(i.line)
                if m:
                    trips = int(m.group(1))
                b = _BODY_RE.search(i.line)
                c = _COND_RE.search(i.line)
                if b:
                    edges.append((b.group(1), trips))
                if c:
                    edges.append((c.group(1), trips))
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*%([\w\.\-]+)", i.line):
                    edges.append((m.group(1), 1))
                continue
            if op == "call":
                m = _TO_APPLY_RE.search(i.line)
                if m:
                    edges.append((m.group(1), 1))
                continue

            base = op.replace("-start", "")
            if base in COLLECTIVES:
                g = _group_size(i.line)
                if g <= 1:
                    continue
                f = _shape_bytes(i.type_str)
                if op.startswith("all-reduce") or op.startswith("all-gather"):
                    # -start result repeats the operand: halve (operand, result)
                    if "-start" in op:
                        f = f / 2.0
                ring = (g - 1) / g
                if base == "all-reduce":
                    wire = 2.0 * f * ring
                elif base == "all-gather":
                    wire = f * ring
                elif base == "reduce-scatter":
                    wire = f * g * ring
                elif base == "collective-permute":
                    wire = f
                else:
                    wire = f * ring
                cost.wire_bytes += wire
                cost.bytes += f + self._operand_bytes(i, types)
                cost.coll_ops[base] = cost.coll_ops.get(base, 0) + 1
                cost.coll_bytes[base] = cost.coll_bytes.get(base, 0.0) + f
                continue

            if op == "dot":
                cost.flops += self._dot_flops(i, types)
                cost.bytes += _shape_bytes(i.type_str) + self._operand_bytes(i, types)
            elif op == "dynamic-update-slice":
                cost.bytes += self._dus_bytes(i, types)
            elif op == "dynamic-slice":
                cost.bytes += 2.0 * _shape_bytes(i.type_str)
            elif op == "fusion":
                m = _CALLS_RE.search(i.line)
                if m:
                    cost.flops += self._fusion_flops(m.group(1))
                if m and self._is_pure_convert(m.group(1)):
                    cost.bytes += self._operand_bytes(i, types)   # read only
                elif m:
                    cost.bytes += self._fusion_io_bytes(i, m.group(1), types)
                else:
                    cost.bytes += _shape_bytes(i.type_str) + self._operand_bytes(i, types)
            elif op in ELEMENTWISE:
                cost.flops += _shape_elems(i.type_str)
                cost.bytes += _shape_bytes(i.type_str) + self._operand_bytes(i, types)
            elif op in ("reduce", "reduce-window"):
                cost.flops += (
                    _shape_elems(types[i.operands[0]])
                    if i.operands and i.operands[0] in types
                    else _shape_elems(i.type_str)
                )
                cost.bytes += _shape_bytes(i.type_str) + self._operand_bytes(i, types)
            elif op in DATA_MOVERS:
                cost.bytes += _shape_bytes(i.type_str) + self._operand_bytes(i, types)
            # FREE ops: no cost
        return cost, edges

    # -- aggregation ----------------------------------------------------------

    def total_cost(self) -> Cost:
        total = Cost()
        seen_stack = set()

        def visit(comp: str, mult: float):
            if comp not in self.comps or comp in seen_stack:
                return
            seen_stack.add(comp)
            total.add(self._local[comp], mult)
            for callee, m in self._edges[comp]:
                visit(callee, mult * m)
            seen_stack.discard(comp)

        visit(self.entry, 1.0)
        return total


def analyze(compiled, *, peak_flops: float = hw.PEAK_FLOPS_BF16) -> dict:
    """Loop-aware roofline terms (per device, seconds) + raw counters."""
    mod = HloModule(compiled.as_text())
    c = mod.total_cost()

    # cross-check: XLA's own (loop-unaware) analysis
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]

    t_compute = c.flops / peak_flops
    t_memory = c.bytes / hw.HBM_BW
    t_coll = c.wire_bytes / hw.ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "wire_bytes_per_device": c.wire_bytes,
        "collective_ops": c.coll_ops,
        "collective_buffer_bytes": c.coll_bytes,
        "xla_flops_noloop": float(ca.get("flops", 0.0)),
        "xla_bytes_noloop": float(ca.get("bytes accessed", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }


# kept for callers that want the legacy name
roofline_terms = analyze


def memory_stats(compiled) -> dict:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": int(ms.argument_size_in_bytes),
        "output_bytes": int(ms.output_size_in_bytes),
        "temp_bytes": int(ms.temp_size_in_bytes),
        "alias_bytes": int(ms.alias_size_in_bytes),
        "peak_bytes_est": int(
            ms.argument_size_in_bytes
            + ms.temp_size_in_bytes
            + ms.output_size_in_bytes
            - ms.alias_size_in_bytes
        ),
    }
