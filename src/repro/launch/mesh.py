"""Production meshes for the multi-pod dry-run and the launchers.

v5e target: one pod = 16x16 = 256 chips. Single-pod mesh is
("data", "model") = (16, 16); the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips) used for inter-pod data parallelism.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types (Auto = today's behavior)
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: Auto is the only (implicit) behavior
    AxisType = None

    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh():
    """Whatever devices exist (CPU smoke tests / examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_axis_kw(2))


# Hardware constants for the roofline analysis (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
PEAK_OPS_INT8 = 394e12        # OP/s  (the 2x 4-bit-BFP claim maps here)
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~ per-device usable)
