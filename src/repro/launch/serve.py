"""Serving launcher: offline HiF4 PTQ + batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16 --quant hif4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.qlinear import QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import ServeConfig, serve
from repro.sharding.rules import ShardCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default="hif4")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    ctx = ModelCtx(quant=QuantConfig(fmt=args.quant),
                   shard=ShardCtx(mesh=mesh), remat=False,
                   attn_q_chunk=32, attn_k_chunk=32)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    toks = serve(cfg, params, prompts, ctx,
                 ServeConfig(max_new_tokens=args.new_tokens))
    for i in range(args.batch):
        print(f"request {i}: {toks[i].tolist()}")


if __name__ == "__main__":
    main()
