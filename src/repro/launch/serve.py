"""Serving launcher: offline HiF4 packing/PTQ + batched scan decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16 --policy paper-iv \
        --impl packed --kv-format hif4

``--policy`` selects the per-site quantization placement (see
docs/EXECUTION.md §Policy resolution): a preset (``paper-iv``,
``uniform:hif4``, ``nvfp4-baseline``, ``sensitive-fallback``) or a policy
JSON file; the launcher prints the resolved plan — one line per site with
its format, impl, and resident artifact — next to the fused-kernel and
residency lines. Without ``--policy`` the legacy ``--quant``/``--impl``
global config applies (identical to ``uniform:<fmt>``).

``--impl`` picks the execution path (see docs/EXECUTION.md): ``packed``
(default) serves real 4.5-bit resident weights through the fused
dequantize-in-kernel matmul (Pallas on TPU, its XLA twin elsewhere);
``qdq`` is the fake-quant accuracy shape; ``pallas`` adds the fixed-point
kernels for dense weights too (interpret mode off TPU — slow on CPU, use
tiny shapes). ``--kv-format hif4`` additionally stores the decode KV cache
at 4.5 bits/value (docs/FORMATS.md) — KV storage stays cache-global.

``--kv-pages N`` (requires ``--kv-format hif4``) swaps the whole-slot
decode cache for the fixed page pool: requests are served through the
paged continuous-batching scheduler (page-granular admission, COW prefix
sharing, LRU eviction / preemption — docs/EXECUTION.md) and the launcher
prints pool residency and scheduler counters instead of the dense
slots x capacity line. ``--kv-page-tokens`` sets the page size.

``--guard`` arms the health sentinels (docs/EXECUTION.md §Failure
semantics): NaN/Inf logits detection fused into the decode scan, per-chunk
0xFF-meta and page-checksum audits over packed KV, quarantine + qdq/bf16
fallback retry, and per-request status reporting (printed per request).
``--inject-fault kind[:key=value,...]`` drives one deterministic fault
through :mod:`repro.runtime.faults` to demonstrate detection/containment,
e.g. ``--inject-fault meta_flip:seed=3,target_request=1,after_chunk=1``.
Both flags route serving through the request scheduler (transformer
families only).

``--journal-dir DIR`` makes the serve crash-safe (docs/EXECUTION.md
§Crash recovery): a write-ahead request journal under DIR records every
admission, per-chunk emission, and terminal status (fsynced once per
decode chunk), and ``--checkpoint-every N`` adds a durable page-pool
checkpoint every N chunks. After a crash — including an injected
``crash_*`` fault — rerunning with ``--resume`` replays the journal:
finished requests' results are injected verbatim, checkpointed residents
restored byte-for-byte, the rest re-prefilled, and every re-served
output is verified bitwise against its journaled token prefix. The
launcher prints journal/checkpoint residency and, on resume, the
recovery report.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.policy import get_policy
from repro.core.qlinear import PackedW, QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import GuardConfig, ServeConfig, serve
from repro.runtime import faults
from repro.runtime.serve_loop import (
    packed_weight_bytes,
    prepare_params_for_serving,
    resolve_kv_format,
    serve_requests,
)
from repro.sharding.rules import ShardCtx


def _leaf_at(tree, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _print_plan(plan, serving_params):
    """The resolved policy plan, one line per site: what each weight site
    quantizes to and what is actually resident for it."""
    print(f"policy plan [{plan.policy.name}] "
          f"({len(plan.packed_paths)}/{len(plan.sites)} sites packed):")
    print(f"  {'site':<18} {'fmt':<10} {'impl':<7} {'resident artifact':<34} "
          f"{'bytes':>12}")
    for site in plan.sites:
        leaf = _leaf_at(serving_params, site.path)
        if isinstance(leaf, PackedW):
            nbytes = leaf.nbytes_packed
            art = f"PackedW 4.5-bit ({nbytes / leaf.n_values:.4f} B/value)"
        elif leaf is None:
            nbytes = 0
            art = "(tied -> embed)" if site.path == "lm_head" else "(absent)"
        else:
            nbytes = int(leaf.nbytes)
            art = (f"qdq {leaf.dtype} (offline PTQ)"
                   if site.cfg.enabled and site.quantize_offline
                   else str(leaf.dtype))
        print(f"  {site.path:<18} {site.cfg.fmt:<10} {site.cfg.impl:<7} "
              f"{art:<34} {nbytes:>12,}")


def _print_kernel_dispatch(serving_params, ctx, args):
    """One line per serving regime: is the fused dequantize-in-kernel matmul
    active for the resident PackedW weights, and with which tile sizes."""
    from repro.core.engine import packed_dispatch_info
    from repro.core.qlinear import PackedW

    pws = [leaf for leaf in jax.tree_util.tree_leaves(
        serving_params, is_leaf=lambda x: isinstance(x, PackedW))
        if isinstance(leaf, PackedW)]
    if not pws:
        return
    # representative weight: a per-layer slice of the first (stacked) leaf
    pw = pws[0]
    if pw.codes.ndim > (2 if pw.kernel_layout else 3):
        pw = jax.tree_util.tree_map(lambda b: b[0], pw)
    info = packed_dispatch_info(ctx.quant, pw, decode_m=args.batch,
                                prefill_m=args.batch * args.prompt_len)
    if not info["fused"]:
        print("packed matmul: dequantize-then-dot fallback "
              "(fused kernel needs impl=packed|pallas, fmt=hif4, "
              "both-operand quantization)")
        return
    k, n = pw.shape2d
    line = f"packed matmul: fused [{info['execution']}] on e.g. (K={k}, N={n})"
    if info["decode_blocks"] is not None:
        line += (f"; blocks decode(bm,bn,bk)={info['decode_blocks']} "
                 f"prefill={info['prefill_blocks']}")
    print(line)


def _print_attention_dispatch(cfg, ctx, capacity):
    """One line for the packed-KV decode hot path: fused Pallas kernel vs
    XLA twin, and the KV tile size either execution streams — next to the
    fused-matmul and residency prints, the whole packed story at a glance."""
    from repro.core.engine import attention_dispatch_info

    a = cfg.attn
    g, t = kvcache.split_features(a.n_kv_heads, a.d_head)
    # shape probe only: dispatch reads ranks/shapes, never the bytes
    probe = {
        "codes": jax.ShapeDtypeStruct((1, g * 32, capacity), jnp.uint8),
        "meta": jax.ShapeDtypeStruct((1, g, capacity), jnp.uint32),
        "tail": jax.ShapeDtypeStruct((1, t, capacity), jnp.bfloat16),
    }
    info = attention_dispatch_info(ctx.quant, probe,
                                   n_kv_heads=a.n_kv_heads, d_head=a.d_head)
    print(f"packed attention: {'fused' if info['fused'] else 'twin'} "
          f"[{info['execution']}] kv tile {info['block_kv']} of "
          f"{capacity} slots")


def _print_journal_residency(directory):
    from repro.runtime.journal import journal_residency

    res = journal_residency(directory)
    print(f"journal residency [{directory}]: "
          f"{res['journal_bytes']} B journal, "
          f"{res['checkpoints']} checkpoint(s) = "
          f"{res['checkpoint_bytes']} B")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default="hif4")
    ap.add_argument("--impl", default="packed",
                    choices=["qdq", "packed", "pallas"])
    ap.add_argument("--decode-chunk", type=int, default=0,
                    help="tokens per jitted decode scan (0 = whole budget)")
    ap.add_argument("--kv-format", default="bf16",
                    choices=list(kvcache.KV_FORMATS),
                    help="decode KV-cache storage (hif4 = 4.5 bits/value)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="> 0: paged KV pool with this many pages "
                         "(page-granular admission + COW prefix sharing; "
                         "requires --kv-format hif4)")
    ap.add_argument("--kv-page-tokens", type=int,
                    default=kvcache.DEFAULT_PAGE_TOKENS,
                    help="tokens per KV pool page")
    ap.add_argument("--guard", action="store_true",
                    help="arm the serving health sentinels: NaN scan flag, "
                         "packed-KV audits, quarantine + fallback retry, "
                         "per-request status reports")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline (implies --guard)")
    ap.add_argument("--inject-fault", default=None, metavar="SPEC",
                    help="deterministic fault injection, "
                         "kind[:key=value,...] with kinds "
                         + "/".join(faults.FAULT_CLASSES)
                         + " (implies --guard)")
    ap.add_argument("--policy", default=None,
                    help="per-site quantization policy: a preset name "
                         "(paper-iv, uniform:<fmt>, nvfp4-baseline, "
                         "sensitive-fallback) or a policy JSON file; "
                         "overrides --quant")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="crash-safe serving: write-ahead request journal "
                         "(+ pool checkpoints) under DIR; routes through "
                         "the request scheduler")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="durable pool checkpoint every N decode chunks "
                         "(0 = journal only; paged scheduler)")
    ap.add_argument("--resume", action="store_true",
                    help="recover from the journal in --journal-dir: "
                         "journaled terminal results are injected, "
                         "checkpointed residents restored, the rest "
                         "re-prefilled — outputs bitwise identical to an "
                         "uninterrupted run")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    kv = kvcache.KVCacheConfig(args.kv_format)
    plan = None
    if args.policy is not None:
        policy = get_policy(args.policy, impl=args.impl, kv=kv)
        plan = lm.quant_plan(cfg, policy)
        quant = plan.base
    else:
        quant = QuantConfig(fmt=args.quant, impl=args.impl, kv=kv)
    ctx = ModelCtx(quant=quant, plan=plan,
                   shard=ShardCtx(mesh=mesh), remat=False,
                   attn_q_chunk=32, attn_k_chunk=32)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    serving_params = prepare_params_for_serving(params, cfg,
                                                ctx.plan or ctx.quant)
    if plan is not None:
        _print_plan(plan, serving_params)
    nbytes, nvals = packed_weight_bytes(serving_params)
    if nvals:
        print(f"packed weight residency: {nbytes / 2**20:.2f} MiB for "
              f"{nvals} values = {nbytes / nvals:.4f} B/value "
              f"(bf16 would be {2 * nvals / 2**20:.2f} MiB)")
        _print_kernel_dispatch(serving_params, ctx, args)
    else:
        print(f"impl={args.impl}: no packed weights resident "
              f"(fake-quant bf16 artifact)")

    guard = None
    if args.guard or args.deadline_s is not None or args.inject_fault:
        guard = GuardConfig(deadline_s=args.deadline_s)
    injector = (faults.FaultInjector(faults.parse_fault(args.inject_fault))
                if args.inject_fault else None)
    sc = ServeConfig(max_new_tokens=args.new_tokens,
                     decode_chunk=args.decode_chunk,
                     kv_pages=args.kv_pages,
                     kv_page_tokens=args.kv_page_tokens,
                     guard=guard,
                     journal_dir=args.journal_dir,
                     checkpoint_every=args.checkpoint_every)
    a = cfg.attn
    kv_fmt = None
    if a is None:
        print("kv cache residency: n/a (attention-free family)")
    else:
        # verbose: the hybrid/audio bf16 fallback prints loudly here
        kv_fmt = resolve_kv_format(cfg, ctx.quant, sc, verbose=True)
        cap = args.prompt_len + args.new_tokens
        per_tok = kvcache.kv_bytes_per_token(
            a.n_kv_heads, a.d_head, kv_fmt) * cfg.n_layers
        bf16_tok = kvcache.kv_bytes_per_token(
            a.n_kv_heads, a.d_head, "bf16") * cfg.n_layers
        if args.kv_pages:
            pg = kvcache.page_nbytes(a.n_kv_heads, a.d_head,
                                     args.kv_page_tokens, cfg.n_layers)
            print(f"kv page pool [{kv_fmt}]: {args.kv_pages} pages x "
                  f"{args.kv_page_tokens} tokens ({pg} B/page) = "
                  f"{args.kv_pages * pg / 2**20:.2f} MiB "
                  f"(whole-slot equivalent: "
                  f"{per_tok * cap * args.batch / 2**20:.2f} MiB for "
                  f"{args.batch} slots x {cap} capacity)")
        else:
            total = per_tok * cap * args.batch
            print(f"kv cache residency [{kv_fmt}]: {per_tok} B/token "
                  f"(bf16: {bf16_tok}) x {cap} capacity x {args.batch} slots "
                  f"= {total / 2**20:.2f} MiB"
                  + (f"  [{bf16_tok / per_tok:.2f}x more slots per byte]"
                     if kv_fmt == "hif4" else ""))
        if kv_fmt == "hif4":
            _print_attention_dispatch(cfg, ctx, cap)

    # family-correct prefill inputs: audio takes encoder frames, vlm
    # takes projected embeds, everything else token ids
    from repro.runtime.scenario import prefill_batch

    batch = prefill_batch(cfg, args.batch, args.prompt_len)
    tokens = batch.get("tokens")
    # packed impls reuse the converted tree (prepare is idempotent on it);
    # the qdq artifact is re-derived inside serve from the raw weights
    sparams = serving_params if nvals else params
    try:
        if args.kv_pages:
            assert tokens is not None, (
                "--kv-pages serves token requests (dense/vlm-embeds not "
                "supported by the paged scheduler entry)")
            assert kv_fmt == "hif4", (
                "--kv-pages requires --kv-format hif4 on a KV-cache family "
                "(the page pool stores packed HiF4 pages)")
            stats: dict = {}
            res = serve_requests(cfg, sparams, list(tokens), ctx, sc,
                                 slots=args.batch, stats=stats,
                                 injector=injector, resume=args.resume)
            print(f"paged scheduler: max {stats['max_concurrent']} "
                  f"concurrent, {stats['shared_page_hits']} shared-page "
                  f"hits, {stats['preemptions']} preemptions, "
                  f"{stats['evictions']} LRU evictions, peak "
                  f"{stats['peak_live_pages']}/{args.kv_pages} pages live")
            toks = jnp.stack(res)
        elif guard is not None or args.journal_dir is not None:
            # guarded/journaled serving is per-request — route through the
            # request scheduler even without the page pool
            assert tokens is not None, (
                "--guard/--inject-fault/--journal-dir serve token requests "
                "through the request scheduler (dense/vlm-embeds not "
                "supported)")
            stats = {}
            res = serve_requests(cfg, sparams, list(tokens), ctx, sc,
                                 slots=args.batch, stats=stats,
                                 injector=injector, resume=args.resume)
            toks = jnp.stack(res)
        else:
            stats = None
            toks = serve(cfg, sparams, batch, ctx, sc)
    except faults.SimulatedCrash as crash:
        # the injected process kill: report what the journal holds and
        # exit cleanly so CI smoke runs can chain a --resume invocation
        print(f"simulated crash: {crash}")
        if args.journal_dir is not None:
            _print_journal_residency(args.journal_dir)
        print("resume with: --journal-dir", args.journal_dir, "--resume")
        return
    if args.journal_dir is not None:
        _print_journal_residency(args.journal_dir)
        if args.resume and stats is not None and "recovery" in stats:
            rec = stats["recovery"]
            print(f"recovery report: {rec['completed']} journaled results "
                  f"injected, {rec['replayed']} residents restored from "
                  f"checkpoint, {rec['re_prefilled']} re-prefilled, "
                  f"{rec['dropped_bytes']} torn journal bytes dropped, "
                  f"{rec['verified']} replay prefixes verified bitwise "
                  f"({rec['recovery_ms']:.1f} ms plan build)")
    if injector is not None:
        for kind, detail in injector.events:
            print(f"injected fault: {kind} {detail}")
    if guard is not None and stats is not None:
        counts = {k: stats[k] for k in
                  ("quarantined", "retried", "rejected", "timeouts")}
        print(f"guarded serving: {counts}")
        for rid in sorted(stats["reports"]):
            rep = stats["reports"][rid]
            line = f"request {rid}: status={rep['status']}"
            if rep["detail"]:
                line += f" ({rep['detail']})"
            print(line)
    for i in range(args.batch):
        print(f"request {i}: {toks[i].tolist()}")


if __name__ == "__main__":
    main()
