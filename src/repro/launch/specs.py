"""Abstract input specs for every (architecture x shape) cell.

``input_specs(cfg, shape)`` returns a PSpec tree describing the step inputs
(ShapeDtypeStruct stand-ins at lowering time — weak-type-correct, shardable,
zero device allocation):

  train   -> {"tokens"} | {"embeds","labels"} | {"frames","tokens"}
  prefill -> the same minus labels
  decode  -> {"token"} plus the decode-cache spec (KV of seq_len capacity)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.models.params import PSpec


def _tokens(b: int, s: int) -> PSpec:
    return PSpec((b, s), ("batch", "act_seq"), dtype=jnp.int32, init="zeros")


def _embeds(b: int, s: int, d: int) -> PSpec:
    return PSpec((b, s, d), ("batch", "act_seq", None), dtype=jnp.bfloat16)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Specs for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    if cfg.family == "audio":
        # seq_len = encoder frames; decoder context matches for train
        out = {"frames": _embeds(b, s, cfg.d_model)}
        if kind == "train":
            out["tokens"] = _tokens(b, s)
        return out
    if cfg.embeds_input:
        out = {"embeds": _embeds(b, s, cfg.d_model)}
        if kind == "train":
            out["labels"] = _tokens(b, s)
        return out
    return {"tokens": _tokens(b, s)}


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Specs for a serve_step: next token ids + cache at seq_len capacity."""
    b, s = shape.global_batch, shape.seq_len
    return {
        "token": PSpec((b,), ("batch",), dtype=jnp.int32, init="zeros"),
        "cache": lm.abstract_cache(cfg, b, s),
    }
