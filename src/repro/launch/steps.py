"""Step functions: the units the dry-run lowers and the runtime executes.

  train_step   — loss + grads (optionally microbatched) + AdamW update
  prefill_step — prompt -> (first sampled token, decode cache)
  serve_step   — (cache, token) -> (next token, cache); the decode unit
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.optim.adamw import AdamWConfig, adamw_update


def _constrain_batch(batch: dict, ctx: ModelCtx) -> dict:
    out = {}
    for k, v in batch.items():
        if v.ndim == 2:
            out[k] = ctx.shard.constrain(v, "batch", "act_seq")
        elif v.ndim == 3:
            out[k] = ctx.shard.constrain(v, "batch", "act_seq", None)
        else:
            out[k] = ctx.shard.constrain(v, "batch")
    return out


def make_train_step(cfg: ArchConfig, ctx: ModelCtx, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, param_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, stats).

    ``param_pspecs`` (tree of PartitionSpecs matching params): when given,
    gradients and the microbatch accumulator are sharding-constrained to
    the parameters' layout. Without it, XLA's propagation leaves the f32
    accumulator ambiguous and materializes full-size per-layer gradient
    all-reduces inside the microbatch scan (measured: 2 GiB x 96 layers x
    8 microbatches of wire on the 340B train cell); with it, the backward
    reduce-scatters straight into the ZeRO/FSDP shard.
    """

    def loss_fn(params, mb):
        return lm.train_loss(params, _constrain_batch(mb, ctx), cfg, ctx)

    def constrain_grads(grads):
        if param_pspecs is None or ctx.shard.mesh is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, param_pspecs,
        )

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_grads(grads)
        else:
            m = num_microbatches

            def split(x):
                assert x.shape[0] % m == 0, (x.shape, m)
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            g0 = constrain_grads(g0)

            def acc(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads = constrain_grads(grads)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
                )
                return (loss_acc + loss, constrain_grads(grad_acc)), None

            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), g0), mbs)
            loss = loss / m
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)

        new_params, new_opt, stats = adamw_update(params, grads, opt_state, opt_cfg)
        stats = dict(stats, loss=loss)
        return new_params, new_opt, stats

    return train_step


def make_prefill_step(cfg: ArchConfig, ctx: ModelCtx):
    def prefill_step(params, batch):
        logits, cache = lm.prefill(params, _constrain_batch(batch, ctx), cfg, ctx)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache

    return prefill_step


def make_serve_step(cfg: ArchConfig, ctx: ModelCtx):
    """One greedy decode step. Cache is functionally updated; the runtime
    donates it so XLA updates in place."""

    def serve_step(params, cache, token):
        logits, new_cache = lm.decode_step(params, token, cache, cfg, ctx)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return serve_step
