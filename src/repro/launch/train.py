"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 100 --quant hif4 [--ckpt-dir /tmp/ckpt]

Full-size configs on real hardware use the same entry point without
--reduced; the mesh is built from whatever devices the runtime exposes
(data x model), and the step function is the exact one the multi-pod
dry-run lowers.
"""
import argparse

import jax

from repro.configs import get_arch
from repro.core.qlinear import QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelCtx
from repro.runtime import TrainLoopConfig, train
from repro.sharding.rules import ShardCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--quant", default="hif4")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    ctx = ModelCtx(
        quant=QuantConfig(fmt=args.quant),
        shard=ShardCtx(mesh=mesh),
        remat=not args.reduced,
        attn_q_chunk=min(512, args.seq_len),
        attn_k_chunk=min(1024, args.seq_len),
    )
    _, _, hist = train(cfg, ctx, TrainLoopConfig(
        steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, checkpoint_dir=args.ckpt_dir,
        num_microbatches=args.microbatches,
    ), on_step=lambda s, st: (
        print(f"step {s:5d} loss {st['loss']:.4f} ({st['time'] * 1e3:.0f}ms)")
        if s % 10 == 0 else None
    ))
    print(f"final loss: {hist['loss'][-1]:.4f}; "
          f"stragglers flagged: {len(hist['stragglers'])}")


if __name__ == "__main__":
    main()
