"""Memory-efficient attention (flash-style chunked online softmax) + GQA.

Never materializes the S x S score matrix: queries are processed in chunks
(outer scan) and keys/values in chunks (inner scan) with running
(max, denominator, accumulator) state — the standard FlashAttention
recurrence expressed in pure jnp so it lowers on any backend and lets XLA
overlap the KV-chunk loop with TP collectives.

The BACKWARD is a custom VJP (:func:`flash_mha`) that recomputes
probabilities chunk-by-chunk from the saved log-sum-exp — differentiating
the naive scan instead makes JAX stack per-chunk probabilities into full
S x S buffers (observed: 2+ GiB per layer at 4k context on the dry-run),
which is exactly the failure FlashAttention exists to avoid.

GQA is computed without materializing repeated KV: q is reshaped to
(B, S, Hkv, rep, D) and contracted against (B, S, Hkv, D).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class AttnChunking(NamedTuple):
    q_chunk: int = 512
    k_chunk: int = 1024


def _chunks(n: int, c: int) -> int:
    c = min(c, n)
    assert n % c == 0, f"seq {n} not divisible by chunk {c}"
    return n // c


def _flash_fwd_impl(q, k, v, causal, q_offset, kv_valid_len, chunking):
    """Chunked online-softmax forward. Returns (out, lse).

    out (B, Sq, H, D) in q.dtype; lse (B, Hkv, rep, Sq) f32 log-sum-exp of the
    scaled scores (the residual the flash backward needs).
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / (D ** 0.5)

    nq = _chunks(Sq, chunking.q_chunk)
    nk = _chunks(Sk, chunking.k_chunk)
    cq, ck = Sq // nq, Sk // nk

    # Inputs stay bf16 (never materialize f32 copies of K/V — XLA hoists
    # such converts out of the KV loop into a full-cache f32 copy);
    # accumulation is f32 via preferred_element_type.
    qc = q.reshape(B, nq, cq, Hkv, rep, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, cq)
    k_pos = jnp.arange(Sk).reshape(nk, ck)

    def q_body(_, qi):
        qblk = qc[:, qi]                       # (B, cq, Hkv, rep, D)
        qp = q_pos[qi]                         # (cq,)

        def kv_body(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp = kc[:, ki], vc[:, ki], k_pos[ki]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if kv_valid_len is not None:
                valid = kp[None, :] < kv_valid_len[:, None]   # (B, ck)
                s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            correction = jnp.exp(m - m_new)
            l_new = l * correction + jnp.sum(p, axis=-1)
            acc_new = acc * correction[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, rep, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, rep, cq), jnp.float32),
            jnp.zeros((B, Hkv, rep, cq, D), jnp.float32),
        )
        if causal and kv_valid_len is None:
            # skip fully-masked KV chunks: only scan ki with any kp <= max qp
            max_qp = q_offset + (qi + 1) * cq - 1
            n_live = jnp.minimum((max_qp // ck) + 1, nk)
        else:
            n_live = nk

        def guarded(carry, ki):
            new, _ = kv_body(carry, ki)
            keep = ki < n_live
            out = jax.tree_util.tree_map(
                lambda a, b: jnp.where(keep, a, b), new, carry
            )
            return out, None

        (m, l, acc), _ = jax.lax.scan(guarded, init, jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]                               # (B,Hkv,rep,cq,D)
        out = jnp.moveaxis(out, 3, 1).reshape(B, cq, Hkv * rep, D)
        lse = m + jnp.log(l)                                   # (B,Hkv,rep,cq)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)        # (B,Sq,H,D)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, rep, Sq)    # (B,Hkv,rep,Sq)
    return out.astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_mha(q, k, v, causal: bool, q_offset: int, chunking: AttnChunking):
    """Differentiable flash attention (training path; no kv_valid_len)."""
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, None, chunking)
    return out


def _flash_mha_fwd(q, k, v, causal, q_offset, chunking):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, None, chunking)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(causal, q_offset, chunking, res, dout):
    """Two-pass chunked backward: dq over q-chunks, dk/dv over kv-chunks.

    Probabilities are recomputed per (q-chunk, kv-chunk) tile from the saved
    lse — O(S * D) residual memory, never an S x S buffer.
    """
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / (D ** 0.5)
    nq = _chunks(Sq, chunking.q_chunk)
    nk = _chunks(Sk, chunking.k_chunk)
    cq, ck = Sq // nq, Sk // nk

    # keep all big operands bf16; accumulate in f32 (preferred_element_type)
    qc = q.reshape(B, nq, cq, Hkv, rep, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)
    doc = dout.reshape(B, nq, cq, Hkv, rep, D)
    lsec = lse.reshape(B, Hkv, rep, nq, cq)
    # delta = rowsum(dout * out): (B, Hkv, rep, nq, cq)
    delta = jnp.einsum(
        "bsgrd,bsgrd->bgrs",
        dout.reshape(B, Sq, Hkv, rep, D),
        out.reshape(B, Sq, Hkv, rep, D),
        preferred_element_type=jnp.float32,
    ).reshape(B, Hkv, rep, nq, cq)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, cq)
    k_pos = jnp.arange(Sk).reshape(nk, ck)

    def tile(qi, ki):
        """Recompute p and ds for one (qi, ki) tile (f32, tile-sized)."""
        qblk = qc[:, qi]                                   # (B,cq,Hkv,rep,D)
        kblk = kc[:, ki]                                   # (B,ck,Hkv,D)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_pos[qi][:, None] >= k_pos[ki][None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lsec[:, :, :, qi, :, None])        # (B,Hkv,rep,cq,ck)
        doblk = doc[:, qi]                                 # (B,cq,Hkv,rep,D)
        dp = jnp.einsum("bqgrd,bkgd->bgrqk", doblk, vc[:, ki],
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, :, :, qi, :, None])        # (B,Hkv,rep,cq,ck)
        return p, ds, doblk

    dt16 = q.dtype

    # ---- pass 1: dq (scan q chunks; inner over kv chunks) ----
    def dq_body(_, qi):
        if causal:
            n_live = jnp.minimum(((q_offset + (qi + 1) * cq - 1) // ck) + 1, nk)
        else:
            n_live = nk

        def inner(dq_blk, ki):
            _, ds, _ = tile(qi, ki)
            contrib = jnp.einsum("bgrqk,bkgd->bqgrd", ds.astype(dt16), kc[:, ki],
                                 preferred_element_type=jnp.float32) * scale
            keep = ki < n_live
            return dq_blk + jnp.where(keep, contrib, 0.0), None

        dq0 = jnp.zeros((B, cq, Hkv, rep, D), jnp.float32)
        dq_blk, _ = jax.lax.scan(inner, dq0, jnp.arange(nk))
        return None, dq_blk

    _, dqs = jax.lax.scan(dq_body, None, jnp.arange(nq))       # (nq,B,cq,...)
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, H, D)

    # ---- pass 2: dk, dv (scan kv chunks; inner over q chunks) ----
    def dkv_body(_, ki):
        if causal:
            # only q chunks whose max position reaches this kv chunk
            first_live = (k_pos[ki][0] - q_offset) // cq
            first_live = jnp.maximum(first_live, 0)
        else:
            first_live = 0

        def inner(carry, qi):
            dk_blk, dv_blk = carry
            p, ds, doblk = tile(qi, ki)
            dvc = jnp.einsum("bgrqk,bqgrd->bkgd", p.astype(dt16), doblk,
                             preferred_element_type=jnp.float32)
            dkc = jnp.einsum("bgrqk,bqgrd->bkgd", ds.astype(dt16), qc[:, qi],
                             preferred_element_type=jnp.float32) * scale
            keep = qi >= first_live
            dk_blk = dk_blk + jnp.where(keep, dkc, 0.0)
            dv_blk = dv_blk + jnp.where(keep, dvc, 0.0)
            return (dk_blk, dv_blk), None

        z = jnp.zeros((B, ck, Hkv, D), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(inner, (z, z), jnp.arange(nq))
        return None, (dk_blk, dv_blk)

    _, (dks, dvs) = jax.lax.scan(dkv_body, None, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hkv, D)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hkv, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def flash_attention(
    q: jax.Array,                     # (B, Sq, H, D)
    k: jax.Array,                     # (B, Sk, Hkv, D)
    v: jax.Array,                     # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    q_offset: int = 0,                # absolute position of q[0] (decode)
    kv_valid_len: Optional[jax.Array] = None,   # (B,) valid KV prefix length
    chunking: AttnChunking = AttnChunking(),
) -> jax.Array:
    if kv_valid_len is None:
        return flash_mha(q, k, v, causal, q_offset, chunking)
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, kv_valid_len, chunking)
    return out


def decode_attention(
    q: jax.Array,                    # (B, H, D) single query token
    k_cache: jax.Array,              # (B, S, Hkv, D)
    v_cache: jax.Array,              # (B, S, Hkv, D)
    length: jax.Array,               # (B,) number of valid cache entries
) -> jax.Array:
    """One-token attention against the KV cache (length-masked softmax).

    The cache is consumed in its own dtype (bf16) with f32 accumulation —
    an .astype(f32) here would make XLA hoist a full f32 copy of the whole
    multi-layer cache out of the layer loop (observed: +27 GiB/device on
    the 340B decode dry-run).
    """
    B, S, Hkv, D = k_cache.shape
    H = q.shape[1]
    rep = H // Hkv
    qf = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bgrd,bsgd->bgrs", qf, k_cache,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < length[:, None]           # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Packed-KV decode: consume an HiF4 4.5-bit cache (repro.core.kvcache)
# ---------------------------------------------------------------------------


def decode_attention_packed(
    q: jax.Array,                    # (B, H, D) single query token
    k_cache: dict,                   # packed leaves {codes, meta, tail},
    v_cache: dict,                   #   either kvcache layout
    length: jax.Array,               # (B,) number of valid cache entries
    n_kv_heads: int,
    d_head: int,
) -> jax.Array:
    """One-token attention against an HiF4-packed KV cache (the non-fused
    models-level fallback; the serving hot path dispatches through
    ``repro.core.engine.attention_decode``).

    Routed through the bounded per-tile loader recurrence
    (:func:`flash_mha_vec_packed` with Sq=1): each KV chunk dequantizes to
    bf16 transiently inside the scan body, so the bf16 working set is ONE
    (B, k_chunk, Hkv, Dh) chunk — never the (B, S, Hkv, Dh) cache the
    pre-fused version materialized in HBM on every decode step. The
    RESIDENT multi-layer cache stays at 4.5 bits/value.
    """
    from repro.core import kvcache
    from repro.kernels.fused_attention import select_kv_block

    # the loader recurrence needs k_chunk | capacity; fit it (the default
    # 1024 would assert on capacities > 1024 not divisible by 1024)
    ck = select_kv_block(kvcache.seq_capacity(k_cache), 1024)
    out = flash_mha_vec_packed(
        q[:, None], k_cache, v_cache, n_kv_heads, d_head,
        causal=False, kv_valid_len=length,
        chunking=AttnChunking(q_chunk=1, k_chunk=ck),
    )
    return out[:, 0]


def flash_mha_vec_packed(
    q: jax.Array,                    # (B, Sq, H, D)
    k_cache: dict,                   # packed leaves, seq capacity Sk
    v_cache: dict,
    n_kv_heads: int,
    d_head: int,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,   # (B,) valid KV prefix length
    chunking: AttnChunking = AttnChunking(),
) -> jax.Array:
    """Vectorized-q flash attention straight off a packed KV cache.

    The vec_q recurrence (:func:`_flash_fwd_vec`, all q chunks advancing
    together through the KV scan) with the K/V chunk DEQUANTIZED PER TILE
    inside the scan body — the bf16 working set is one (B, ck, Hkv, Dh)
    chunk, never the whole cache. Accepts either packed layout (artifact
    or kernel-tile; ``repro.core.kvcache.slice_tokens``). This is the
    multi-token-per-step shape (chunked prefill continuation, speculative
    verify) of :func:`decode_attention_packed`. Forward-only: caches are
    never differentiated.
    """
    from repro.core import kvcache

    B, Sq, H, D = q.shape
    assert D == d_head, (q.shape, d_head)
    Sk = kvcache.seq_capacity(k_cache)
    nk = _chunks(Sk, chunking.k_chunk)
    ck = Sk // nk

    def loader(ki):
        kblk = kvcache.dequantize_kv(
            kvcache.slice_tokens(k_cache, ki * ck, ck), n_kv_heads, D)
        vblk = kvcache.dequantize_kv(
            kvcache.slice_tokens(v_cache, ki * ck, ck), n_kv_heads, D)
        return kblk, vblk

    out, _ = _flash_fwd_vec(q, None, None, causal, q_offset, chunking,
                            kv_loader=loader, kv_shape=(Sk, n_kv_heads),
                            kv_valid_len=kv_valid_len)
    return out


# ---------------------------------------------------------------------------
# Vectorized-q flash attention ("vec_q"): the q-chunk axis is a DATA axis
# ---------------------------------------------------------------------------
#
# The scan-over-q-chunks formulation above cannot be parallelized across
# devices (scan is sequential), so when an arch's head count does not divide
# the TP axis (qwen1.5-4b H=20, llava H=56, whisper H=6 on 16-way TP) the
# whole attention replicates — a 16x FLOP/byte waste measured in the
# baseline roofline. Here all q chunks advance together through the online-
# softmax KV scan, so the nq axis can carry a sharding constraint over the
# TP axis: sequence-parallel attention without ring communication (KV is
# small after GQA; it stays replicated on the TP axis).
#
# Trade-off vs scan_q: no causal early-exit (every (q,k) tile is computed,
# ~2x for causal) — but it unlocks 16x parallelism where heads can't shard.


def _flash_fwd_vec(q, k, v, causal, q_offset, chunking, constrain_nq=None,
                   *, kv_loader=None, kv_shape=None, kv_valid_len=None):
    """Returns (out (B,Sq,H,D), lse (B,nq,Hkv,rep,cq)).

    ``kv_loader(ki) -> (kblk, vblk)`` abstracts where a KV chunk comes
    from: None reads dense (B, Sk, Hkv, D) arrays ``k``/``v``; a loader
    (with ``kv_shape = (Sk, Hkv)``) may dequantize a packed cache per tile
    (:func:`flash_mha_vec_packed`). One recurrence, both storages.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = kv_shape if kv_loader is not None else (k.shape[1], k.shape[2])
    rep = H // Hkv
    scale = 1.0 / (D ** 0.5)
    nq = _chunks(Sq, chunking.q_chunk)
    nk = _chunks(Sk, chunking.k_chunk)
    cq, ck = Sq // nq, Sk // nk

    qc = q.reshape(B, nq, cq, Hkv, rep, D)
    if constrain_nq is not None:
        qc = constrain_nq(qc)
    if kv_loader is None:
        kc = k.reshape(B, nk, ck, Hkv, D)
        vc = v.reshape(B, nk, ck, Hkv, D)
        kv_loader = lambda ki: (kc[:, ki], vc[:, ki])
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, cq)
    k_pos = jnp.arange(Sk).reshape(nk, ck)

    def kv_body(carry, ki):
        m, l, acc = carry
        kblk, vblk = kv_loader(ki)
        s = jnp.einsum("bnqgrd,bkgd->bngrqk", qc, kblk,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_pos[:, :, None] >= k_pos[ki][None, None, :]  # (nq,cq,ck)
            s = jnp.where(mask[None, :, None, None], s, NEG_INF)
        if kv_valid_len is not None:
            valid = k_pos[ki][None, :] < kv_valid_len[:, None]    # (B, ck)
            s = jnp.where(valid[:, None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bngrqk,bkgd->bngrqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, nq, Hkv, rep, cq), NEG_INF, jnp.float32),
        jnp.zeros((B, nq, Hkv, rep, cq), jnp.float32),
        jnp.zeros((B, nq, Hkv, rep, cq, D), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(kv_body, init, jnp.arange(nk))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]                        # (B,nq,Hkv,rep,cq,D)
    out = jnp.moveaxis(out, 4, 2).reshape(B, Sq, H, D).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_mha_vec(q, k, v, causal: bool, q_offset: int, chunking: AttnChunking):
    out, _ = _flash_fwd_vec(q, k, v, causal, q_offset, chunking,
                            _VEC_CONSTRAIN[0])
    return out


# module-level hook so the sharding constraint reaches inside custom_vjp
# without being a differentiable argument (set by attn_full per call)
_VEC_CONSTRAIN = [None]


def _flash_vec_fwd(q, k, v, causal, q_offset, chunking):
    out, lse = _flash_fwd_vec(q, k, v, causal, q_offset, chunking,
                              _VEC_CONSTRAIN[0])
    return out, (q, k, v, out, lse)


def _flash_vec_bwd(causal, q_offset, chunking, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / (D ** 0.5)
    nq = _chunks(Sq, chunking.q_chunk)
    nk = _chunks(Sk, chunking.k_chunk)
    cq, ck = Sq // nq, Sk // nk
    constrain = _VEC_CONSTRAIN[0]

    qc = q.reshape(B, nq, cq, Hkv, rep, D)
    doc = dout.reshape(B, nq, cq, Hkv, rep, D)
    if constrain is not None:
        qc = constrain(qc)
        doc = constrain(doc)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)
    delta = jnp.einsum(
        "bsgrd,bsgrd->bgrs",
        dout.reshape(B, Sq, Hkv, rep, D), out.reshape(B, Sq, Hkv, rep, D),
        preferred_element_type=jnp.float32,
    ).reshape(B, Hkv, rep, nq, cq).transpose(0, 3, 1, 2, 4)  # (B,nq,Hkv,rep,cq)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, cq)
    k_pos = jnp.arange(Sk).reshape(nk, ck)
    dt16 = q.dtype

    def tile(ki):
        """All q chunks vs kv chunk ki: p, ds (B,nq,Hkv,rep,cq,ck) f32."""
        s = jnp.einsum("bnqgrd,bkgd->bngrqk", qc, kc[:, ki],
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_pos[:, :, None] >= k_pos[ki][None, None, :]
            s = jnp.where(mask[None, :, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])
        dp = jnp.einsum("bnqgrd,bkgd->bngrqk", doc, vc[:, ki],
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        return p, ds

    def body(dq_acc, ki):
        p, ds = tile(ki)
        dq_acc = dq_acc + jnp.einsum(
            "bngrqk,bkgd->bnqgrd", ds.astype(dt16), kc[:, ki],
            preferred_element_type=jnp.float32) * scale
        dk_blk = jnp.einsum("bngrqk,bnqgrd->bkgd", ds.astype(dt16), qc,
                            preferred_element_type=jnp.float32) * scale
        dv_blk = jnp.einsum("bngrqk,bnqgrd->bkgd", p.astype(dt16), doc,
                            preferred_element_type=jnp.float32)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, nq, cq, Hkv, rep, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(nk))
    dq = dq.reshape(B, Sq, H, D).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hkv, D).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hkv, D).astype(v.dtype)
    return dq, dk, dv


flash_mha_vec.defvjp(_flash_vec_fwd, _flash_vec_bwd)
