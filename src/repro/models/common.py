"""Shared model building blocks: norms, RoPE, initializers, activations."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import tap as site_tap
from repro.core.policy import QuantPlan, uniform_site_config
from repro.core.qlinear import NO_QUANT, QuantConfig
from repro.sharding.rules import NO_SHARD, ShardCtx


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Everything a model forward needs besides params and inputs.

    Quantization placement is PER SITE: every linear call site asks
    :meth:`site_quant` for its config. With a resolved :class:`QuantPlan`
    attached (``plan``), the answer comes from the policy; without one,
    from the uniform shim over the global ``quant`` — which reproduces
    the legacy behavior (body quantized, embed/lm_head/router excluded)
    through the same rule machinery instead of hardcoded NO_QUANT calls.
    ``scope`` is the param-tree prefix the current block runs under
    ("blocks", "shared", "enc_blocks") — set via :meth:`scoped` by the
    family forwards, so shared block code resolves the right sites.
    """

    quant: QuantConfig = NO_QUANT
    plan: Optional[QuantPlan] = None
    scope: str = ""
    shard: ShardCtx = dataclasses.field(default_factory=lambda: NO_SHARD)
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    # "scan_q": sequential q-chunk loop with causal early-exit (default).
    # "vec_q" : q-chunk axis is a shardable data axis — use when the head
    #           count does not divide the TP axis (see attention.py §vec_q).
    attn_impl: str = "scan_q"
    # Decode KV-tile override for the packed attention paths (None = the
    # kernel's own select_kv_block). Bitwise parity between a paged run
    # (tiles = pages) and a contiguous reference depends on the PARTITION
    # of tokens into tiles, so solo references set this to the page size.
    attn_kv_block: Optional[int] = None

    def __post_init__(self):
        # A plan-carrying ctx left at the default quant derives it from the
        # plan's attention-site config: KV-format resolution and packed-KV
        # attention dispatch read ctx.quant, and silently running them off
        # NO_QUANT while the sites follow the plan would drop the policy's
        # kv/impl (ModelCtx(plan=plan) is the natural spelling).
        if self.plan is not None and self.quant == NO_QUANT:
            object.__setattr__(self, "quant", self.plan.base)

    def scoped(self, prefix: str) -> "ModelCtx":
        return dataclasses.replace(self, scope=prefix)

    def site_quant(self, site: str) -> QuantConfig:
        """The QuantConfig the linear layer at ``site`` executes under
        (``site`` is relative to :attr:`scope`, e.g. "attn.wq")."""
        path = f"{self.scope}.{site}" if self.scope else site
        # calibration probe: mark the activation tap with the site path the
        # next engine contraction executes under (no-op without a tap —
        # see repro.core.tap)
        site_tap.mark_site(path)
        if self.plan is not None:
            return self.plan.at(path)
        return uniform_site_config(self.quant, path)


DEFAULT_CTX = ModelCtx()


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, std=0.02, dtype=jnp.bfloat16):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def zeros(shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jax.Array, weight: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: (..., seq) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., seq, d/2)
    cos = jnp.cos(angles)[..., None, :]                           # (..., seq, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation_fn(name: str):
    if name == "swiglu":  # handled in mlp (two matmuls); gate act is silu
        return jax.nn.silu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Quantized dense helper
# ---------------------------------------------------------------------------


def dense(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    quant: QuantConfig = NO_QUANT,
    shard: Optional[ShardCtx] = None,
    accum_dtype=None,
) -> jax.Array:
    """y = x @ w (+ b), executed by the engine ``quant.impl`` selects.

    ``w`` is (d_in, ...) dense, or a :class:`PackedW` (HiF4 bit-packed
    serving weight, dequantized in-graph — 4.5 bits/value of residency and
    FSDP-gather wire) — call sites accept either transparently. ``quant``
    is the PER-SITE config (callers pass ``ctx.site_quant("attn.wq")``
    etc.); the §IV exclusions (embed/lm_head/router) are default policy
    rules, not hardcoded NO_QUANT arguments (repro.core.policy). ``shard``
    (usually ctx.shard) reaches packed dequantization so the gather moves
    the 4.5-bit payload.
    """
    ectx = engine.EngineCtx(quant=quant, shard=shard if shard is not None
                            else NO_SHARD)
    y = engine.matmul(x, w, ectx, contract_x=-1, contract_w=0,
                      accum_dtype=accum_dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean CE over tokens; logits (..., V) f32-upcast, labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
