"""Top-level language models for all assigned architecture families.

One functional API over five families:

  dense / vlm    — GQA transformer (qk-norm, QKV-bias, swiglu/squared-relu)
  moe            — GQA transformer with top-k MoE FFN (EP-sharded)
  ssm            — Mamba2 (SSD) stack, attention-free
  hybrid         — Mamba2 backbone + ONE shared attention+FFN block invoked
                   every ``hybrid_attn_every`` layers (Zamba2 scheme)
  audio          — encoder-decoder (Whisper backbone; stub conv frontend)

Entry points (all pure functions of pytrees — pjit-able directly):

  abstract_params(cfg)                 -> PSpec tree (no allocation)
  init_params(cfg, key)                -> materialized params
  train_loss(params, batch, cfg, ctx)  -> scalar CE loss
  prefill(params, batch, cfg, ctx)     -> (last-token logits, decode cache)
  decode_step(params, token, cache, cfg, ctx) -> (logits, new cache)
  abstract_cache(cfg, batch, seq)      -> PSpec tree for the decode cache

Layers are stacked and iterated with lax.scan (O(1) compile scaling to 96
layers); the residual stream is sequence-sharded over the TP axis at layer
boundaries (Megatron-style SP) so remat-saved activations fit HBM at 340B.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mamba2, moe as moe_mod, transformer as tf
from repro.models.common import ModelCtx, cross_entropy, dense
from repro.models.params import PSpec, stack_specs, init_from_specs


# ---------------------------------------------------------------------------
# Positional (sinusoidal, for the audio enc-dec family)
# ---------------------------------------------------------------------------


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """(...,) int positions -> (..., d) f32 sinusoidal embeddings."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Per-family block specs
# ---------------------------------------------------------------------------


def _tblock_specs(cfg: ArchConfig) -> dict:
    """Transformer block: norm+attn+norm+ffn (ffn = mlp or moe)."""
    specs = {
        "norm1": tf.norm_specs(cfg),
        "attn": tf.attn_specs(cfg),
        "norm2": tf.norm_specs(cfg),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_mod.moe_specs(cfg)
    else:
        specs["mlp"] = tf.mlp_specs(cfg)
    return specs


def _dec_block_specs(cfg: ArchConfig) -> dict:
    """Decoder block for enc-dec: self-attn + cross-attn + mlp."""
    return {
        "norm1": tf.norm_specs(cfg),
        "attn": tf.attn_specs(cfg),
        "norm_x": tf.norm_specs(cfg),
        "xattn": tf.attn_specs(cfg),
        "norm2": tf.norm_specs(cfg),
        "mlp": tf.mlp_specs(cfg),
    }


def _enc_block_specs(cfg: ArchConfig) -> dict:
    return {
        "norm1": tf.norm_specs(cfg),
        "attn": tf.attn_specs(cfg),
        "norm2": tf.norm_specs(cfg),
        "mlp": tf.mlp_specs(cfg),
    }


def _hybrid_layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_super_blocks, mamba_layers_per_super)."""
    per = cfg.hybrid_attn_every
    assert cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per


def abstract_params(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    specs: dict = {
        "embed": PSpec((v, d), ("vocab", "fsdp"), std=0.02),
        "final_norm": tf.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = PSpec((d, v), ("fsdp", "vocab"), std=0.02)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        specs["blocks"] = stack_specs(_tblock_specs(cfg), cfg.n_layers)
    elif fam == "ssm":
        specs["blocks"] = stack_specs(mamba2.mamba_specs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        ns, per = _hybrid_layout(cfg)
        specs["blocks"] = stack_specs(
            stack_specs(mamba2.mamba_specs(cfg), per), ns
        )
        specs["shared"] = {
            "norm1": tf.norm_specs(cfg),
            "attn": tf.attn_specs(cfg),
            "norm2": tf.norm_specs(cfg),
            "mlp": tf.mlp_specs(cfg),
        }
    elif fam == "audio":
        specs["enc_blocks"] = stack_specs(_enc_block_specs(cfg), cfg.enc_layers)
        specs["enc_norm"] = tf.norm_specs(cfg)
        specs["blocks"] = stack_specs(_dec_block_specs(cfg), cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return specs


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return init_from_specs(abstract_params(cfg), key)


# ---------------------------------------------------------------------------
# Decode-cache specs
# ---------------------------------------------------------------------------

ENC_FRAMES_DECODE = 1536  # nominal encoder length backing a decode step (audio)


def abstract_cache(cfg: ArchConfig, batch: int, seq: int,
                   kv_format: str = "bf16") -> dict:
    """Cache pytree spec for a decode step with capacity ``seq``.

    kv_format="hif4" packs the attention KV caches at 4.5 bits/value
    (repro.core.kvcache) for the transformer families and the audio
    decoder (both the growing "self" cache and the read-only encoder
    "cross" cache); SSM state and the hybrid caches stay bf16
    (documented fallback, docs/EXECUTION.md).
    """
    fam = cfg.family
    pos = PSpec((), (), dtype=jnp.int32, init="zeros")
    if fam in ("dense", "vlm", "moe"):
        return {
            "kv": stack_specs(
                tf.attn_cache_specs(cfg, batch, seq, kv_format), cfg.n_layers
            ),
            "pos": pos,
        }
    if fam == "ssm":
        return {
            "layers": stack_specs(mamba2.mamba_cache_specs(cfg, batch), cfg.n_layers),
            "pos": pos,
        }
    if fam == "hybrid":
        ns, per = _hybrid_layout(cfg)
        return {
            "layers": stack_specs(
                stack_specs(mamba2.mamba_cache_specs(cfg, batch), per), ns
            ),
            "kv": stack_specs(tf.attn_cache_specs(cfg, batch, seq), ns),
            "pos": pos,
        }
    if fam == "audio":
        return {
            "self": stack_specs(
                tf.attn_cache_specs(cfg, batch, seq, kv_format), cfg.n_layers
            ),
            "cross": stack_specs(
                tf.attn_cache_specs(cfg, batch, ENC_FRAMES_DECODE, kv_format),
                cfg.n_layers,
            ),
            "pos": pos,
        }
    raise ValueError(fam)


def init_cache(cfg: ArchConfig, batch: int, seq: int,
               kv_format: str = "bf16") -> dict:
    """Zero-initialized decode cache (for real serving, not the dry-run)."""
    return init_from_specs(abstract_cache(cfg, batch, seq, kv_format),
                           jax.random.PRNGKey(0))


def init_paged_cache(cfg: ArchConfig, batch: int, n_pages: int,
                     page_tokens: int, max_pages_per_slot: int) -> dict:
    """Zero-initialized PAGED decode cache for the page-pool scheduler.

    ``kv`` holds the fixed-size HiF4 page pool shared by all slots
    (repro.core.kvcache.init_page_pool — leaves (L, n_pages, F, P));
    ``pages`` (B, max_pages_per_slot) int32 is the per-slot page table
    (all-zero rows point at the reserved scratch page) and ``pos`` (B,)
    the per-slot token counts. Transformer families only — the pool IS
    the self-attention KV cache.
    """
    from repro.core import kvcache

    assert cfg.family in ("dense", "vlm", "moe"), cfg.family
    a = cfg.attn
    return {
        "kv": kvcache.init_page_pool(cfg.n_layers, a.n_kv_heads, a.d_head,
                                     n_pages, page_tokens),
        "pages": jnp.zeros((batch, max_pages_per_slot), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig, ctx: ModelCtx):
    # the lookup is a gather, not a matmul: policy resolution clamps the
    # "embed" site to fmt='none' (and §IV keeps it high-precision anyway)
    x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(ctx.compute_dtype)


def lm_logits(params: dict, x: jax.Array, cfg: ArchConfig, ctx: ModelCtx):
    x = tf.norm_apply(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        w = params["embed"].T                            # (d, V)
    else:
        w = params["lm_head"]
    # per-site config ("lm_head"): fmt='none' under the default §IV rules,
    # quantizable by an explicit policy rule; f32 accumulation either way
    # (loss-critical logits)
    y = dense(x, w, quant=ctx.site_quant("lm_head"), accum_dtype=jnp.float32)
    axes = ("batch", "act_seq", "vocab") if y.ndim == 3 else ("batch", "vocab")
    return ctx.shard.constrain(y.astype(jnp.float32), *axes)


# ---------------------------------------------------------------------------
# Transformer-family forward (dense / vlm / moe)
# ---------------------------------------------------------------------------


def _tblock_apply(p, x, cfg, ctx, *, mode, cache=None, pos=None,
                  causal=True, use_rope=True, pages=None):
    h = tf.norm_apply(p["norm1"], x, cfg)
    if mode == "decode":
        a, new_cache = tf.attn_decode(p["attn"], h, cache, pos, cfg, ctx,
                                      use_rope=use_rope, pages=pages)
    else:
        a, new_cache = tf.attn_full(
            p["attn"], h, cfg, ctx, causal=causal, use_rope=use_rope,
            return_cache=(mode == "prefill"),
        )
    x = x + a
    h2 = tf.norm_apply(p["norm2"], x, cfg)
    if "moe" in p:
        f = moe_mod.moe_apply(p["moe"], h2, cfg, ctx)
    else:
        f = tf.mlp_apply(p["mlp"], h2, cfg, ctx)
    return x + f, new_cache


def _scan_layers(body, x0, xs, remat: bool):
    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x0, xs)


def _transformer_forward(params, x, cfg, ctx, *, mode, caches=None, pos=None,
                         pages=None):
    """x (B,S,d). Returns (x, caches-or-None). mode: train|prefill|decode."""
    sp = ("batch", "act_seq", None) if x.shape[1] > 1 else ("batch", None, None)
    bctx = ctx.scoped("blocks")

    if mode == "train":
        def body(h, p_layer):
            h = ctx.shard.constrain(h, *sp)
            h, _ = _tblock_apply(p_layer, h, cfg, bctx, mode="train")
            return h, None
        x, _ = _scan_layers(body, x, params["blocks"], ctx.remat)
        return ctx.shard.constrain(x, *sp), None

    if mode == "prefill":
        def body(h, p_layer):
            h = ctx.shard.constrain(h, *sp)
            h, cache = _tblock_apply(p_layer, h, cfg, bctx, mode="prefill")
            return h, cache
        x, caches = _scan_layers(body, x, params["blocks"], False)
        return ctx.shard.constrain(x, *sp), caches

    # decode (``pages`` is loop-invariant: the page table is closure-
    # captured while the per-layer pool leaves ride the scan xs)
    def body(h, layer):
        p_layer, cache = layer
        h, new_cache = _tblock_apply(p_layer, h, cfg, bctx, mode="decode",
                                     cache=cache, pos=pos, pages=pages)
        return h, new_cache
    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# SSM-family forward (mamba2)
# ---------------------------------------------------------------------------


def _ssm_forward(params, x, cfg, ctx, *, mode, caches=None):
    sp = ("batch", "act_seq", None) if x.shape[1] > 1 else ("batch", None, None)
    bctx = ctx.scoped("blocks")
    if mode in ("train", "prefill"):
        want_cache = mode == "prefill"

        def body(h, p_layer):
            h = ctx.shard.constrain(h, *sp)
            out, cache = mamba2.mamba_full(p_layer, h, cfg, bctx,
                                           return_cache=want_cache)
            return h + out, cache
        remat = ctx.remat and mode == "train"
        x, caches = _scan_layers(body, x, params["blocks"], remat)
        return ctx.shard.constrain(x, *sp), (caches if want_cache else None)

    def body(h, layer):
        p_layer, cache = layer
        out, new_cache = mamba2.mamba_step(p_layer, h, cache, cfg, bctx)
        return h + out, new_cache
    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Hybrid-family forward (zamba2: shared attention block + mamba groups)
# ---------------------------------------------------------------------------


def _hybrid_forward(params, x, cfg, ctx, *, mode, caches=None, pos=None):
    shared = params["shared"]
    sp = ("batch", "act_seq", None) if x.shape[1] > 1 else ("batch", None, None)
    sctx = ctx.scoped("shared")
    bctx = ctx.scoped("blocks")

    def shared_apply(h, kv_cache):
        hn = tf.norm_apply(shared["norm1"], h, cfg)
        if mode == "decode":
            a, new_kv = tf.attn_decode(shared["attn"], hn, kv_cache, pos, cfg,
                                       sctx)
        else:
            a, new_kv = tf.attn_full(shared["attn"], hn, cfg, sctx, causal=True,
                                     return_cache=(mode == "prefill"))
        h = h + a
        h2 = tf.norm_apply(shared["norm2"], h, cfg)
        return h + tf.mlp_apply(shared["mlp"], h2, cfg, sctx), new_kv

    if mode in ("train", "prefill"):
        want_cache = mode == "prefill"

        def super_body(h, p_super):
            h = ctx.shard.constrain(h, *sp)
            h, kv = shared_apply(h, None)

            def inner(hh, p_layer):
                out, mc = mamba2.mamba_full(p_layer, hh, cfg, bctx,
                                            return_cache=want_cache)
                return hh + out, mc
            h, mcaches = jax.lax.scan(inner, h, p_super)
            return h, (mcaches, kv)
        remat = ctx.remat and mode == "train"
        x, ys = _scan_layers(super_body, x, params["blocks"], remat)
        x = ctx.shard.constrain(x, *sp)
        if want_cache:
            mcaches, kvs = ys
            return x, {"layers": mcaches, "kv": kvs}
        return x, None

    def super_body(h, xs):
        p_super, mcache, kv_cache = xs
        h, new_kv = shared_apply(h, kv_cache)

        def inner(hh, layer):
            p_layer, mc = layer
            out, new_mc = mamba2.mamba_step(p_layer, hh, mc, cfg, bctx)
            return hh + out, new_mc
        h, new_mc = jax.lax.scan(inner, h, (p_super, mcache))
        return h, (new_mc, new_kv)

    x, (new_layers, new_kvs) = jax.lax.scan(
        super_body, x, (params["blocks"], caches["layers"], caches["kv"])
    )
    return x, {"layers": new_layers, "kv": new_kvs}


# ---------------------------------------------------------------------------
# Audio enc-dec forward (whisper)
# ---------------------------------------------------------------------------


def _encode(params, frames, cfg, ctx):
    """frames (B, S_enc, d): precomputed frame embeddings (stub frontend)."""
    B, S, d = frames.shape
    x = frames.astype(ctx.compute_dtype) + sinusoid(jnp.arange(S), d).astype(
        ctx.compute_dtype
    )
    sp = ("batch", "act_seq", None)
    ectx = ctx.scoped("enc_blocks")

    def body(h, p_layer):
        h = ctx.shard.constrain(h, *sp)
        hn = tf.norm_apply(p_layer["norm1"], h, cfg)
        a, _ = tf.attn_full(p_layer["attn"], hn, cfg, ectx, causal=False,
                            use_rope=False)
        h = h + a
        h2 = tf.norm_apply(p_layer["norm2"], h, cfg)
        return h + tf.mlp_apply(p_layer["mlp"], h2, cfg, ectx), None

    x, _ = _scan_layers(body, x, params["enc_blocks"], ctx.remat)
    return tf.norm_apply(params["enc_norm"], x, cfg)


def _cross_kv(params, enc, cfg, ctx):
    """Project encoder output into per-decoder-layer cross KV caches."""
    a = cfg.attn
    B, S, d = enc.shape

    bctx = ctx.scoped("blocks")

    def body(_, p_layer):
        pa = p_layer["xattn"]
        k = dense(enc, pa["wk"].reshape(d, -1),
                  quant=bctx.site_quant("xattn.wk"), shard=ctx.shard).reshape(
            B, S, a.n_kv_heads, a.d_head
        )
        v = dense(enc, pa["wv"].reshape(d, -1),
                  quant=bctx.site_quant("xattn.wv"), shard=ctx.shard).reshape(
            B, S, a.n_kv_heads, a.d_head
        )
        if a.qkv_bias:
            k = k + pa["bk"].astype(k.dtype)
            v = v + pa["bv"].astype(v.dtype)
        return None, {"k": k, "v": v}

    _, kv = jax.lax.scan(body, None, params["blocks"])
    return kv


def _dec_block_apply(p, x, cfg, ctx, *, mode, self_cache, cross_kv, pos):
    h = tf.norm_apply(p["norm1"], x, cfg)
    if mode == "decode":
        a, new_self = tf.attn_decode(p["attn"], h, self_cache, pos, cfg, ctx,
                                     use_rope=False)
    else:
        a, new_self = tf.attn_full(p["attn"], h, cfg, ctx, causal=True,
                                   use_rope=False,
                                   return_cache=(mode == "prefill"))
    x = x + a

    hx = tf.norm_apply(p["norm_x"], x, cfg)
    if mode == "decode":
        a, _ = tf.attn_decode(p["xattn"], hx, cross_kv, pos, cfg, ctx,
                              use_rope=False, cross=True, site="xattn")
    else:
        # full-sequence cross attention against the encoder output KV
        B, S, d = hx.shape
        aa = cfg.attn
        q = dense(hx, p["xattn"]["wq"].reshape(d, -1),
                  quant=ctx.site_quant("xattn.wq"), shard=ctx.shard).reshape(
            B, S, aa.n_heads, aa.d_head
        )
        if aa.qkv_bias:
            q = q + p["xattn"]["bq"].astype(q.dtype)
        from repro.models.attention import flash_attention, AttnChunking
        o = flash_attention(
            q, cross_kv["k"], cross_kv["v"], causal=False,
            chunking=AttnChunking(q_chunk=min(ctx.attn_q_chunk, S),
                                  k_chunk=min(ctx.attn_k_chunk, cross_kv["k"].shape[1])),
        )
        a = dense(o.reshape(B, S, -1), p["xattn"]["wo"].reshape(-1, d),
                  quant=ctx.site_quant("xattn.wo"), shard=ctx.shard)
    x = x + a

    h2 = tf.norm_apply(p["norm2"], x, cfg)
    return x + tf.mlp_apply(p["mlp"], h2, cfg, ctx), new_self


def _audio_forward(params, dec_x, cfg, ctx, *, mode, frames=None, caches=None,
                   pos=None):
    """dec_x (B, S_dec, d) embedded decoder input."""
    sp = ("batch", "act_seq", None) if dec_x.shape[1] > 1 else ("batch", None, None)
    bctx = ctx.scoped("blocks")
    if mode in ("train", "prefill"):
        enc = _encode(params, frames, cfg, ctx)
        cross = _cross_kv(params, enc, cfg, ctx)        # (L, B, S_enc, Hkv, Dh)

        def body(h, layer):
            p_layer, ckv = layer
            h = ctx.shard.constrain(h, *sp)
            h, self_cache = _dec_block_apply(p_layer, h, cfg, bctx, mode=mode,
                                             self_cache=None, cross_kv=ckv,
                                             pos=None)
            return h, self_cache
        remat = ctx.remat and mode == "train"
        x, self_caches = _scan_layers(body, dec_x, (params["blocks"], cross), remat)
        x = ctx.shard.constrain(x, *sp)
        if mode == "prefill":
            return x, {"self": self_caches, "cross": cross}
        return x, None

    def body(h, layer):
        p_layer, self_cache, ckv = layer
        h, new_self = _dec_block_apply(p_layer, h, cfg, bctx, mode="decode",
                                       self_cache=self_cache, cross_kv=ckv,
                                       pos=pos)
        return h, new_self
    x, new_self = jax.lax.scan(
        body, dec_x, (params["blocks"], caches["self"], caches["cross"])
    )
    return x, {"self": new_self, "cross": caches["cross"]}


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _backbone(params, x, cfg, ctx, *, mode, caches=None, pos=None,
              frames=None, pages=None):
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return _transformer_forward(params, x, cfg, ctx, mode=mode,
                                    caches=caches, pos=pos, pages=pages)
    assert pages is None, f"paged KV pool is transformer-only, got {fam!r}"
    if fam == "ssm":
        return _ssm_forward(params, x, cfg, ctx, mode=mode,
                            caches=caches)
    if fam == "hybrid":
        return _hybrid_forward(params, x, cfg, ctx, mode=mode, caches=caches,
                               pos=pos)
    if fam == "audio":
        return _audio_forward(params, x, cfg, ctx, mode=mode, frames=frames,
                              caches=caches, pos=pos)
    raise ValueError(fam)


def train_loss(params: dict, batch: dict, cfg: ArchConfig, ctx: ModelCtx):
    """Next-token CE loss. batch: {"tokens"} | {"embeds","labels"} |
    {"frames","tokens"} (audio)."""
    if cfg.family == "audio":
        x = embed_tokens(params, batch["tokens"], cfg, ctx)
        x = x + sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        h, _ = _backbone(params, x, cfg, ctx, mode="train",
                         frames=batch["frames"])
        logits = lm_logits(params, h, cfg, ctx)
        return cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
    if cfg.embeds_input:
        x = batch["embeds"].astype(ctx.compute_dtype)
        labels = batch["labels"]
        h, _ = _backbone(params, x, cfg, ctx, mode="train")
        logits = lm_logits(params, h, cfg, ctx)
        return cross_entropy(logits[:, :-1], labels[:, 1:])
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg, ctx)
    h, _ = _backbone(params, x, cfg, ctx, mode="train")
    logits = lm_logits(params, h, cfg, ctx)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


def prefill(params: dict, batch: dict, cfg: ArchConfig, ctx: ModelCtx):
    """Process the prompt; return (last-token logits (B,V), decode cache)."""
    if cfg.family == "audio":
        # encode the frames; decoder consumes BOS (token 0)
        B = batch["frames"].shape[0]
        bos = jnp.zeros((B, 1), jnp.int32)
        x = embed_tokens(params, bos, cfg, ctx)
        x = x + sinusoid(jnp.arange(1), cfg.d_model).astype(x.dtype)
        h, caches = _backbone(params, x, cfg, ctx, mode="prefill",
                              frames=batch["frames"])
        seq_pos = jnp.asarray(1, jnp.int32)
    elif cfg.embeds_input:
        x = batch["embeds"].astype(ctx.compute_dtype)
        h, caches = _backbone(params, x, cfg, ctx, mode="prefill")
        seq_pos = jnp.asarray(x.shape[1], jnp.int32)
    else:
        x = embed_tokens(params, batch["tokens"], cfg, ctx)
        h, caches = _backbone(params, x, cfg, ctx, mode="prefill")
        seq_pos = jnp.asarray(x.shape[1], jnp.int32)
    logits = lm_logits(params, h[:, -1:], cfg, ctx)[:, 0]       # (B, V)

    if cfg.family in ("dense", "vlm", "moe"):
        cache = {"kv": caches, "pos": seq_pos}
    elif cfg.family == "ssm":
        cache = {"layers": caches, "pos": seq_pos}
    elif cfg.family == "hybrid":
        cache = {"layers": caches["layers"], "kv": caches["kv"], "pos": seq_pos}
    else:  # audio
        cache = {"self": caches["self"], "cross": caches["cross"], "pos": seq_pos}
    return logits, cache


def pad_cache(cache: dict, cfg: ArchConfig, capacity: int) -> dict:
    """Grow prefill KV caches along the token axis to ``capacity`` slots.

    Dense leaves (L, B, S, Hkv, Dh) pad axis 2; HiF4-packed tensors pad
    their own layout's token axis (``repro.core.kvcache.pad_tokens`` —
    the kernel-tile layout keeps tokens LAST). Zero padding is inert
    under the length mask either way.
    """
    from repro.core import kvcache

    def pad_dense(x):
        s = x.shape[2]  # (L, B, S, Hkv, Dh)
        if s >= capacity:
            return x
        pads = [(0, 0)] * x.ndim
        pads[2] = (0, capacity - s)
        return jnp.pad(x, pads)

    def grow(kv):
        return {
            name: (kvcache.pad_tokens(t, capacity) if kvcache.is_packed_kv(t)
                   else pad_dense(t))
            for name, t in kv.items()
        }

    out = dict(cache)
    for key in ("kv", "self"):
        if key in out:
            out[key] = grow(out[key])
    return out


def quantize_kv_cache(cache: dict, cfg: ArchConfig) -> dict:
    """Convert a prefill KV cache to the HiF4-packed layout (one-time).

    KV leaves (L, B, S, Hkv, Dh) become packed {codes, meta, tail} leaves
    (4.5 bits/value + bf16 partial-group tail) in the KERNEL-TILE layout
    (token axis last) the fused decode-attention kernel streams — the
    analogue of ``PackedW.to_kernel_layout`` in
    ``prepare_params_for_serving``, applied once at cache build. Grouping
    is per token and the re-layout is a pure bit move, so this bulk
    conversion is bit-identical to appending the same tokens one at a
    time — the invariant continuous-batching parity rests on. The
    transformer families convert their self-attention cache ("kv"); the
    audio family converts both the decoder "self" cache and the
    read-only encoder "cross" cache (the cross cache never grows, so it
    is packed once here and only ever dequantized on read). Call before
    :func:`pad_cache` (zero padding after packing stays inert).
    """
    from repro.core import kvcache

    assert cfg.family in ("dense", "vlm", "moe", "audio"), cfg.family

    def pack(kv):
        return {
            "k": kvcache.to_kernel_layout(kvcache.quantize_kv(kv["k"])),
            "v": kvcache.to_kernel_layout(kvcache.quantize_kv(kv["v"])),
        }

    out = dict(cache)
    if cfg.family == "audio":
        out["self"] = pack(cache["self"])
        out["cross"] = pack(cache["cross"])
    else:
        out["kv"] = pack(cache["kv"])
    return out


def decode_step(params: dict, token: jax.Array, cache: dict, cfg: ArchConfig,
                ctx: ModelCtx):
    """token (B,) int32 -> (logits (B, V), updated cache)."""
    pos = cache["pos"]
    x = embed_tokens(params, token[:, None], cfg, ctx)          # (B, 1, d)
    if cfg.family == "audio":
        x = x + sinusoid(pos + jnp.arange(1), cfg.d_model).astype(x.dtype)
        h, new = _backbone(params, x, cfg, ctx, mode="decode", caches=cache,
                           pos=pos)
        new_cache = {"self": new["self"], "cross": new["cross"], "pos": pos + 1}
    elif cfg.family == "ssm":
        h, new = _backbone(params, x, cfg, ctx, mode="decode",
                           caches=cache["layers"])
        new_cache = {"layers": new, "pos": pos + 1}
    elif cfg.family == "hybrid":
        h, new = _backbone(params, x, cfg, ctx, mode="decode", caches=cache,
                           pos=pos)
        new_cache = {"layers": new["layers"], "kv": new["kv"], "pos": pos + 1}
    else:
        pages = cache.get("pages")
        h, new = _backbone(params, x, cfg, ctx, mode="decode",
                           caches=cache["kv"], pos=pos, pages=pages)
        new_cache = {"kv": new, "pos": pos + 1}
        if pages is not None:
            new_cache["pages"] = pages
    logits = lm_logits(params, h[:, -1:], cfg, ctx)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Packed-weight serving overlay (HiF4 4.5-bit deployment artifact)
# ---------------------------------------------------------------------------

from repro.core.policy import STACKED_COLLECTIONS, QuantPlan, QuantPolicy
from repro.core.qlinear import QuantConfig


def quant_plan(cfg: ArchConfig, policy) -> QuantPlan:
    """Resolve a policy (or a legacy global QuantConfig, via the uniform
    shim) against this architecture's param specs — the explicit
    site -> QuantConfig plan everything serving-side packs and QDQs from."""
    if isinstance(policy, QuantPlan):
        return policy
    if isinstance(policy, QuantConfig):
        policy = QuantPolicy.uniform(policy)
    return policy.resolve(abstract_params(cfg), family=cfg.family)


def _default_packed_plan(cfg: ArchConfig) -> QuantPlan:
    """The historical packing set: uniform hif4/packed over the default
    packable sites (used when callers pack without an explicit policy)."""
    return quant_plan(cfg, QuantConfig(fmt="hif4", impl="packed"))


def _marker_geometry(site, axes: tuple):
    """(k, n, L, out_name, c_name) of a packed STACKED site spec."""
    import numpy as np

    ca = site.contract_axes
    nd = len(site.shape)
    out_axes = tuple(a for a in range(1, nd) if a not in ca)
    k = int(np.prod([site.shape[a] for a in ca]))
    n = int(np.prod([site.shape[a] for a in out_axes])) if out_axes else 1
    out_name = next((axes[a] for a in out_axes if axes[a] is not None), None)
    c_name = next((axes[a] for a in ca if axes[a] is not None), None)
    return k, n, site.shape[0], out_name, c_name


def packed_overlay(specs: dict, plan: QuantPlan) -> dict:
    """Replace the block-weight PSpecs the PLAN marks packed with packed
    codes/meta PSpecs — the overlay packs exactly the policy's site set,
    nothing else.

    Returned leaves for a packed weight: a dict
        {"__packed__": True, "codes": PSpec, "meta": PSpec,
         "shape2d": (K, N), "dtype": ...}
    which launch/runtime code converts into :class:`PackedW` nodes (with
    ShapeDtypeStructs for the dry-run, real buffers for serving).
    """

    def walk(node, parts):
        if isinstance(node, PSpec):
            site = plan.get(".".join(parts))
            if site is None or not site.packed:
                return node
            k, n, L, out_name, c_name = _marker_geometry(site, node.axes)
            return {
                "__packed__": True,
                "codes": PSpec((L, n, k // 64, 32),
                               ("layers", out_name, c_name, None),
                               dtype=jnp.uint8, init="zeros"),
                "meta": PSpec((L, n, k // 64),
                              ("layers", out_name, c_name),
                              dtype=jnp.uint32, init="zeros"),
                "shape2d": (k, n),
                "dtype": jnp.bfloat16,
                "axes2d": (out_name, c_name),
            }
        if isinstance(node, dict):
            return {kk: walk(vv, parts + (kk,)) for kk, vv in node.items()}
        return node

    out = dict(specs)
    for blk in STACKED_COLLECTIONS:
        if blk in out:
            out[blk] = walk(out[blk], (blk,))
    return out


def is_packed_marker(node) -> bool:
    return isinstance(node, dict) and node.get("__packed__") is True


def realize_packed(tree, leaf_fn):
    """Convert packed markers into PackedW nodes; other PSpecs via leaf_fn.

    ``leaf_fn(pspec)`` -> array-like (ShapeDtypeStruct or real buffer).
    """
    from repro.core.qlinear import PackedW

    def walk(node):
        if is_packed_marker(node):
            return PackedW(leaf_fn(node["codes"]), leaf_fn(node["meta"]),
                           tuple(node["shape2d"]), node["dtype"],
                           tuple(node["axes2d"]))
        if isinstance(node, PSpec):
            return leaf_fn(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(tree)


def pack_params_for_serving(params: dict, cfg: ArchConfig,
                            plan: Optional[QuantPlan] = None) -> dict:
    """Offline conversion of real trained weights into PackedW nodes.

    Packs EXACTLY the sites ``plan`` marks packed (default: the uniform
    hif4/packed plan — the historical behavior). A policy rule flipping
    one site to bf16/qdq leaves that site's weight dense here, and the
    engine serves it through the matching non-packed path.
    """
    from repro.core.qlinear import PackedW

    if plan is None:
        plan = _default_packed_plan(cfg)
    specs = abstract_params(cfg)

    def walk(p_node, s_node, parts):
        if isinstance(s_node, PSpec):
            site = plan.get(".".join(parts))
            if site is None or not site.packed:
                return p_node
            ca = site.contract_axes
            _, _, _, out_name, c_name = _marker_geometry(site, s_node.axes)
            # per-layer pack, stacked along L
            stacked = [
                PackedW.from_dense(p_node[i], tuple(a - 1 for a in ca))
                for i in range(p_node.shape[0])
            ]
            codes = jnp.stack([s.codes for s in stacked])
            meta = jnp.stack([s.meta for s in stacked])
            return PackedW(codes, meta, stacked[0].shape2d,
                           p_node.dtype, (out_name, c_name))
        if isinstance(s_node, dict):
            return {kk: walk(p_node[kk], vv, parts + (kk,))
                    for kk, vv in s_node.items()}
        return p_node

    out = dict(params)
    for blk in STACKED_COLLECTIONS:
        if blk in out:
            out[blk] = walk(params[blk], specs[blk], (blk,))
    return out
