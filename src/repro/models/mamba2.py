"""Mamba2 block: SSD (state-space duality) chunked scan + recurrent decode.

Follows arXiv:2405.21060. The selective SSM recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t B_t x_t,   y_t = C_t . h_t + D x_t
is evaluated in chunks: an intra-chunk quadratic ("attention-like") term and
an inter-chunk state recurrence (lax.scan over chunks). Heads are sharded
over the TP axis; the FLOP-dominant in/out projections are quantized (HiF4
applies to matmul-layer tensors per the paper's placement); the SSD scan
itself stays high-precision — noted in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ModelCtx, dense, rms_norm
from repro.models.params import PSpec


def dims(cfg: ArchConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return di, H, s.n_groups, s.d_state, s.head_dim, s.conv_kernel


def mamba_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, H, G, N, P, K = dims(cfg)
    return {
        "pre_norm": PSpec((d,), (None,), init="ones"),
        "w_z": PSpec((d, di), ("fsdp", "ssm_inner")),
        "w_x": PSpec((d, di), ("fsdp", "ssm_inner")),
        "w_b": PSpec((d, G * N), ("fsdp", None)),
        "w_c": PSpec((d, G * N), ("fsdp", None)),
        "w_dt": PSpec((d, H), ("fsdp", "heads")),
        "conv_w_x": PSpec((K, di), (None, "ssm_inner"), std=0.2),
        "conv_b_x": PSpec((di,), ("ssm_inner",), init="zeros"),
        "conv_w_bc": PSpec((K, 2 * G * N), (None, None), std=0.2),
        "conv_b_bc": PSpec((2 * G * N,), (None,), init="zeros"),
        "a_log": PSpec((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "dt_bias": PSpec((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "d_skip": PSpec((H,), ("heads",), dtype=jnp.float32, init="ones"),
        "gate_norm": PSpec((di,), ("ssm_inner",), init="ones"),
        "w_out": PSpec((di, d), ("ssm_inner", "fsdp")),
    }


def mamba_cache_specs(cfg: ArchConfig, batch: int) -> dict:
    di, H, G, N, P, K = dims(cfg)
    return {
        "conv_x": PSpec((batch, K - 1, di), ("batch", None, "ssm_inner"),
                        dtype=jnp.bfloat16, init="zeros"),
        "conv_bc": PSpec((batch, K - 1, 2 * G * N), ("batch", None, None),
                         dtype=jnp.bfloat16, init="zeros"),
        "ssd": PSpec((batch, H, P, N), ("batch", "heads", None, None),
                     dtype=jnp.float32, init="zeros"),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv1d
# ---------------------------------------------------------------------------


def conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (B,S,C), w (K,C): causal depthwise conv, returns (B,S,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(xp[:, k : k + S] * w[k].astype(x.dtype) for k in range(K))
    return jax.nn.silu((y + b.astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)


def conv_step(x1: jax.Array, state: jax.Array, w: jax.Array, b: jax.Array):
    """x1 (B,C) one step, state (B,K-1,C) past inputs -> (y1, new_state)."""
    window = jnp.concatenate([state, x1[:, None]], axis=1)          # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = jax.nn.silu(y + b.astype(jnp.float32))
    return y.astype(x1.dtype), window[:, 1:].astype(state.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_scan(
    xh: jax.Array,        # (B, S, H, P) bf16
    dt: jax.Array,        # (B, S, H) f32 (softplus'd, > 0)
    a: jax.Array,         # (H,) f32, negative
    bv: jax.Array,        # (B, S, N) f32  (n_groups=1 path; B matrix)
    cv: jax.Array,        # (B, S, N) f32
    d_skip: jax.Array,    # (H,) f32
    chunk: int,
    init_state=None,      # (B, H, P, N) f32 or None
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = xh.shape
    N = bv.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, f"seq {S} not divisible by ssd chunk {chunk}"
    nc = S // chunk

    x_ = xh.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    dt_ = dt.reshape(B, nc, chunk, H)
    b_ = bv.reshape(B, nc, chunk, N)
    c_ = cv.reshape(B, nc, chunk, N)

    dA = dt_ * a                                          # (B,nc,l,H), <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                        # inclusive cumsum

    # ---- intra-chunk (quadratic in chunk length) ----
    # L[t, j] = exp(sum_{j < t' <= t} dA_{t'}) for t >= j
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]   # (B,nc,t,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.exp(jnp.where(tri[None, None, :, :, None], jnp.minimum(diff, 0.0), -jnp.inf))
    scores = jnp.einsum("bctn,bcjn->bctj", c_, b_)             # (B,nc,t,j)
    m = scores[..., None] * L                                   # (B,nc,t,j,H)
    y_intra = jnp.einsum("bctjh,bcjh,bcjhp->bcthp", m, dt_, x_)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)         # (B,nc,l,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", b_, decay_to_end * dt_, x_)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                   # (B,nc,H)
    s0 = (
        jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(s_prev, inp):
        st, dec = inp                                           # (B,H,P,N), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    states_t = jnp.moveaxis(states, 1, 0)                       # (nc,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                   # (nc,B,H)
    final_state, s_prevs = jax.lax.scan(body, s0, (states_t, decay_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                       # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    decay_in = jnp.exp(dA_cs)                                   # (B,nc,l,H)
    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", c_, s_prevs, decay_in)

    y = y_intra + y_inter + d_skip[None, None, None, :, None] * x_
    return y.reshape(B, S, H, P).astype(xh.dtype), final_state


def ssd_step(
    x1: jax.Array,       # (B, H, P)
    dt1: jax.Array,      # (B, H) f32
    a: jax.Array,        # (H,) f32
    b1: jax.Array,       # (B, N) f32
    c1: jax.Array,       # (B, N) f32
    d_skip: jax.Array,   # (H,) f32
    state: jax.Array,    # (B, H, P, N) f32
):
    """One recurrent SSD step (decode)."""
    xf = x1.astype(jnp.float32)
    da = jnp.exp(dt1 * a)                                       # (B,H)
    state = state * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, xf, b1
    )
    y = jnp.einsum("bhpn,bn->bhp", state, c1) + d_skip[None, :, None] * xf
    return y.astype(x1.dtype), state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def _in_proj(p, h, cfg: ArchConfig, ctx: ModelCtx):
    """Shared by full/step: project residual h -> z, x, B, C, dt."""
    di, H, G, N, P, K = dims(cfg)
    z = dense(h, p["w_z"], quant=ctx.site_quant("w_z"), shard=ctx.shard)
    xin = dense(h, p["w_x"], quant=ctx.site_quant("w_x"), shard=ctx.shard)
    bc = jnp.concatenate(
        [dense(h, p["w_b"], quant=ctx.site_quant("w_b"), shard=ctx.shard),
         dense(h, p["w_c"], quant=ctx.site_quant("w_c"), shard=ctx.shard)],
        axis=-1,
    )
    dt = dense(h, p["w_dt"], quant=ctx.site_quant("w_dt"), shard=ctx.shard).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return z, xin, bc, dt


def mamba_full(
    p: dict,
    x: jax.Array,                  # (B, S, d) residual stream
    cfg: ArchConfig,
    ctx: ModelCtx,
    *,
    return_cache: bool = False,
):
    """Full-sequence Mamba2 block (train / prefill)."""
    di, H, G, N, P, K = dims(cfg)
    B, S, _ = x.shape
    h = rms_norm(x, p["pre_norm"], eps=cfg.norm_eps)
    z, xin, bc, dt = _in_proj(p, h, cfg, ctx)

    xc = conv_full(xin, p["conv_w_x"], p["conv_b_x"])
    bcc = conv_full(bc, p["conv_w_bc"], p["conv_b_bc"])
    bv = bcc[..., :N].astype(jnp.float32)
    cv = bcc[..., N:].astype(jnp.float32)

    xh = xc.reshape(B, S, H, P)
    xh = ctx.shard.constrain(xh, "batch", None, "heads", None)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, final_state = ssd_scan(xh, dt, a, bv, cv, p["d_skip"], cfg.ssm.chunk)

    y = y.reshape(B, S, di)
    y = rms_norm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["gate_norm"], eps=cfg.norm_eps)
    out = dense(y, p["w_out"], quant=ctx.site_quant("w_out"), shard=ctx.shard)
    if return_cache:
        cache = {
            "conv_x": _tail(xin, K - 1),
            "conv_bc": _tail(bc, K - 1),
            "ssd": final_state,
        }
        return out, cache
    return out, None


def _tail(x: jax.Array, n: int) -> jax.Array:
    """Last n steps of (B, S, C), left-padded with zeros if S < n."""
    B, S, C = x.shape
    if S >= n:
        return x[:, S - n :]
    return jnp.pad(x, ((0, 0), (n - S, 0), (0, 0)))


def mamba_step(
    p: dict,
    x: jax.Array,                  # (B, 1, d)
    cache: dict,
    cfg: ArchConfig,
    ctx: ModelCtx,
):
    """One-token recurrent Mamba2 step (decode)."""
    di, H, G, N, P, K = dims(cfg)
    B = x.shape[0]
    h = rms_norm(x[:, 0], p["pre_norm"], eps=cfg.norm_eps)      # (B, d)
    z, xin, bc, dt = _in_proj(p, h, cfg, ctx)                   # (B, ·)

    xc, conv_x = conv_step(xin, cache["conv_x"], p["conv_w_x"], p["conv_b_x"])
    bcc, conv_bc = conv_step(bc, cache["conv_bc"], p["conv_w_bc"], p["conv_b_bc"])
    b1 = bcc[..., :N].astype(jnp.float32)
    c1 = bcc[..., N:].astype(jnp.float32)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, ssd_state = ssd_step(
        xc.reshape(B, H, P), dt, a, b1, c1, p["d_skip"], cache["ssd"]
    )
    y = y.reshape(B, di)
    y = rms_norm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["gate_norm"], eps=cfg.norm_eps)
    out = dense(y, p["w_out"], quant=ctx.site_quant("w_out"), shard=ctx.shard)[:, None]        # (B, 1, d)
    new_cache = {"conv_x": conv_x, "conv_bc": conv_bc, "ssd": ssd_state}
    return out, new_cache
