"""Mixture-of-Experts FFN: top-k token-choice routing, capacity dispatch, EP.

GShard-style dispatch: tokens are grouped per batch element, each group
dispatches into an (experts, capacity) buffer via one-hot einsums — the
TPU-native formulation (no scatter). Expert weights are sharded over the
"model" mesh axis (expert parallelism); the dispatched activations carry an
"experts" sharding constraint so XLA inserts the all-to-all.

The router (gating network) is excluded from quantization by the default
policy rules (paper §IV-C; see repro.core.policy). Expert matmuls are
quantized along the contraction dim like every other linear layer, each
under its own resolved site config ("moe.wg", "moe.wo", ...).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import engine
from repro.models.common import ModelCtx, dense
from repro.models.params import PSpec


def moe_specs(cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, fe, E = cfg.d_model, m.d_expert, m.n_experts
    specs = {
        # router in f32: small, excluded from quantization, numerically touchy
        "router": PSpec((d, E), ("fsdp", None), dtype=jnp.float32),
    }
    if cfg.activation == "swiglu":
        specs["wg"] = PSpec((E, d, fe), ("experts", "fsdp", None))
        specs["wu"] = PSpec((E, d, fe), ("experts", "fsdp", None))
    else:
        specs["wi"] = PSpec((E, d, fe), ("experts", "fsdp", None))
    specs["wo"] = PSpec((E, fe, d), ("experts", None, "fsdp"))
    return specs


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4, floor 4


def _dispatch_combine(idx: jax.Array, gates: jax.Array, E: int, C: int):
    """Build the (B, S, E, C) combine tensor (gate-weighted one-hots).

    idx (B, S, k) int32 — chosen experts; gates (B, S, k) f32. Tokens beyond
    an expert's capacity C within their group are dropped (standard GShard).
    Returns combine f32 and the boolean dispatch mask.
    """
    B, S, k = idx.shape
    prev = jnp.zeros((B, E), jnp.int32)
    combine = jnp.zeros((B, S, E, C), jnp.float32)
    for slot in range(k):
        mask = jax.nn.one_hot(idx[:, :, slot], E, dtype=jnp.int32)     # (B,S,E)
        pos = jnp.cumsum(mask, axis=1) - mask + prev[:, None, :]       # (B,S,E)
        prev = prev + jnp.sum(mask, axis=1)
        keep = (pos < C) & (mask > 0)                                  # (B,S,E)
        pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, C - 1), C, dtype=jnp.float32)
        combine = combine + (
            pos_oh * keep[..., None] * gates[:, :, slot, None, None]
        )
    dispatch = combine > 0.0
    return combine, dispatch


def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig, ctx: ModelCtx) -> jax.Array:
    """x (B, S, d) -> (B, S, d). Each batch element is one dispatch group."""
    m = cfg.moe
    B, S, d = x.shape
    E, C = m.n_experts, capacity(cfg, S)

    # --- routing (f32; excluded from quantization by the default policy
    # rules — paper §IV-C — but a per-site rule CAN now opt it in) ---
    logits = dense(x, p["router"],
                   quant=ctx.site_quant("moe.router")).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)                # (B,S,k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    combine, dispatch = _dispatch_combine(idx, gates, E, C)

    # --- dispatch: token-major -> expert-major (all-to-all under EP) ---
    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    xe = ctx.shard.constrain(xe, "batch", "experts", None, None)

    # --- expert FFN (quantized like any linear layer, each projection
    # under its own policy site; engine qdq path — batched-expert weights
    # have no packed/pallas dispatch, see docs/EXECUTION.md) ---

    def qbmm(a, w, site, a_axis=-1, w_axis=1):
        """Batched-expert einsum with A-W quantization on the contraction."""
        ectx = engine.EngineCtx(quant=ctx.site_quant(site), shard=ctx.shard)
        return engine.qdq_einsum("becd,edf->becf", a, w, ectx,
                                 a_axis=a_axis, w_axis=w_axis)

    if cfg.activation == "swiglu":
        h = jax.nn.silu(qbmm(xe, p["wg"], "moe.wg").astype(jnp.float32))
        h = (h * qbmm(xe, p["wu"], "moe.wu").astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(qbmm(xe, p["wi"], "moe.wi").astype(jnp.float32)).astype(x.dtype)
    h = ctx.shard.constrain(h, "batch", "experts", None, None)
    ye = qbmm(h, p["wo"], "moe.wo")                            # (B,E,C,d)
    ye = ctx.shard.constrain(ye, "batch", "experts", None, None)

    # --- combine: expert-major -> token-major ---
    y = jnp.einsum("bsec,becd->bsd", combine.astype(ye.dtype), ye)
    return y.astype(x.dtype)


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, n_experts: int):
    """Switch-Transformer load-balancing auxiliary loss (for training)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(idx[..., 0].reshape(-1), n_experts, dtype=jnp.float32), axis=0
    )
    return n_experts * jnp.sum(me * ce)
