"""Abstract parameter specs: shapes + logical sharding axes, no allocation.

Every model family first builds a pytree of :class:`PSpec` leaves. From that
single source of truth we derive:

* ``init_from_specs``      — materialized random params (smoke tests, examples)
* ``shape_structs``        — ``jax.ShapeDtypeStruct`` tree (dry-run: no memory)
* ``shardings_from_specs`` — NamedShardings resolved via :class:`ShardCtx`
* ``pspecs_from_specs``    — raw PartitionSpecs (for in_shardings of pjit)

Keeping specs abstract is what lets the 340B configs lower on a CPU-only
container: the dry-run never allocates a single parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import ShardCtx


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter: shape, logical axis names, dtype, initializer."""

    shape: tuple
    axes: tuple                      # logical names (or None), len == ndim
    dtype: Any = jnp.bfloat16
    init: str = "normal"             # normal | zeros | ones
    std: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_pspec)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layer axis of size ``n`` to every leaf."""
    return tree_map_specs(
        lambda p: PSpec(
            shape=(n,) + p.shape,
            axes=(axis_name,) + p.axes,
            dtype=p.dtype,
            init=p.init,
            std=p.std,
        ),
        tree,
    )


def init_from_specs(tree, key: jax.Array):
    """Materialize parameters (deterministic per-leaf key via fold_in)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_pspec)

    def one(i: int, p: PSpec):
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        k = jax.random.fold_in(key, i)
        x = jax.random.truncated_normal(k, -2.0, 2.0, p.shape, jnp.float32) * p.std
        return x.astype(p.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(i, p) for i, p in enumerate(leaves)]
    )


def shape_structs(tree, sharding_tree=None):
    """ShapeDtypeStruct tree for .lower() — optionally carrying shardings."""
    if sharding_tree is None:
        return tree_map_specs(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)
    return jax.tree_util.tree_map(
        lambda p, s: jax.ShapeDtypeStruct(p.shape, p.dtype, sharding=s),
        tree,
        sharding_tree,
        is_leaf=is_pspec,
    )


def pspecs_from_specs(tree, shard: ShardCtx):
    return tree_map_specs(lambda p: shard.pspec(p.axes, p.shape), tree)


def shardings_from_specs(tree, shard: ShardCtx):
    return tree_map_specs(lambda p: shard.sharding(p.axes, p.shape), tree)


def n_elements(tree) -> int:
    import numpy as np

    total = 0
    for p in jax.tree_util.tree_leaves(tree, is_leaf=is_pspec):
        total += int(np.prod(p.shape))
    return total
