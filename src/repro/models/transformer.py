"""Transformer blocks: GQA attention (qk-norm, QKV-bias) + dense MLPs.

All linear layers run through :func:`repro.models.common.dense` with a
PER-SITE quantization config (``ctx.site_quant("attn.wq")`` etc., resolved
by the :mod:`repro.core.policy` rules) applied along the contraction
dimension — the paper's A-W PTQ placement (§IV) is the default rule set.
Norms, softmax, RoPE stay high-precision.

Three attention execution modes:
  * full    — flash attention over the whole sequence (train / encoder)
  * prefill — full + returns the RoPE'd KV as a cache
  * decode  — one token vs. a KV cache (append at ``pos``)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import engine as qengine
from repro.core import kvcache
from repro.models.attention import (
    AttnChunking,
    decode_attention,
    flash_attention,
)
from repro.models.common import ModelCtx, apply_rope, dense, layer_norm, rms_norm
from repro.models.params import PSpec


# ---------------------------------------------------------------------------
# Norm wrapper (family-dependent: audio uses LN+bias, LMs use RMSNorm)
# ---------------------------------------------------------------------------


def norm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    if cfg.family == "audio":
        return {
            "w": PSpec((d,), (None,), init="ones"),
            "b": PSpec((d,), (None,), init="zeros"),
        }
    return {"w": PSpec((d,), (None,), init="ones")}


def norm_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if "b" in p:
        return layer_norm(x, p["w"], p["b"], eps=cfg.norm_eps)
    return rms_norm(x, p["w"], eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_specs(cfg: ArchConfig) -> dict:
    a = cfg.attn
    d = cfg.d_model
    specs = {
        "wq": PSpec((d, a.n_heads, a.d_head), ("fsdp", "heads", None)),
        "wk": PSpec((d, a.n_kv_heads, a.d_head), ("fsdp", "kv_heads", None)),
        "wv": PSpec((d, a.n_kv_heads, a.d_head), ("fsdp", "kv_heads", None)),
        "wo": PSpec((a.n_heads, a.d_head, d), ("heads", None, "fsdp")),
    }
    if a.qkv_bias:
        specs["bq"] = PSpec((a.n_heads, a.d_head), ("heads", None), init="zeros")
        specs["bk"] = PSpec((a.n_kv_heads, a.d_head), ("kv_heads", None), init="zeros")
        specs["bv"] = PSpec((a.n_kv_heads, a.d_head), ("kv_heads", None), init="zeros")
    if a.qk_norm:
        specs["q_norm"] = PSpec((a.d_head,), (None,), init="ones")
        specs["k_norm"] = PSpec((a.d_head,), (None,), init="ones")
    return specs


def _proj_qkv(p: dict, x: jax.Array, cfg: ArchConfig, ctx: ModelCtx,
              site: str = "attn"):
    """x (..., d) -> q (..., H, Dh), k/v (..., Hkv, Dh), RoPE NOT yet applied.

    ``site`` names the param subtree relative to ctx.scope ("attn" or the
    audio decoder's "xattn") so each projection resolves its own policy
    site (e.g. "blocks.xattn.wq").
    """
    a = cfg.attn
    d = cfg.d_model
    lead = x.shape[:-1]
    q = dense(x, p["wq"].reshape(d, -1), quant=ctx.site_quant(f"{site}.wq"),
              shard=ctx.shard).reshape(
        lead + (a.n_heads, a.d_head)
    )
    k = dense(x, p["wk"].reshape(d, -1), quant=ctx.site_quant(f"{site}.wk"),
              shard=ctx.shard).reshape(
        lead + (a.n_kv_heads, a.d_head)
    )
    v = dense(x, p["wv"].reshape(d, -1), quant=ctx.site_quant(f"{site}.wv"),
              shard=ctx.shard).reshape(
        lead + (a.n_kv_heads, a.d_head)
    )
    if a.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if a.qk_norm:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    return q, k, v


def _out_proj(p: dict, o: jax.Array, cfg: ArchConfig, ctx: ModelCtx,
              site: str = "attn") -> jax.Array:
    a = cfg.attn
    lead = o.shape[:-2]
    o = o.reshape(lead + (a.n_heads * a.d_head,))
    return dense(o, p["wo"].reshape(-1, cfg.d_model),
                 quant=ctx.site_quant(f"{site}.wo"), shard=ctx.shard)


def attn_full(
    p: dict,
    x: jax.Array,                 # (B, S, d)
    cfg: ArchConfig,
    ctx: ModelCtx,
    *,
    causal: bool = True,
    use_rope: bool = True,
    return_cache: bool = False,
    site: str = "attn",
):
    """Full-sequence attention; optionally returns the KV cache (prefill)."""
    B, S, _ = x.shape
    q, k, v = _proj_qkv(p, x, cfg, ctx, site=site)
    if use_rope:
        positions = jnp.arange(S)
        q = apply_rope(q, positions, cfg.attn.rope_theta)
        k = apply_rope(k, positions, cfg.attn.rope_theta)
    chunking = AttnChunking(
        q_chunk=min(ctx.attn_q_chunk, S), k_chunk=min(ctx.attn_k_chunk, S)
    )
    if ctx.attn_impl == "vec_q":
        from repro.models import attention as attn_mod

        attn_mod._VEC_CONSTRAIN[0] = lambda qc: ctx.shard.constrain(
            qc, "batch", "attn_q_chunks", None, None, None, None
        )
        o = attn_mod.flash_mha_vec(q, k, v, causal, 0, chunking)
    else:
        # NOTE (§Perf, refuted hypothesis): repeating KV to full heads when
        # kv_heads don't divide the TP axis was tried to remove the per-tile
        # all-to-alls XLA emits for the (g, rep) head split — it REGRESSED
        # (343s vs 314s collective on 340B train: the repeated-KV gathers
        # outweigh the all-to-alls they replace). Kept as measured evidence.
        q = ctx.shard.constrain(q, "batch", None, "heads", None)
        k = ctx.shard.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.shard.constrain(v, "batch", None, "kv_heads", None)
        o = flash_attention(q, k, v, causal=causal, chunking=chunking)
    y = _out_proj(p, o, cfg, ctx, site=site)
    if return_cache:
        return y, {"k": k, "v": v}
    return y, None


def _append_kv_per_slot(cache: jax.Array, new: jax.Array, pos: jax.Array):
    """Write new (B, 1, Hkv, Dh) into cache (B, S, Hkv, Dh) at pos (B,).

    Per-batch-element write offsets are what continuous batching needs: a
    freshly admitted request sits at its prompt length while its slot
    neighbours are deep into decode.
    """
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p, 0, 0)
        )
    )(cache, new, pos)


def attn_decode(
    p: dict,
    x: jax.Array,                 # (B, 1, d) — the new token's hidden state
    cache: dict,                  # {"k","v"}: (B, S, Hkv, Dh); roped already
    pos: jax.Array,               # int32 valid-cache-slot count: scalar
    #                               (whole batch in lockstep) or (B,)
    #                               per-slot (continuous batching)
    cfg: ArchConfig,
    ctx: ModelCtx,
    *,
    use_rope: bool = True,
    cross: bool = False,          # cross-attention: read-only cache, no append
    site: str = "attn",
    pages=None,                   # (B, max_pages) page table: paged pool cache
):
    """One-token attention against (and, unless cross, appending to) a cache."""
    B = x.shape[0]
    per_slot = jnp.ndim(pos) == 1
    q, k_new, v_new = _proj_qkv(p, x, cfg, ctx, site=site)  # (B, 1, H/Hkv, Dh)
    if use_rope:
        positions = pos[:, None] if per_slot else pos + jnp.arange(1)
        q = apply_rope(q, positions, cfg.attn.rope_theta)
        if not cross:
            k_new = apply_rope(k_new, positions, cfg.attn.rope_theta)
    if cross:
        # read-only encoder cache: every position is valid, so length is
        # the cache capacity whether dense (S axis) or HiF4-packed
        new_cache = cache
        cap = (kvcache.seq_capacity(cache["k"])
               if kvcache.is_packed_kv(cache["k"]) else cache["k"].shape[1])
        length = jnp.full((B,), cap, jnp.int32)
    elif pages is not None:
        # paged HiF4 pool (repro.core.kvcache.init_page_pool): per-layer
        # leaves (n_pages, F, P); the one token's bytes land through the
        # page table at (pages[b, pos//P], pos % P). The scheduler owns
        # allocation/COW, so live slots always write an exclusive page.
        assert kvcache.is_packed_kv(cache["k"]), "page pool is HiF4-only"
        assert per_slot, "paged decode uses per-slot positions"
        new_cache = {
            "k": kvcache.append_token_paged(cache["k"], k_new, pos, pages),
            "v": kvcache.append_token_paged(cache["v"], v_new, pos, pages),
        }
        length = pos + 1
    elif kvcache.is_packed_kv(cache["k"]):
        # HiF4-packed cache (repro.core.kvcache): quantize the one new
        # token into its own 64-groups + tail and write only those bytes;
        # attention dequantizes on read. Handles scalar and per-slot pos.
        new_cache = {
            "k": kvcache.append_token(cache["k"], k_new, pos),
            "v": kvcache.append_token(cache["v"], v_new, pos),
        }
        length = pos + 1 if per_slot else jnp.full((B,), pos + 1, jnp.int32)
    elif per_slot:
        k = _append_kv_per_slot(cache["k"], k_new, pos)
        v = _append_kv_per_slot(cache["v"], v_new, pos)
        new_cache = {"k": k, "v": v}
        length = pos + 1
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                         (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                         (0, pos, 0, 0))
        new_cache = {"k": k, "v": v}
        length = jnp.full((B,), pos + 1, jnp.int32)
    if kvcache.is_packed_kv(new_cache["k"]):
        # engine-dispatched packed decode: the fused Pallas kernel on TPU
        # (impl packed/pallas, kernel-tile cache), its bit-exact XLA twin
        # everywhere else — either way the bf16 working set is one KV tile
        # (docs/EXECUTION.md). The bf16 branch below is untouched.
        ectx = qengine.EngineCtx(quant=ctx.quant, shard=ctx.shard)
        o = qengine.attention_decode(q[:, 0], new_cache["k"], new_cache["v"],
                                     length, cfg.attn.n_kv_heads,
                                     cfg.attn.d_head, ectx, pages=pages,
                                     block_kv=ctx.attn_kv_block)
    else:
        o = decode_attention(q[:, 0], new_cache["k"], new_cache["v"], length)
    y = _out_proj(p, o[:, None], cfg, ctx, site=site)  # (B, 1, d)
    return y, new_cache


def attn_cache_specs(cfg: ArchConfig, batch: int, seq: int,
                     kv_format: str = "bf16") -> dict:
    """Abstract per-layer KV-cache spec. seq is sharded over the TP axis
    ("kv_seq" context parallelism) — kv_heads rarely divide the model axis
    (8 kv heads vs 16-way TP) whereas 32k..512k sequences always do.

    kv_format="hif4" yields the packed KERNEL-TILE layout of
    repro.core.kvcache (codes/meta at 4.5 bits/value + a bf16
    partial-group tail, feature-major with the token axis last — the
    layout the fused decode-attention kernel tiles directly,
    docs/FORMATS.md); the seq axis keeps the same "kv_seq" sharding —
    groups never cross tokens, so context parallelism slices packed
    leaves exactly like dense ones.
    """
    a = cfg.attn
    if kv_format == "hif4":
        g, t = kvcache.split_features(a.n_kv_heads, a.d_head)
        packed = {
            "codes": PSpec((batch, g * 32, seq), ("batch", None, "kv_seq"),
                           dtype=jnp.uint8, init="zeros"),
            "meta": PSpec((batch, g, seq), ("batch", None, "kv_seq"),
                          dtype=jnp.uint32, init="zeros"),
            "tail": PSpec((batch, t, seq), ("batch", None, "kv_seq"),
                          init="zeros"),
        }
        return {"k": dict(packed), "v": dict(packed)}
    return {
        "k": PSpec((batch, seq, a.n_kv_heads, a.d_head),
                   ("batch", "kv_seq", None, None)),
        "v": PSpec((batch, seq, a.n_kv_heads, a.d_head),
                   ("batch", "kv_seq", None, None)),
    }


# ---------------------------------------------------------------------------
# Dense MLP (swiglu | squared_relu | gelu)
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "wg": PSpec((d, f), ("fsdp", "ff")),
            "wu": PSpec((d, f), ("fsdp", "ff")),
            "wo": PSpec((f, d), ("ff", "fsdp")),
        }
    return {
        "wi": PSpec((d, f), ("fsdp", "ff")),
        "wo": PSpec((f, d), ("ff", "fsdp")),
    }


def mlp_apply(p: dict, x: jax.Array, cfg: ArchConfig, ctx: ModelCtx) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(dense(x, p["wg"], quant=ctx.site_quant("mlp.wg"),
                              shard=ctx.shard).astype(jnp.float32))
        h = (h * dense(x, p["wu"], quant=ctx.site_quant("mlp.wu"),
                       shard=ctx.shard).astype(jnp.float32)).astype(x.dtype)
    else:
        h = dense(x, p["wi"], quant=ctx.site_quant("mlp.wi"),
                  shard=ctx.shard).astype(jnp.float32)
        h = jnp.square(jax.nn.relu(h)) if cfg.activation == "squared_relu" else jax.nn.gelu(h)
        h = h.astype(x.dtype)
    h = ctx.shard.constrain(h, "batch", None, "ff")
    return dense(h, p["wo"], quant=ctx.site_quant("mlp.wo"), shard=ctx.shard)
