"""AdamW from scratch: decoupled weight decay, global-norm clip, warmup+cosine.

Optimizer moments are f32 and carry the SAME logical sharding axes as their
parameters — with the "fsdp" rule active this is ZeRO-1: each DP rank holds
1/|data| of every moment tensor (XLA keeps the update local to the shard,
no gathers: the update is elementwise).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import PSpec, tree_map_specs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init_specs(param_specs) -> dict:
    """Abstract opt-state specs: f32 moments with the params' sharding."""
    def f32_like(p: PSpec) -> PSpec:
        return PSpec(p.shape, p.axes, dtype=jnp.float32, init="zeros")

    return {
        "m": tree_map_specs(f32_like, param_specs),
        "v": tree_map_specs(f32_like, param_specs),
        "step": PSpec((), (), dtype=jnp.int32, init="zeros"),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
