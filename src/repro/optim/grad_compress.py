"""HiF4 gradient compression for data-parallel all-reduce (beyond-paper).

Why HiF4 specifically: gradients have a huge dynamic range across tensors
and steps. FP8/NVFP4-style compressors need per-tensor software scaling
passes before every reduce; HiF4's 69-binade global range (Table II) lets
gradients be cast DIRECTLY, no scale sweep — the same property that saves
Mistral-7B in the paper's Table III saves the optimizer here.

Transport actually moves 4.5 bits/value: the all-reduce is decomposed as
  pack (codes uint8 + meta uint32)
  -> all_to_all         (each rank owns 1/N of the groups; wire = packed)
  -> local dequant+sum  (f32)
  -> requant+pack
  -> all_gather         (wire = packed)
i.e. the classic compressed reduce-scatter/all-gather, 16/4.5 = 3.56x less
wire than a bf16 ring all-reduce. A local error-feedback accumulator keeps
the compound update unbiased over steps (Karimireddy et al.-style EF).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import hif4

GROUP = hif4.GROUP_SIZE


def _flatten_to_groups(x: jnp.ndarray):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, GROUP), n


def qdq_flat(x: jnp.ndarray) -> jnp.ndarray:
    """HiF4 QDQ of an arbitrary tensor in flat 64-groups (for EF math)."""
    groups, n = _flatten_to_groups(x)
    deq = hif4.dequantize_groups(hif4.quantize_groups(groups))
    return deq.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def pack_flat(x: jnp.ndarray):
    """tensor -> (codes (G, 32) uint8, meta (G,) uint32, orig_len)."""
    groups, n = _flatten_to_groups(x)
    packed = hif4.pack_groups(hif4.quantize_groups(groups))
    return packed.codes, packed.meta, n


def unpack_flat(codes, meta, n, shape, dtype=jnp.float32):
    vals = hif4.dequantize_groups(hif4.unpack_groups(hif4.HiF4Packed(codes, meta)))
    return vals.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str, n_dev: int) -> jnp.ndarray:
    """All-reduce-mean of ``x`` moving HiF4-packed bytes on the wire.

    Must run inside shard_map/pmap over ``axis_name``. Groups are sharded
    round-robin across ranks for the reduce-scatter phase.
    """
    groups, n = _flatten_to_groups(x)
    g = groups.shape[0]
    pad_g = (-g) % n_dev
    if pad_g:
        groups = jnp.pad(groups, ((0, pad_g), (0, 0)))
    packed = hif4.pack_groups(hif4.quantize_groups(groups))

    # reduce-scatter phase: rank i receives chunk i of every peer
    codes = packed.codes.reshape(n_dev, -1, 32)
    meta = packed.meta.reshape(n_dev, -1)
    codes_x = jax.lax.all_to_all(codes, axis_name, 0, 0, tiled=False)
    meta_x = jax.lax.all_to_all(meta, axis_name, 0, 0, tiled=False)
    # local dequant + sum over peers (f32)
    vals = hif4.dequantize_groups(
        hif4.unpack_groups(hif4.HiF4Packed(codes_x, meta_x))
    )                                               # (n_dev, g/n_dev, 64)
    local_sum = jnp.mean(vals, axis=0)

    # all-gather phase: share requantized partial sums
    rs = hif4.pack_groups(hif4.quantize_groups(local_sum))
    codes_g = jax.lax.all_gather(rs.codes, axis_name)   # (n_dev, g/n_dev, 32)
    meta_g = jax.lax.all_gather(rs.meta, axis_name)
    full = hif4.dequantize_groups(
        hif4.unpack_groups(hif4.HiF4Packed(codes_g, meta_g))
    ).reshape(-1, GROUP)
    if pad_g:
        full = full[:g]
    return full.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def ef_compress_step(grad: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback: returns (compressed value to reduce, new residual)."""
    target = grad.astype(jnp.float32) + err
    q = qdq_flat(target)
    return q, target - q


def make_dp_compressed_train_step(loss_fn, opt_update, mesh, axis: str = "data"):
    """shard_map DP train step with HiF4-compressed gradient all-reduce.

    Params replicated per rank; batch split over ``axis``. Suitable for
    the inter-pod DP axis (the slow links) of models that fit replicated —
    the TP/FSDP axes keep XLA's native collectives.
    """
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    n_dev = mesh.shape[axis]

    def step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        reduced, new_err = [], []
        for g, e in zip(flat_g, flat_e):
            q, res = ef_compress_step(g, e)
            r = compressed_psum(q, axis, n_dev)
            reduced.append(r.astype(g.dtype))
            new_err.append(res)
        grads = jax.tree_util.tree_unflatten(tdef, reduced)
        err_out = jax.tree_util.tree_unflatten(tdef, new_err)
        loss = jax.lax.pmean(loss, axis)
        new_params, new_opt, stats = opt_update(params, grads, opt_state)
        return new_params, new_opt, err_out, dict(stats, loss=loss)

    return shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )  # check_vma off: compressed_psum mixes manual pack/unpack with psum
