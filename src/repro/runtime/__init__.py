from repro.runtime.train_loop import TrainLoopConfig, train  # noqa: F401
from repro.runtime.serve_loop import ServeConfig, serve  # noqa: F401
from repro.runtime.guard import (  # noqa: F401
    ArtifactError,
    ArtifactIntegrityError,
    ArtifactLayoutError,
    ArtifactNotFoundError,
    GuardConfig,
    PoolExhaustedError,
    ServeError,
    SnapshotIntegrityError,
)
from repro.runtime.faults import FaultInjector, FaultSpec, parse_fault  # noqa: F401,E501
