from repro.runtime.train_loop import TrainLoopConfig, train  # noqa: F401
from repro.runtime.serve_loop import ServeConfig, serve  # noqa: F401
