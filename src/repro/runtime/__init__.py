from repro.runtime.train_loop import TrainLoopConfig, train  # noqa: F401
from repro.runtime.serve_loop import ServeConfig, serve  # noqa: F401
from repro.runtime.guard import (  # noqa: F401
    ArtifactError,
    ArtifactIntegrityError,
    ArtifactLayoutError,
    ArtifactNotFoundError,
    GuardConfig,
    JournalError,
    PoolExhaustedError,
    RecoveryError,
    ServeError,
    SnapshotIntegrityError,
)
from repro.runtime.faults import (  # noqa: F401
    FaultInjector,
    FaultSpec,
    SimulatedCrash,
    parse_fault,
)
from repro.runtime.journal import (  # noqa: F401
    RecoveryPlan,
    RequestJournal,
    journal_residency,
    read_journal,
    recover,
)
