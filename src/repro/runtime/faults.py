"""Deterministic, seedable fault injection for the serve stack.

Every guard in :mod:`repro.runtime.guard` must be shown to FIRE, not just
exist — this module is the attacker side of that proof. The schedulers in
``serve_loop`` expose four injection points (all no-ops without an
injector): page-pool corruption before a decode chunk, contiguous-cache
corruption before a chunk, preemption-snapshot corruption after the
fingerprint is stamped, and page theft at serve start. The test suite
(``tests/test_faults.py``) and the ``--inject-fault`` launcher flag drive
one :class:`FaultInjector` per serve call.

Fault classes (``FaultSpec.kind``):

* ``code_flip`` — one random bit of one packed-codes byte in a settled
  page owned by the target request. Values perturb silently (finite), so
  ONLY the per-page checksum audit can catch it.
* ``meta_flip`` — one random bit of one packed meta word in such a page.
  Caught by the checksum audit; if the flip lands in the E6M2 byte the
  scale changes (possibly to the 0xFF NaN sentinel) and the meta/NaN
  sentinels fire too.
* ``page_corruption`` — ``bits`` random bit flips across the page's
  codes plus one meta word forced to the 0xFF sentinel: exercises the
  checksum, the 0xFF counter, and the NaN logits flag at once.
* ``nan_activation`` — a NaN written into the target slot's bf16 KV
  values; propagates through attention to the logits, where the scan
  sentinel catches it.
* ``pool_starvation`` — the injector allocates (and never releases)
  pool pages at serve start so the target can never be admitted.
* ``snapshot_truncation`` — a preempted slot's host snapshot loses its
  last page column (``bits == 0``) or takes one bit flip, AFTER its
  fingerprint was stamped.

Crash-point classes (``repro.runtime.journal``): these KILL the serving
process — :class:`SimulatedCrash` propagates out of ``serve_requests``
like a SIGKILL would, leaving exactly the journal prefix a real crash at
that point leaves. Tests then resume with the same journal dir and
assert the recovered outputs are bitwise identical to an uninterrupted
run:

* ``crash_after_admit`` — dies right after the target request's
  ``admitted`` record was committed (durable admit, no decode yet).
* ``crash_mid_decode`` — dies after decode chunk ``after_chunk``'s
  record (and any due checkpoint) was committed.
* ``crash_during_checkpoint`` — dies inside the checkpoint write: the
  ``.npz`` exists on disk but its journal record never commits, so
  recovery must ignore the orphaned file.
* ``journal_truncation`` — ``crash_mid_decode`` plus ``bits`` bytes torn
  off the journal's end (a half-flushed final write); the crc framing
  must drop the torn record and recover the valid prefix.

All randomness comes from ``numpy.random.default_rng(spec.seed)`` — the
same spec injects the same fault, so containment tests can assert
bitwise-identical survivor outputs across runs.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

FAULT_CLASSES = (
    "code_flip",
    "meta_flip",
    "page_corruption",
    "nan_activation",
    "pool_starvation",
    "snapshot_truncation",
    "crash_after_admit",
    "crash_mid_decode",
    "crash_during_checkpoint",
    "journal_truncation",
)

CRASH_CLASSES = FAULT_CLASSES[-4:]


class SimulatedCrash(RuntimeError):
    """The injected process kill: deliberately NOT a ServeError — no
    scheduler guard may catch it (a real SIGKILL cannot be caught
    either). The journal's durable prefix is all recovery gets."""


@dataclasses.dataclass
class FaultSpec:
    """One injected fault. ``target_request`` is the victim's request id;
    ``after_chunk`` delays injection until that many decode chunks have
    run (so the victim is resident and has settled pages); ``bits`` sets
    the flip count for ``page_corruption`` and selects truncation
    (``0``) vs bit flip for ``snapshot_truncation``; ``hold_pages`` is
    how many pages ``pool_starvation`` steals (0 = all)."""

    kind: str
    seed: int = 0
    target_request: int = 0
    after_chunk: int = 0
    bits: int = 16
    hold_pages: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_CLASSES:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_CLASSES}")


def parse_fault(text: str) -> FaultSpec:
    """``kind[:key=value,...]`` (the ``--inject-fault`` launcher syntax),
    e.g. ``meta_flip:seed=3,target_request=1,after_chunk=2``."""
    kind, _, rest = text.partition(":")
    kwargs = {}
    if rest:
        for part in rest.split(","):
            key, _, val = part.partition("=")
            kwargs[key.strip()] = int(val)
    return FaultSpec(kind=kind.strip(), **kwargs)


def _flip_bit(arr: jnp.ndarray, idx: tuple, bit: int) -> jnp.ndarray:
    one = jnp.asarray(1 << bit, arr.dtype)
    return arr.at[idx].set(arr[idx] ^ one)


class FaultInjector:
    """Injects exactly ONE fault per serve call, at a deterministic spot.

    ``events`` logs every injection as ``(kind, detail_dict)`` so tests
    can assert the fault really landed; ``fired`` is True afterwards.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.fired = False
        self.events: list = []
        self.held_pages: list = []

    # -- serve-start hook ---------------------------------------------------

    def steal_pages(self, pool) -> None:
        """pool_starvation: hold pages so admission starves."""
        if self.spec.kind != "pool_starvation":
            return
        want = self.spec.hold_pages or pool.usable_pages
        while len(self.held_pages) < want:
            pid = pool.alloc(owner="__fault_injector__")
            if pid is None:
                break
            self.held_pages.append(pid)
        self.fired = True
        self.events.append(
            ("pool_starvation", {"held": tuple(self.held_pages)}))

    # -- paged-scheduler hook (before a decode chunk) -----------------------

    def _target_page(self, pool, slot_req, slot_pages):
        for b, rid in enumerate(slot_req):
            if rid != self.spec.target_request or not slot_pages[b]:
                continue
            owned = [p for p in slot_pages[b]
                     if pool.owner.get(p) == rid]
            return (owned or slot_pages[b])[0]
        return None

    def poison_pool(self, kv: dict, pool, slot_req, slot_pages,
                    chunk_idx: int) -> dict:
        """Corrupt one settled page of the target request on device."""
        if (self.fired or chunk_idx < self.spec.after_chunk
                or self.spec.kind not in
                ("code_flip", "meta_flip", "page_corruption")):
            return kv
        pid = self._target_page(pool, slot_req, slot_pages)
        if pid is None:
            return kv            # victim not resident yet — try next chunk
        k = dict(kv["k"])
        if self.spec.kind == "code_flip":
            rows, cols = k["codes"].shape[2], k["codes"].shape[3]
            idx = (0, pid, int(self.rng.integers(rows)), 0)
            bit = int(self.rng.integers(8))
            k["codes"] = _flip_bit(k["codes"], idx, bit)
            detail = {"page": pid, "leaf": "codes", "idx": idx, "bit": bit}
        elif self.spec.kind == "meta_flip":
            rows = k["meta"].shape[2]
            idx = (0, pid, int(self.rng.integers(rows)), 0)
            bit = int(self.rng.integers(32))
            k["meta"] = _flip_bit(k["meta"], idx, bit)
            detail = {"page": pid, "leaf": "meta", "idx": idx, "bit": bit}
        else:                    # page_corruption
            rows, cols = k["codes"].shape[2], k["codes"].shape[3]
            flips = []
            for _ in range(max(1, self.spec.bits)):
                idx = (0, pid, int(self.rng.integers(rows)),
                       int(self.rng.integers(cols)))
                bit = int(self.rng.integers(8))
                k["codes"] = _flip_bit(k["codes"], idx, bit)
                flips.append((idx, bit))
            # and one meta word forced to the 0xFF NaN sentinel
            midx = (0, pid, int(self.rng.integers(k["meta"].shape[2])), 0)
            k["meta"] = k["meta"].at[midx].set(
                k["meta"][midx] | jnp.uint32(0xFF << 24))
            detail = {"page": pid, "flips": flips, "meta_nan_at": midx}
        self.fired = True
        self.events.append((self.spec.kind, detail))
        return {"k": k, "v": kv["v"]}

    # -- slot-scheduler hook (before a decode chunk) ------------------------

    def poison_cache(self, kv: dict, slot_req, chunk_idx: int) -> dict:
        """nan_activation: NaN into the target slot's bf16 V cache (token
        0 — always a valid, attended position)."""
        if (self.fired or chunk_idx < self.spec.after_chunk
                or self.spec.kind != "nan_activation"):
            return kv
        for b, rid in enumerate(slot_req):
            if rid != self.spec.target_request:
                continue
            v = kv["v"]
            assert not isinstance(v, dict), (
                "nan_activation targets the bf16 KV cache; use "
                "code_flip/meta_flip/page_corruption for packed KV")
            idx = (0, b) + (0,) * (v.ndim - 2)
            self.fired = True
            self.events.append(("nan_activation", {"slot": b, "idx": idx}))
            return {"k": kv["k"], "v": v.at[idx].set(jnp.nan)}
        return kv

    # -- crash-point hook (journaled schedulers) ----------------------------

    def crash_point(self, point: str, *, chunk_idx: int = 0,
                    rid=None, journal=None) -> None:
        """Kill the process at a named crash point by raising
        :class:`SimulatedCrash`. The journal is committed first — a real
        crash can only lose what was never fsynced, and these faults
        model the crash *after* the durable write the point is named
        for. ``journal_truncation`` additionally tears ``spec.bits``
        bytes off the journal's end before dying."""
        if self.fired or self.spec.kind not in CRASH_CLASSES:
            return
        kind = self.spec.kind
        if kind == "crash_after_admit":
            if point != "after_admit" or rid != self.spec.target_request:
                return
        elif kind in ("crash_mid_decode", "journal_truncation"):
            if point != "mid_decode" or chunk_idx < self.spec.after_chunk:
                return
        else:                              # crash_during_checkpoint
            if point != "during_checkpoint":
                return
        if journal is not None:
            journal.commit()
            if kind == "journal_truncation":
                journal.truncate_tail(self.spec.bits)
        self.fired = True
        self.events.append((kind, {"point": point, "chunk": chunk_idx,
                                   "rid": rid}))
        raise SimulatedCrash(
            f"simulated process kill at crash point {point!r} "
            f"(fault {kind}, chunk {chunk_idx}); resume from the journal")

    # -- preemption hook (after the fingerprint is stamped) -----------------

    def poison_snapshot(self, pages: dict, rid) -> dict:
        """Corrupt a host page snapshot: truncate the last page column
        (``bits == 0``) or flip one bit in the codes payload."""
        if self.fired or self.spec.kind != "snapshot_truncation":
            return pages
        if rid != self.spec.target_request:
            return pages
        out = {t: dict(leaves) for t, leaves in pages.items()}
        if self.spec.bits == 0:
            for t in ("k", "v"):
                out[t] = {key: np.asarray(a)[:, :-1]
                          for key, a in out[t].items()}
            detail = {"mode": "truncated_last_page"}
        else:
            codes = np.array(out["k"]["codes"], copy=True)
            flat = codes.reshape(-1)
            pos = int(self.rng.integers(flat.size))
            bit = int(self.rng.integers(8))
            flat[pos] ^= np.uint8(1 << bit)
            out["k"]["codes"] = codes
            detail = {"mode": "bit_flip", "pos": pos, "bit": bit}
        self.fired = True
        self.events.append(("snapshot_truncation", {"rid": rid, **detail}))
        return out
