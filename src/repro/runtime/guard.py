"""Request-level fault domains for the serve stack.

The paper's 0xFF E6M2 NaN sentinel (docs/FORMATS.md, ``hif4.py``) exists
so that corrupted 4-bit payloads surface loudly instead of decoding into
silently wrong values. This module is the serving side of that contract:
cheap health sentinels fused into the decode scan, per-chunk integrity
audits over packed KV pages, integrity fingerprints for host preemption
snapshots and serving artifacts, and the status vocabulary the schedulers
use to contain a fault to the one request it hit.

Detection mechanisms, by fault class
------------------------------------

* **NaN/Inf activations** — the guarded decode scan carries a per-slot
  ``bad`` flag, OR-ing a ``~isfinite(logits)`` reduction every step
  (:func:`bad_logits`). Token outputs are bitwise identical to the
  unguarded scan; the flag is one extra (B, V) reduction.
* **0xFF meta corruption** — :func:`repro.core.hif4.meta_nan_mask`
  counted per slot (contiguous cache) or per page (paged pool). Algorithm
  1 never emits 0xFF, so any nonzero count is corruption — this covers
  the hot partial page whose checksum is legitimately in flux.
* **Arbitrary bit flips in packed pages** — per-page modular byte-sum
  checksums (:func:`repro.core.kvcache.page_checksums`) recomputed once
  per chunk and compared against the values recorded after the previous
  chunk, skipping pages the scheduler legitimately wrote in between. A
  single bit flip provably changes the sum.
* **Snapshot truncation / flips** — :func:`snapshot_fingerprint` (crc32
  over bytes + shapes) stamped when a preempted slot's pages are pulled
  to host, verified before re-admission ever scatters them back.
* **Artifact corruption** — per-leaf sha256 over PackedW codes/meta plus
  format invariants (:func:`artifact_integrity`), written into the
  serving artifact's ``extra.json`` and re-verified on load.

Statuses (every request gets exactly one, in ``stats["reports"]``):

* ``ok`` — served normally.
* ``retried`` — hit a fault (quarantine or corrupt snapshot) but was
  re-served successfully: from its prompt on the normal path (snapshot
  drop; greedy decode is deterministic, so the result is still exact) or
  solo on the qdq/bf16 degradation path (quarantine retry).
* ``quarantined`` — evicted after a fault and the one fallback retry
  also failed (or retries are disabled); result is an eos/-1 fill.
* ``rejected`` — could not be admitted (pool starvation) within the
  bounded retry budget; never ran.
* ``timeout`` — exceeded its deadline; partial result, padded.
"""
from __future__ import annotations

import dataclasses
import hashlib
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hif4, kvcache
from repro.core.qlinear import PackedW

STATUS_NAMES = frozenset(
    {"ok", "retried", "quarantined", "rejected", "timeout"})

FAULT_REASONS = (
    "nan_logits",          # decode-scan sentinel fired
    "meta_nan",            # 0xFF E6M2 count went nonzero
    "page_checksum",       # a settled page's byte sum changed
    "snapshot_integrity",  # preemption snapshot failed its fingerprint
    "pool_exhausted",      # admission/growth starved of pages
    "deadline",            # wall-clock deadline exceeded
)


# ---------------------------------------------------------------------------
# Typed serving exceptions (satellite: replace bare asserts/RuntimeErrors)
# ---------------------------------------------------------------------------


class ServeError(RuntimeError):
    """Base of all typed serving errors (subclasses RuntimeError so any
    pre-existing ``except RuntimeError`` handling keeps working)."""


class PoolExhaustedError(ServeError):
    """The paged KV pool cannot supply the pages a request needs and no
    guard is installed to convert the failure into a ``rejected`` status."""


class SnapshotIntegrityError(ServeError):
    """A preempted slot's host page snapshot failed its fingerprint."""


class JournalError(ServeError):
    """The write-ahead request journal is missing or corrupt beyond the
    torn-tail case its record framing recovers from (no valid start
    record, wrong version)."""


class RecoveryError(ServeError):
    """Crash recovery could not be performed safely: the resume request
    list / serve config does not match the journaled serve, or a
    recovered request's re-served output contradicts its journaled token
    prefix (recovered state is checked, not trusted)."""


class ArtifactError(ServeError):
    """Base for serving-artifact load/save problems."""


class ArtifactNotFoundError(ArtifactError):
    """No serving artifact at the given path."""


class ArtifactLayoutError(ArtifactError):
    """The tree handed to ``save_serving_artifact`` is not raw weights."""


class ArtifactIntegrityError(ArtifactError):
    """A loaded artifact's packed payload fails its recorded checksums or
    the HiF4 format invariants."""


# ---------------------------------------------------------------------------
# Guard configuration + per-request reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Health-sentinel configuration (frozen/hashable: rides on
    :class:`repro.runtime.serve_loop.ServeConfig` without disturbing jit
    cache keys — none of these fields enter traced code).

    nan_sentinel: carry the per-slot NaN/Inf logits flag in the decode
        scan. meta_audit: count 0xFF E6M2 sentinels over packed KV per
        chunk. page_checksums: per-page byte-sum audit over the paged
        pool per chunk. retry_fallback: re-serve a quarantined request
        once, solo, on the qdq impl + bf16 KV degradation path.
        deadline_s: per-request wall-clock budget (None = unlimited).
        max_admission_retries / admission_backoff_s: bounded retry with
        exponential backoff before a starved request is ``rejected``.
    """

    nan_sentinel: bool = True
    meta_audit: bool = True
    page_checksums: bool = True
    retry_fallback: bool = True
    deadline_s: Optional[float] = None
    max_admission_retries: int = 2
    admission_backoff_s: float = 0.0


def new_report() -> dict:
    return {"status": "ok", "detail": None, "retries": 0}


# ---------------------------------------------------------------------------
# Decode-scan + cache sentinels (device side)
# ---------------------------------------------------------------------------


def bad_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """(B, V) logits -> (B,) bool: True where any entry is NaN/Inf."""
    return ~jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)


def slot_meta_nan_counts(kv: dict) -> jnp.ndarray:
    """Contiguous packed cache {"k","v"} (kernel layout, leaves
    (L, B, G, S) meta) -> (B,) int32 count of 0xFF E6M2 sentinels."""
    total = 0
    for t in (kv["k"], kv["v"]):
        total = total + jnp.sum(
            hif4.meta_nan_mask(t["meta"]).astype(jnp.int32), axis=(0, 2, 3))
    return total


def pool_page_sums(kv: dict) -> jnp.ndarray:
    """Paged pool {"k","v"} -> (NP,) uint32 per-page content checksums,
    K+V combined (the 0xFF counts come fused out of the guarded scan —
    :func:`slot_meta_nan_counts` reduces the pool's (L, NP, G, P) meta to
    the same per-page axis)."""
    return kvcache.page_checksums(kv["k"]) + kvcache.page_checksums(kv["v"])


def pool_page_stats(kv: dict) -> dict:
    """Paged pool {"k","v"} -> {"sums": (NP,) uint32 content checksums,
    "meta_nan": (NP,) int32 0xFF counts}, both K+V combined."""
    nan = (kvcache.page_meta_nan_counts(kv["k"])
           + kvcache.page_meta_nan_counts(kv["v"]))
    return {"sums": pool_page_sums(kv), "meta_nan": nan}


slot_meta_nan_jit = jax.jit(slot_meta_nan_counts)
pool_page_sums_jit = jax.jit(pool_page_sums)
pool_page_stats_jit = jax.jit(pool_page_stats)


# ---------------------------------------------------------------------------
# Preemption-snapshot fingerprints (host side)
# ---------------------------------------------------------------------------


def snapshot_fingerprint(pages: dict) -> int:
    """crc32 over a host page snapshot's bytes AND shapes ({"k","v"} of
    {"codes","meta","tail"} numpy blocks) — truncation changes the shape
    term even if the surviving bytes happen to collide."""
    h = 0
    for tname in ("k", "v"):
        for key in ("codes", "meta", "tail"):
            a = np.asarray(pages[tname][key])
            h = zlib.crc32(repr((tname, key, a.shape, str(a.dtype))).encode(),
                           h)
            h = zlib.crc32(np.ascontiguousarray(a).view(np.uint8).tobytes(),
                           h)
    return h


def verify_snapshot(snap: dict) -> bool:
    """True iff a preemption snapshot still matches the fingerprint
    stamped when it was taken."""
    try:
        return snapshot_fingerprint(snap["pages"]) == snap["crc32"]
    except Exception:
        return False           # missing leaves / mangled structure


# ---------------------------------------------------------------------------
# Serving-artifact integrity (per-leaf checksums + format invariants)
# ---------------------------------------------------------------------------

INTEGRITY_VERSION = 1


def _packed_leaves(tree):
    """(path string, PackedW) pairs, without flattening INTO PackedW."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PackedW))
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat
            if isinstance(leaf, PackedW)]


def packed_invariants(name: str, leaf: PackedW) -> list:
    """HiF4 format invariants of one packed weight; [] when healthy.

    Checked both at export and at load: the E6M2 scale byte must never be
    the 0xFF NaN sentinel (Algorithm 1 cannot produce it), the contract
    dimension must be whole 64-groups, and codes/meta must agree on the
    group geometry of the declared (K, N) shape.
    """
    errs = []
    k, n = leaf.shape2d
    meta = np.asarray(leaf.meta)
    codes = np.asarray(leaf.codes)
    if k % hif4.GROUP_SIZE:
        errs.append(f"{name}: K={k} is not a multiple of 64 (group size)")
    nan = int(((meta >> 24) == hif4.META_NAN).sum())
    if nan:
        errs.append(
            f"{name}: {nan} meta word(s) carry the E6M2 NaN sentinel 0xFF "
            "— Algorithm 1 never emits it; the payload is corrupt")
    if leaf.kernel_layout:
        want_codes = meta.shape[:-2] + (meta.shape[-2] * 32, meta.shape[-1])
    else:
        want_codes = meta.shape + (32,)
    if codes.shape != want_codes:
        errs.append(
            f"{name}: codes shape {codes.shape} does not match meta "
            f"geometry (expected {want_codes})")
    return errs


def artifact_integrity(tree) -> dict:
    """Integrity record for a serving artifact: per-PackedW-leaf sha256
    over the codes and meta payloads. Stored in the artifact's
    ``extra.json`` by ``save_serving_artifact``."""
    leaves = {}
    for name, leaf in _packed_leaves(tree):
        leaves[name] = {
            "codes_sha256": hashlib.sha256(
                np.asarray(leaf.codes).tobytes()).hexdigest(),
            "meta_sha256": hashlib.sha256(
                np.asarray(leaf.meta).tobytes()).hexdigest(),
        }
    return {"version": INTEGRITY_VERSION, "leaves": leaves}


def verify_artifact_integrity(tree, integrity: dict, directory: str):
    """Raise :class:`ArtifactIntegrityError` if any packed leaf fails its
    recorded checksums or the HiF4 format invariants."""
    recorded = integrity.get("leaves", {})
    errs = []
    for name, leaf in _packed_leaves(tree):
        errs.extend(packed_invariants(name, leaf))
        ent = recorded.get(name)
        if ent is None:
            errs.append(f"{name}: no integrity record in extra.json")
            continue
        for field, payload in (("codes_sha256", leaf.codes),
                               ("meta_sha256", leaf.meta)):
            got = hashlib.sha256(np.asarray(payload).tobytes()).hexdigest()
            if got != ent[field]:
                errs.append(f"{name}: {field} mismatch (payload corrupt)")
    if errs:
        raise ArtifactIntegrityError(
            f"serving artifact at {directory!r} failed integrity "
            f"verification:\n  - " + "\n  - ".join(errs)
            + "\n  re-export it with repro.runtime.serve_loop."
            "save_serving_artifact from the raw training weights."
        )
