"""Crash-safe serving: write-ahead request journal + pool checkpoints.

A process crash must not lose finished work, and everything it does lose
must be recomputable EXACTLY. HiF4 makes the second half cheap: packed
page bytes are per-token-deterministic (a token's 64-elem groups depend
only on its own K/V vectors — docs/FORMATS.md), and greedy decode is
deterministic, so a request re-served from its prompt reproduces its
original tokens bit for bit. The journal therefore only has to make the
*bookkeeping* durable — which requests were admitted, which tokens each
chunk emitted, which requests reached a terminal status — plus periodic
page-pool checkpoints so long-running residents resume from their last
durable position instead of re-decoding from scratch.

Three pieces:

* :class:`RequestJournal` — append-only, crc32-framed record stream
  (``serve.journal``). Records buffer in memory and ``commit()`` writes +
  fsyncs them once per decode chunk, so the journal adds one small
  sequential write per chunk, not one per event. A fresh journal is
  staged at ``serve.journal.tmp`` and atomically renamed over the live
  file only after its start record (and any carried-over terminal
  results) are durable — a crash during resume can never destroy the
  previous journal.
* :func:`save_pool_checkpoint` / :func:`load_pool_checkpoint` — the
  resident slots' page bytes (via the same ``_pool_gather`` blocks
  preemption snapshots use), written as an ``.npz`` next to the journal
  and sha256-fingerprinted. The journal's ``checkpoint`` record is the
  COMMIT POINT: a checkpoint whose record never made it to the journal
  (crash mid-write) is ignored on recovery, never half-trusted.
* :func:`recover` — replays a journal (torn/truncated tail records are
  detected by the length+crc framing and dropped, never misparsed) into
  a :class:`RecoveryPlan`: terminal requests get their journaled results
  injected; residents covered by a verified checkpoint become preemption-
  style byte snapshots the paged scheduler restores through its existing
  ``try_admit`` path; everything else re-enters the queue from its
  prompt. The resumed serve then *verifies* recovery — each re-served
  request's output must extend its journaled token prefix, else
  :class:`~repro.runtime.guard.RecoveryError` — recovered state is
  checked, not trusted.

Byte layouts are specified in docs/FORMATS.md (§Write-ahead journal);
the recovery matrix per crash fault class is in docs/EXECUTION.md
(§Crash recovery). Crash points are driven deterministically by
``repro.runtime.faults`` (``crash_after_admit`` / ``crash_mid_decode`` /
``crash_during_checkpoint`` / ``journal_truncation``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.guard import (JournalError, RecoveryError,
                                 snapshot_fingerprint)

JOURNAL_VERSION = 1
JOURNAL_NAME = "serve.journal"
MAGIC = b"HJ01"
_HEADER = len(MAGIC) + 8            # magic | u32 payload len | u32 crc32

EVENT_KINDS = frozenset(
    {"start", "admitted", "chunk", "preempted", "done", "checkpoint"})


# ---------------------------------------------------------------------------
# Record framing (encode / decode)
# ---------------------------------------------------------------------------


def encode_record(event: dict) -> bytes:
    """One framed record: ``HJ01 | u32 len | u32 crc32(payload) | payload``
    with the payload UTF-8 JSON (sorted keys: byte-stable for a given
    event). Little-endian lengths; crc over the payload bytes only."""
    assert event.get("ev") in EVENT_KINDS, event
    payload = json.dumps(event, sort_keys=True,
                         separators=(",", ":")).encode("utf-8")
    head = MAGIC + len(payload).to_bytes(4, "little") \
        + zlib.crc32(payload).to_bytes(4, "little")
    return head + payload


def decode_records(data: bytes) -> tuple[list, int]:
    """(events, dropped_bytes): every fully-framed, crc-clean record from
    the front of ``data``; parsing stops at the FIRST bad frame (wrong
    magic, short header, short payload, crc mismatch, or invalid JSON)
    and everything from there on counts as dropped. A torn final record —
    the expected shape after a crash mid-write — is therefore detected
    and discarded, never misparsed into a bogus event."""
    events, off = [], 0
    n = len(data)
    while off + _HEADER <= n:
        if data[off:off + 4] != MAGIC:
            break
        size = int.from_bytes(data[off + 4:off + 8], "little")
        crc = int.from_bytes(data[off + 8:off + 12], "little")
        payload = data[off + _HEADER:off + _HEADER + size]
        if len(payload) < size or zlib.crc32(payload) != crc:
            break
        try:
            event = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            break
        if not isinstance(event, dict) or event.get("ev") not in EVENT_KINDS:
            break
        events.append(event)
        off += _HEADER + size
    return events, n - off


def prompt_sha256(prompt) -> str:
    """Identity of one request's prompt tokens — journaled at start and
    re-checked at resume, so a journal can never replay onto a different
    request list."""
    toks = np.asarray(jnp.asarray(prompt, jnp.int32)).ravel()
    return hashlib.sha256(toks.astype("<i4").tobytes()).hexdigest()


# ---------------------------------------------------------------------------
# Write-ahead journal (writer)
# ---------------------------------------------------------------------------


class RequestJournal:
    """Append-only request-lifecycle journal, fsync-batched per chunk.

    Writes stage at ``<dir>/serve.journal.tmp``; :meth:`activate` (called
    once the start record and any resume carry-over are durable) renames
    it atomically over ``serve.journal``. The fd stays valid across the
    rename, so appending simply continues on the live file. ``append``
    only buffers; ``commit`` does one write + flush + fsync — the
    scheduler calls it once per decode chunk (and before any simulated
    crash point, so crash tests exercise exactly the durable prefix a
    real kill would leave).
    """

    def __init__(self, directory: str):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.path = os.path.join(directory, JOURNAL_NAME)
        self._tmp_path = self.path + ".tmp"
        self._fh = open(self._tmp_path, "wb")
        self._active = False
        self._buffer: list[bytes] = []
        self.records_written = 0

    def append(self, ev: str, **fields) -> None:
        self._buffer.append(encode_record({"ev": ev, **fields}))

    def commit(self) -> None:
        """Flush buffered records durably (one write + fsync). A no-op
        with nothing buffered — the last durable state is still on disk,
        so a redundant fsync buys nothing."""
        if not self._buffer:
            return
        self._fh.write(b"".join(self._buffer))
        self.records_written += len(self._buffer)
        self._buffer.clear()
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def activate(self) -> None:
        """Commit, then atomically replace the live journal with the
        staged one. Until this runs, a crash leaves the previous journal
        untouched (resume-over-resume safety)."""
        self.commit()
        os.replace(self._tmp_path, self.path)
        self._active = True

    def truncate_tail(self, nbytes: int) -> None:
        """Chop ``nbytes`` off the end of the journal file — the
        ``journal_truncation`` fault hook's model of a torn final write.
        The reader must recover the remaining valid record prefix."""
        self.commit()
        size = self._fh.tell()
        self._fh.truncate(max(0, size - max(1, nbytes)))
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh.closed:
            return
        self.commit()
        self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_journal(directory: str) -> tuple[list, int]:
    """(events, dropped_bytes) of ``<dir>/serve.journal``. Raises
    :class:`JournalError` when there is no journal or its first record is
    not a valid ``start`` — with no start record nothing is recoverable
    and resuming would silently re-serve from scratch."""
    path = os.path.join(directory, JOURNAL_NAME)
    if not os.path.exists(path):
        raise JournalError(
            f"no journal at {path!r}: nothing to resume (a journaled serve "
            "writes it on its first committed chunk)")
    with open(path, "rb") as f:
        data = f.read()
    events, dropped = decode_records(data)
    if not events or events[0]["ev"] != "start" \
            or events[0].get("v") != JOURNAL_VERSION:
        raise JournalError(
            f"journal at {path!r} has no valid version-{JOURNAL_VERSION} "
            "start record — corrupt beyond the torn-tail case the framing "
            "recovers from")
    return events, dropped


# ---------------------------------------------------------------------------
# Pool checkpoints (resident page bytes, sha256-fingerprinted)
# ---------------------------------------------------------------------------

_SNAP_LEAVES = tuple((t, key) for t in ("k", "v")
                     for key in ("codes", "meta", "tail"))


def _store(a: np.ndarray) -> np.ndarray:
    """bfloat16 has no portable npz dtype — store tails as uint16 bits."""
    a = np.ascontiguousarray(a)
    return a.view(np.uint16) if a.dtype == np.dtype(jnp.bfloat16) else a


def _restore(a: np.ndarray, leaf: str) -> np.ndarray:
    return a.view(np.dtype(jnp.bfloat16)) if leaf == "tail" else a


def save_pool_checkpoint(directory: str, chunk_idx: int,
                         residents: dict) -> tuple[str, str]:
    """Write ``ckpt_<chunk>.npz`` holding every resident request's page
    blocks. ``residents`` maps rid -> the preemption-snapshot dict shape
    (``{"pages": {"k"/"v": {"codes","meta","tail"}}, "token", "toks"}``).
    Returns (filename, sha256 of the file bytes) — the journal's
    ``checkpoint`` record carries both, and recovery re-hashes the file
    before trusting a single byte of it."""
    arrays = {}
    for rid, snap in residents.items():
        for t, key in _SNAP_LEAVES:
            arrays[f"r{rid}_{t}_{key}"] = _store(
                np.asarray(snap["pages"][t][key]))
    fname = f"ckpt_{chunk_idx:08d}.npz"
    path = os.path.join(directory, fname)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    with open(path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    return fname, digest


def load_pool_checkpoint(directory: str, record: dict) -> Optional[dict]:
    """Rebuild rid -> page-block dicts from a journal ``checkpoint``
    record. Returns None (checkpoint unusable; callers fall back to
    re-prefill) when the file is missing or its sha256 does not match the
    journaled fingerprint — a half-written or bit-rotted checkpoint must
    degrade recovery, not poison it."""
    path = os.path.join(directory, record["file"])
    if not os.path.exists(path):
        return None
    with open(path, "rb") as f:
        data = f.read()
    if hashlib.sha256(data).hexdigest() != record["sha256"]:
        return None
    with np.load(os.path.join(directory, record["file"])) as z:
        out = {}
        for rid_s in record["residents"]:
            rid = int(rid_s)
            try:
                pages = {t: {key: _restore(z[f"r{rid}_{t}_{key}"], key)
                             for key in ("codes", "meta", "tail")}
                         for t in ("k", "v")}
            except KeyError:
                return None
            out[rid] = pages
    return out


# ---------------------------------------------------------------------------
# Replay -> recovery plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecoveryPlan:
    """Everything a resumed serve needs, rebuilt from checkpoint + tail.

    ``completed``: rid -> {"toks", "status", "detail", "retries"} for
    requests with a journaled terminal event (result injected, never
    re-served). ``suspended``: rid -> preemption-style snapshot
    (``pages``/``crc32``/``token``/``toks``) restored through the paged
    scheduler's existing snapshot re-admission. ``emitted``: rid -> the
    journaled token prefix every re-served request's output is verified
    against. ``replayed``/``re_prefilled``/``dropped_records`` feed the
    launcher's recovery report; ``recovery_ms`` is the plan-build time
    (journal read + checkpoint verify + snapshot rebuild)."""

    meta: dict
    completed: dict = dataclasses.field(default_factory=dict)
    suspended: dict = dataclasses.field(default_factory=dict)
    emitted: dict = dataclasses.field(default_factory=dict)
    replayed: int = 0
    re_prefilled: int = 0
    dropped_records: int = 0
    recovery_ms: float = 0.0

    def report(self) -> dict:
        return {"completed": len(self.completed),
                "replayed": self.replayed,
                "re_prefilled": self.re_prefilled,
                "dropped_bytes": self.dropped_records,
                "recovery_ms": round(self.recovery_ms, 3)}

    def expected_prefix(self, rid: int) -> list:
        """The journaled greedy tokens a re-served request MUST reproduce
        (clamped at budget and first eos, matching the scheduler's
        finalize semantics)."""
        toks = list(self.emitted.get(rid, ()))[: self.meta["budget"]]
        eos = self.meta.get("eos")
        if eos is not None and eos in toks:
            toks = toks[: toks.index(eos) + 1]
        return toks


def replay(events: list) -> tuple[dict, dict, dict, Optional[dict]]:
    """Fold a journal's event stream into per-request state.

    Returns (emitted, terminal, in_flight, last_checkpoint): ``emitted``
    maps rid -> every token journaled for it (reset by a fresh-prefill
    re-admission — a dropped snapshot recomputes from the prompt, so
    earlier emissions are superseded, not extended); ``terminal`` maps
    rid -> its ``done`` event; ``in_flight`` holds the rids admitted but
    not terminal."""
    emitted: dict = {}
    terminal: dict = {}
    admitted: set = set()
    last_ckpt = None
    for ev in events[1:]:
        kind = ev["ev"]
        if kind == "admitted":
            admitted.add(ev["rid"])
            emitted[ev["rid"]] = list(ev["toks"])
        elif kind == "chunk":
            for rid_s, toks in ev["emitted"].items():
                emitted.setdefault(int(rid_s), []).extend(toks)
        elif kind == "done":
            terminal[ev["rid"]] = ev
        elif kind == "checkpoint":
            last_ckpt = ev
        # "preempted" carries no replay state: the snapshot lived only in
        # process memory, and a checkpoint taken while the pages were
        # still resident stays valid regardless (its file copy is frozen)
    in_flight = {rid for rid in admitted if rid not in terminal}
    return emitted, terminal, in_flight, last_ckpt


def recover(directory: str, requests, *, budget: int,
            eos: Optional[int]) -> RecoveryPlan:
    """Build the :class:`RecoveryPlan` a resumed serve starts from.

    Validates the journal against the resume-time ``requests`` (count +
    per-prompt sha256 — :class:`RecoveryError` on mismatch: replaying a
    journal onto different prompts would "verify" garbage), loads and
    verifies the last committed checkpoint, and restores each covered
    resident as a crc-stamped byte snapshot. Residents without verified
    coverage — and requests never admitted — simply re-enter the queue
    from their prompts: greedy decode is deterministic, so their results
    are exact either way; the checkpoint only buys back the decode time.
    """
    t0 = time.perf_counter()
    events, dropped = read_journal(directory)
    meta = events[0]
    if meta["n_requests"] != len(requests):
        raise RecoveryError(
            f"journal at {directory!r} covers {meta['n_requests']} "
            f"requests but resume was handed {len(requests)}")
    shas = [prompt_sha256(r) for r in requests]
    if meta["prompts"] != shas:
        bad = [i for i, (a, b) in enumerate(zip(meta["prompts"], shas))
               if a != b]
        raise RecoveryError(
            f"resume prompts differ from the journaled serve at request "
            f"id(s) {bad}: a journal only replays onto the request list "
            "that wrote it")
    if budget != meta["budget"] or eos != meta.get("eos"):
        raise RecoveryError(
            f"resume serve config (budget={budget}, eos={eos}) differs "
            f"from the journaled serve (budget={meta['budget']}, "
            f"eos={meta.get('eos')}); recovered decode would not be "
            "bitwise comparable")

    emitted, terminal, in_flight, ckpt = replay(events)
    plan = RecoveryPlan(meta=meta, emitted=emitted, dropped_records=dropped)
    for rid, ev in terminal.items():
        plan.completed[rid] = {"toks": list(ev["toks"]),
                               "status": ev["status"],
                               "detail": ev.get("detail"),
                               "retries": ev.get("retries", 0)}
    pages_by_rid = {}
    if ckpt is not None:
        pages_by_rid = load_pool_checkpoint(directory, ckpt) or {}
    for rid in sorted(in_flight):
        res = ckpt["residents"].get(str(rid)) if ckpt is not None else None
        pages = pages_by_rid.get(rid)
        if res is not None and pages is not None:
            snap = {"pages": pages, "token": res["token"],
                    "toks": list(res["toks"]),
                    "written": None}      # derived by the scheduler:
            #                               prompt + toks[:-1] (invariant)
            snap["crc32"] = snapshot_fingerprint(pages)
            plan.suspended[rid] = snap
            plan.replayed += 1
        else:
            plan.re_prefilled += 1
    plan.recovery_ms = (time.perf_counter() - t0) * 1e3
    return plan


def journal_residency(directory: str) -> dict:
    """Bytes on disk under a journal dir (the launcher's residency print):
    journal file size, checkpoint count + bytes."""
    out = {"journal_bytes": 0, "checkpoints": 0, "checkpoint_bytes": 0}
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if name == JOURNAL_NAME:
            out["journal_bytes"] = os.path.getsize(path)
        elif name.startswith("ckpt_") and name.endswith(".npz"):
            out["checkpoints"] += 1
            out["checkpoint_bytes"] += os.path.getsize(path)
    return out
