"""Scenario-matrix harness: declarative serve cells with dispatch probes.

A :class:`Scenario` is ONE cell of the regression matrix
(arch x impl x kv_format x policy x batch x seqlen), declared as data:
what to serve, how to serve it, which engine routes the cell MUST take
(``expect``), and how much measured-latency drift the stored trajectory
tolerates (``rel_tol``). :func:`run_scenarios` executes every cell
through the real ``repro.runtime.serve_loop`` stack — the same jitted
prefill / quantize-KV / decode-scan (or page-pool ``serve_requests``)
path production serving runs — and returns one record per cell carrying:

- measured steady-state decode-step latency (interleaved best-of-N on
  the jitted scan: the cells alternate inside one timing loop so
  sustained machine-load phases hit every cell equally — sequential
  phases were measured to swing CPU ratios 2.5-4x) and prefill latency;
- a roofline byte count built from EXACT HiF4 payload sizes (0.5625
  B/value packed weights, ``kvcache.kv_bytes_per_token`` for the cache)
  — ``benchmarks/roofline.py`` turns it into a predicted step time
  against the measured stream bandwidth;
- the engine dispatch actually probed for the cell
  (:func:`repro.core.engine.attention_dispatch_info` /
  :func:`packed_dispatch_info` / :func:`resolve_kv_format`) checked
  against the declared ``expect`` assertions.

Dispatch is probed analytically rather than spied at runtime because the
serve jit cache (``serve_loop._JIT_CACHE``) means repeated cells never
re-trace; ``tests/test_scenario.py`` pins probe == actual execution.

``benchmarks/matrix.py`` declares the cells and owns the stored
``BENCH_matrix.json`` trajectory + gates; this module is the mechanism.
"""
import dataclasses
import os
import shutil
import tempfile
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import engine as qengine
from repro.core import kvcache
from repro.core.policy import get_policy
from repro.core.qlinear import PackedW
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import faults
from repro.runtime import serve_loop
from repro.runtime.serve_loop import (
    ServeConfig,
    build_decode_cache,
    kv_format_fallback,
    packed_weight_bytes,
    resolve_kv_format,
    serve_requests,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative cell of the serve regression matrix."""

    name: str                     # unique cell id in BENCH_matrix.json
    arch: str                     # registry id; served .reduced()
    impl: str                     # qdq | packed | pallas
    kv_format: str                # REQUESTED cache format: bf16 | hif4
    paged: bool = False           # page-pool serve_requests cell
    guarded: bool = False         # guarded decode scan + per-chunk KV audit
    journaled: bool = False       # write-ahead journal + pool checkpoints
    #                               (journal dir is a per-run tempdir)
    recovery: bool = False        # crash (crash_mid_decode) + --resume cell:
    #                               records the recovery report and asserts
    #                               bitwise-identical recovered outputs
    decode_chunk: int = 0         # tokens per jitted scan chunk (0 = budget);
    #                               journal commits are per chunk, so the
    #                               journal cells pin it for a fair ratio
    policy: str = "uniform:hif4"  # QuantPolicy preset for weight sites
    batch: int = 2
    prompt_len: int = 16
    new_tokens: int = 8
    rel_tol: float = 3.0          # regression factor vs stored decode_step_ms
    # expected-dispatch assertions, e.g. ("kv:hif4", "kv:no-fallback",
    # "attn:fused_decode_attention", "matmul:fused") — see check_expect
    expect: Sequence[str] = ()


# expectation vocabulary -> how the probed dispatch must look. Routes are
# backend-NEUTRAL: "attn:fused_decode_attention" means the cell is
# kernel-eligible (the Pallas kernel on TPU, its bit-exact XLA twin
# off-TPU); "attn:twin" means the chunked-dequantize twin is the ONLY
# possible execution (qdq impl / layout), on every backend.
_EXPECT_CHECKS = {
    "kv:hif4": lambda d: d["kv_format_resolved"] == "hif4",
    "kv:bf16": lambda d: d["kv_format_resolved"] == "bf16",
    "kv:fallback": lambda d: d["kv_format_fallback"],
    "kv:no-fallback": lambda d: not d["kv_format_fallback"],
    "attn:fused_decode_attention":
        lambda d: d["attn"].get("kernel_eligible") and not d["paged"],
    "attn:fused_paged_decode_attention":
        lambda d: d["attn"].get("kernel_eligible") and d["paged"],
    "attn:twin":
        lambda d: d["attn"]["route"] != "none"
        and d["attn"].get("kernel_eligible") is False,
    "attn:dense": lambda d: d["attn"]["route"] == "dense",
    "attn:none": lambda d: d["attn"]["route"] == "none",
    "matmul:fused": lambda d: d["matmul"]["route"] == "fused",
    "matmul:dequant-dot": lambda d: d["matmul"]["route"] == "dequant-dot",
    "matmul:qdq": lambda d: d["matmul"]["route"] == "qdq",
}

EXPECTATIONS = tuple(sorted(_EXPECT_CHECKS))


def check_expect(expect: Sequence[str], dispatch: dict) -> list:
    """The declared assertions a probed dispatch violates (empty = pass)."""
    failed = []
    for e in expect:
        if e not in _EXPECT_CHECKS:
            failed.append(f"{e} (unknown expectation)")
        elif not _EXPECT_CHECKS[e](dispatch):
            failed.append(e)
    return failed


def prefill_batch(cfg, batch: int, prompt_len: int, seed: int = 1) -> dict:
    """The prefill inputs each family's serve entry takes."""
    key = jax.random.PRNGKey(seed)
    if cfg.family == "audio":
        return {"frames": jax.random.normal(
            key, (batch, prompt_len, cfg.d_model), jnp.float32)}
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(
            key, (batch, prompt_len, cfg.d_model), jnp.float32)}
    return {"tokens": jax.random.randint(
        key, (batch, prompt_len), 0, cfg.vocab)}


def _first_packed(params) -> Optional[PackedW]:
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedW)):
        if isinstance(leaf, PackedW):
            return leaf
    return None


def probe_dispatch(cfg, quant, serve_cfg: ServeConfig, serving_params,
                   *, paged: bool = False, batch: int = 1,
                   prompt_len: int = 16) -> dict:
    """Analytically resolve every dispatch decision this cell will hit.

    Pure probes — no serving, no tracing: ``resolve_kv_format`` for the
    cache format, :func:`repro.core.engine.attention_dispatch_info` on a
    geometry-exact packed probe cache (page-pool shaped for paged cells),
    and :func:`repro.core.engine.packed_dispatch_info` on the first real
    ``PackedW`` of the serving params (all block matmuls share the
    eligibility rule, which depends on impl/format, not shape).
    """
    a = cfg.attn
    resolved = resolve_kv_format(cfg, quant, serve_cfg)
    d = {
        "kv_format_resolved": resolved,
        "kv_format_fallback": kv_format_fallback(cfg, quant, serve_cfg),
        "paged": paged,
    }
    if cfg.family == "ssm" or a is None:
        d["attn"] = {"route": "none"}
    elif resolved != "hif4":
        d["attn"] = {"route": "dense"}
    elif paged:
        pool = kvcache.init_page_pool(cfg.n_layers, a.n_kv_heads, a.d_head,
                                      2, serve_cfg.kv_page_tokens)
        d["attn"] = qengine.attention_dispatch_info(
            quant, pool["k"], n_kv_heads=a.n_kv_heads, d_head=a.d_head,
            paged=True)
    else:
        probe = kvcache.to_kernel_layout(kvcache.quantize_kv(
            jnp.zeros((1, 8, a.n_kv_heads, a.d_head), jnp.bfloat16)))
        d["attn"] = qengine.attention_dispatch_info(
            quant, probe, n_kv_heads=a.n_kv_heads, d_head=a.d_head)
    w = _first_packed(serving_params)
    if w is None:
        # nothing packed (qdq plan / hybrid artifact): fake-quant dense dots
        d["matmul"] = {"route": "qdq", "execution": "qdq dense dot"}
    else:
        info = qengine.packed_dispatch_info(
            quant, w, decode_m=batch, prefill_m=batch * prompt_len)
        info["route"] = "fused" if info["fused"] else "dequant-dot"
        d["matmul"] = info
    return d


def _params_nbytes(params) -> int:
    """Resident weight bytes, PackedW-aware (exact 4.5-bit payload)."""
    packed_b, _ = packed_weight_bytes(params)
    dense_b = sum(
        leaf.nbytes for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedW))
        if not isinstance(leaf, PackedW))
    return packed_b + int(dense_b)


def decode_step_bytes(cfg, serving_params, cache, valid_len: int) -> dict:
    """EXACT HBM traffic floor of one decode step, from payload sizes.

    A decode step must stream every resident weight byte once (batch
    reuses them) plus the valid prefix of every attention cache entry:
    packed entries at their true 4.5-bit + meta + tail payload
    (``kvcache.packed_kv_nbytes``), dense entries at 2 B/value. The
    read-only cross cache is fully valid; recurrent ("layers") state is
    read AND written every step. This is the roofline numerator —
    dividing by measured stream bandwidth gives the predicted step time.
    """
    weight_bytes = _params_nbytes(serving_params)
    kv_bytes = 0
    for entry, frac_valid in (("kv", None), ("self", None), ("cross", 1.0)):
        kv = cache.get(entry)
        if kv is None:
            continue
        for tensor in (kv["k"], kv["v"]):
            if kvcache.is_packed_kv(tensor):
                total = kvcache.packed_kv_nbytes(tensor)
                cap = kvcache.seq_capacity(tensor)
            else:
                total = int(tensor.nbytes)
                cap = tensor.shape[2]          # (L, B, S, Hkv, Dh)
            frac = 1.0 if frac_valid else min(valid_len / cap, 1.0)
            kv_bytes += int(total * frac)
    state_bytes = 0
    if "layers" in cache:
        state_bytes = 2 * int(sum(                 # read + write
            leaf.nbytes for leaf in jax.tree_util.tree_leaves(cache["layers"])))
    return {
        "weight_bytes": weight_bytes,
        "kv_bytes": kv_bytes,
        "state_bytes": state_bytes,
        "bytes_per_step": weight_bytes + kv_bytes + state_bytes,
    }


def _build_cell(scn: Scenario):
    """Materialize one cell: cfg/ctx/plan, serving params, decode state."""
    cfg = get_arch(scn.arch).reduced()
    plan = lm.quant_plan(cfg, get_policy(
        scn.policy, impl=scn.impl, kv=kvcache.KVCacheConfig(scn.kv_format)))
    ctx = ModelCtx(quant=plan.base, plan=plan, remat=False,
                   attn_q_chunk=8, attn_k_chunk=8)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    sp = serve_loop.prepare_params_for_serving(params, cfg, plan)
    return cfg, ctx, sp


def _serve_cfg(scn: Scenario,
               journal_dir: Optional[str] = None) -> ServeConfig:
    sc = ServeConfig(max_new_tokens=scn.new_tokens, kv_format=scn.kv_format,
                     decode_chunk=scn.decode_chunk)
    if scn.paged:
        # pool sized to hold every request at full length, page = 16 toks
        pages = scn.batch * (
            -(-(scn.prompt_len + scn.new_tokens) // 16)) + 1
        sc = dataclasses.replace(sc, kv_pages=pages, kv_page_tokens=16,
                                 cache_capacity=-(-(scn.prompt_len
                                                    + scn.new_tokens) // 16) * 16)
    if scn.journaled:
        assert journal_dir is not None, (
            f"cell {scn.name}: journaled Scenario needs a journal_dir")
        # the overhead cell measures the WAL alone (fsync per chunk);
        # pool checkpoints — whose cost is a cadence knob, absurdly dense
        # at benchmark-cell scale (2-token chunks) — are exercised and
        # timed by the recovery cell instead
        sc = dataclasses.replace(sc, journal_dir=journal_dir,
                                 checkpoint_every=2 if scn.recovery else 0)
    return sc


def run_scenarios(scenarios: Sequence[Scenario], *, repeats: int = 7,
                  gate_pairs: Sequence[tuple] = (), log=print) -> list:
    """Execute cells through the real serve stack; one record per cell.

    Scan-served cells (everything non-paged) are timed INTERLEAVED on
    their jitted decode scans, feeding each call's returned state back
    (the scan donates its cache — this is exactly the serving steady
    state), best-of-``repeats``. Paged cells run the page-pool
    ``serve_requests`` scheduler end-to-end (admission + prefill +
    decode), so their latency is a coarser ms/token — their ``rel_tol``
    should say so. Each record's ``roofline`` carries exact payload byte
    counts; ``benchmarks.roofline`` turns them into predicted times.

    ``gate_pairs`` lists (baseline, subject) cell-name pairs the ratio
    gates compare. Each pair gets a SECOND, tight A/B interleave after
    the global rotation, recorded on the subject's record under
    ``gate_timing``: inside the full rotation every step inherits a
    different predecessor's cache/allocator state, and on CPU hosts
    that churn swings a single cell 10-20% between runs — noise a
    per-cell rel_tol absorbs but a two-cell ratio does not. Strict
    alternation gives both sides the same predecessor (each other), the
    same reasoning that made serve_throughput's kv_format sweep
    interleaved.
    """
    names = [s.name for s in scenarios]
    assert len(set(names)) == len(names), f"duplicate cell names: {names}"
    records, states, steps, serving, paged_cells = {}, {}, {}, {}, []
    tmp_dirs = []
    for scn in scenarios:
        t_setup = time.perf_counter()
        cfg, ctx, sp = _build_cell(scn)
        jdir = None
        if scn.journaled:
            # tmpfs when available: the overhead gate measures the WAL's
            # software cost (framing, fsync-batching, replay bookkeeping),
            # not the sync latency of whatever disk backs $TMPDIR.
            shm = "/dev/shm" if os.path.isdir("/dev/shm") else None
            jdir = tempfile.mkdtemp(prefix=f"matrix_{scn.name}_", dir=shm)
            tmp_dirs.append(jdir)
        sc = _serve_cfg(scn, journal_dir=jdir)
        dispatch = probe_dispatch(cfg, ctx.quant, sc, sp, paged=scn.paged,
                                  batch=scn.batch, prompt_len=scn.prompt_len)
        failed = check_expect(scn.expect, dispatch)
        rec = dict(dataclasses.asdict(scn))
        rec["expect"] = list(scn.expect)
        rec.update({
            "family": cfg.family,
            "kv_format_resolved": dispatch["kv_format_resolved"],
            "dispatch": {
                "kv_format_fallback": dispatch["kv_format_fallback"],
                "attn": dispatch["attn"],
                "matmul": dispatch["matmul"],
            },
            "dispatch_ok": not failed,
            "dispatch_failures": failed,
        })
        records[scn.name] = rec
        if scn.paged:
            paged_cells.append((scn, cfg, ctx, sp, sc))
            log(f"[matrix] {scn.name}: paged cell set up "
                f"({time.perf_counter() - t_setup:.1f}s)")
            continue

        sctx = serve_loop.serving_ctx(ctx)
        batch = prefill_batch(cfg, scn.batch, scn.prompt_len)
        prefill = serve_loop._jit_prefill(cfg, sctx)
        if scn.guarded:
            # production guarded chunk: one jitted call returns tokens +
            # a fused flags vector (NaN sentinels ++ 0xFF meta counters),
            # and the scheduler pulls tokens and flags to host in a single
            # device_get after every chunk — those costs belong in the
            # number the guard_overhead gate compares against the
            # unguarded twin
            gstep = serve_loop._jit_decode_scan_guarded(
                cfg, sctx, scn.new_tokens, None)
            zeros = jnp.zeros((scn.batch,), bool)

            def step(sp, token, cache, done, _g=gstep, _z=zeros):
                toks, token, cache, done, flags = _g(sp, token, cache,
                                                     done, _z)
                jax.device_get((toks, flags))
                return toks, token, cache, done
        else:
            ustep = serve_loop._jit_decode_scan(cfg, sctx, scn.new_tokens,
                                                None)

            # schedulers pull tokens to host every chunk; time that too so
            # guarded and unguarded cells differ only by the guard work
            def step(sp, token, cache, done, _u=ustep):
                toks, token, cache, done = _u(sp, token, cache, done)
                jax.device_get(toks)
                return toks, token, cache, done
        logits, cache = build_decode_cache(cfg, sp, batch, sctx, sc,
                                           quant=ctx.quant)
        rec["roofline"] = decode_step_bytes(
            cfg, sp, cache, scn.prompt_len + scn.new_tokens // 2)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = jnp.zeros(token.shape, bool)
        toks, token, cache, done = step(sp, token, cache, done)
        jax.block_until_ready(toks)                  # compile + warmup
        t_pre = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            out = prefill(sp, batch)
            jax.block_until_ready(out)
            t_pre = min(t_pre, time.perf_counter() - t0)
        rec["prefill_ms"] = round(t_pre * 1e3, 4)
        serving[scn.name], steps[scn.name] = sp, step
        states[scn.name] = (token, cache, done)
        log(f"[matrix] {scn.name}: compiled + warm "
            f"({time.perf_counter() - t_setup:.1f}s)")

    # interleaved steady-state decode timing across ALL scan cells
    best = {name: float("inf") for name in states}
    for _ in range(repeats):
        for name in states:
            token, cache, done = states[name]
            t0 = time.perf_counter()
            toks, token, cache, done = steps[name](
                serving[name], token, cache, done)
            jax.block_until_ready(toks)
            n = records[name]["new_tokens"]
            best[name] = min(best[name], (time.perf_counter() - t0) / n)
            states[name] = (token, cache, done)
    for name, t in best.items():
        records[name]["decode_step_ms"] = round(t * 1e3, 4)
        records[name]["timing"] = "scan-interleaved"

    # tight pairwise A/B interleave per ratio-gate pair (see docstring)
    for base_name, sub_name in gate_pairs:
        if base_name not in states or sub_name not in states:
            continue
        pair_best = {base_name: float("inf"), sub_name: float("inf")}
        for _ in range(3 * repeats):
            for name in (base_name, sub_name):
                token, cache, done = states[name]
                t0 = time.perf_counter()
                toks, token, cache, done = steps[name](
                    serving[name], token, cache, done)
                jax.block_until_ready(toks)
                n = records[name]["new_tokens"]
                pair_best[name] = min(pair_best[name],
                                      (time.perf_counter() - t0) / n)
                states[name] = (token, cache, done)
        records[sub_name].setdefault("gate_timing", {})[base_name] = {
            "baseline_ms": round(pair_best[base_name] * 1e3, 4),
            "subject_ms": round(pair_best[sub_name] * 1e3, 4)}

    pmap = {scn.name: (scn, cfg, ctx, sp, sc,
                       [jax.random.randint(jax.random.PRNGKey(40 + i),
                                           (scn.prompt_len,), 0, cfg.vocab)
                        for i in range(scn.batch)])
            for scn, cfg, ctx, sp, sc in paged_cells}

    def paged_e2e(name, *, stats=None, injector=None, resume=False):
        scn, cfg, ctx, sp, sc, reqs = pmap[name]
        t0 = time.perf_counter()
        out = serve_requests(cfg, sp, reqs, ctx, sc, slots=scn.batch,
                             stats=stats, injector=injector, resume=resume)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    rounds = max(2, repeats // 3)
    for name, (scn, cfg, ctx, sp, sc, reqs) in pmap.items():
        rec = records[name]
        t_e2e, out = float("inf"), None
        for _ in range(rounds):
            out, dt = paged_e2e(name)
            t_e2e = min(t_e2e, dt)
        rec["decode_step_ms"] = round(t_e2e / scn.new_tokens * 1e3, 4)
        rec["timing"] = "e2e-paged"
        rec["prefill_ms"] = None
        cache = lm.init_cache(cfg, scn.batch, scn.prompt_len + scn.new_tokens,
                              "hif4")
        rec["roofline"] = decode_step_bytes(
            cfg, sp, cache, scn.prompt_len + scn.new_tokens // 2)
        log(f"[matrix] {scn.name}: paged e2e {rec['decode_step_ms']} ms/tok")
        if scn.recovery:
            # crash the journaled serve mid-decode, then resume from its
            # journal and require bitwise-identical recovered outputs
            ref = [jax.device_get(r).tolist() for r in out]
            inj = faults.FaultInjector(faults.FaultSpec(
                "crash_mid_decode", after_chunk=1))
            crashed = False
            try:
                paged_e2e(name, injector=inj)
            except faults.SimulatedCrash:
                crashed = True
            stats: dict = {}
            out2, dt2 = paged_e2e(name, stats=stats, resume=True)
            got = [jax.device_get(r).tolist() for r in out2]
            rec["recovery"] = dict(
                stats.get("recovery", {}), crashed=crashed,
                bitwise=(got == ref), resume_ms=round(dt2 * 1e3, 3))
            log(f"[matrix] {scn.name}: recovery {rec['recovery']}")

    # tight pairwise A/B interleave for paged gate pairs (the scan-cell
    # loop above covers pairs timed on jitted decode scans; paged cells
    # are timed end-to-end, so their ratio gets the same treatment here)
    for base_name, sub_name in gate_pairs:
        if base_name not in pmap or sub_name not in pmap:
            continue
        pair_best = {base_name: float("inf"), sub_name: float("inf")}
        for _ in range(2 * rounds):
            for name in (base_name, sub_name):
                _, dt = paged_e2e(name)
                pair_best[name] = min(pair_best[name],
                                      dt / pmap[name][0].new_tokens)
        records[sub_name].setdefault("gate_timing", {})[base_name] = {
            "baseline_ms": round(pair_best[base_name] * 1e3, 4),
            "subject_ms": round(pair_best[sub_name] * 1e3, 4)}

    for d in tmp_dirs:
        shutil.rmtree(d, ignore_errors=True)
    return [records[s.name] for s in scenarios]
