"""Batched serving loop: offline weight PTQ -> prefill -> greedy decode.

Weights are quantized ONCE (``quantize_params_offline``) — the deployment
artifact; activations are cast dynamically inside each step (the paper's
A-W placement). The KV cache buffer is donated so decode updates in place.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import QuantConfig, quantize_params_offline
from repro.models import lm
from repro.models.common import ModelCtx


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    cache_capacity: Optional[int] = None   # default: prompt + max_new


def prepare_params_for_serving(params: dict, quant: QuantConfig) -> dict:
    """Offline PTQ of every block weight (embed/head/router excluded)."""
    if not quant.enabled:
        return params
    out = dict(params)
    for key in ("blocks", "shared", "enc_blocks"):
        if key in out:
            out[key] = quantize_params_offline(out[key], quant)
    return out


def serve(
    cfg: ArchConfig,
    params: dict,
    batch: dict,                       # prefill inputs (tokens/embeds/frames)
    ctx: ModelCtx,
    serve_cfg: ServeConfig = ServeConfig(),
):
    """Greedy-decode ``max_new_tokens``; returns (B, T) int32 tokens."""
    qcfg = dataclasses.replace(ctx.quant, offline_weights=True)
    sctx = ModelCtx(quant=qcfg, shard=ctx.shard, remat=False,
                    param_dtype=ctx.param_dtype, compute_dtype=ctx.compute_dtype,
                    attn_q_chunk=ctx.attn_q_chunk, attn_k_chunk=ctx.attn_k_chunk)
    params = prepare_params_for_serving(params, ctx.quant)

    logits, cache = jax.jit(lambda p, b: lm.prefill(p, b, cfg, sctx))(
        params, batch
    )
    if cfg.family in ("dense", "vlm", "moe", "audio", "hybrid"):
        prompt_len = int(cache["pos"])
        cap = serve_cfg.cache_capacity or prompt_len + serve_cfg.max_new_tokens
        cache = lm.pad_cache(cache, cfg, cap)

    step = jax.jit(
        lambda p, t, c: lm.decode_step(p, t, c, cfg, sctx),
        donate_argnums=(2,),
    )
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [token]
    for _ in range(serve_cfg.max_new_tokens - 1):
        logits, cache = step(params, token, cache)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(token)
    return jnp.stack(out, axis=1)
