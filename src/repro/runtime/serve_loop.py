"""Batched serving loop: offline weight PTQ/packing -> prefill -> scan decode.

Weights are converted ONCE into the deployment artifact the configured
execution path consumes (``QuantConfig.impl``):

  qdq            -> fake-quant (QDQ) bf16 weights (accuracy-experiment shape)
  packed/pallas  -> :class:`PackedW` 4.5-bit buffers in the K-major kernel
                    layout (real 0.5625 B/value HBM residency) consumed
                    directly by the fused dequantize-in-kernel matmul
                    (repro.kernels.fused_matmul)

Decode runs as a ``jax.lax.scan`` over a static token budget — ONE jitted
dispatch per chunk instead of one per token — with per-request done masks.
:func:`serve_requests` adds a slot-based continuous-batching scheduler on
top: a fixed number of decode slots, per-slot cache positions, and admission
of queued requests into slots as earlier requests finish.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import kvcache
from repro.core.policy import STACKED_COLLECTIONS, QuantPlan
from repro.core.qlinear import QuantConfig, quantize_params_offline
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import guard as guard_mod
from repro.runtime.guard import (ArtifactLayoutError, ArtifactNotFoundError,
                                 GuardConfig, PoolExhaustedError)


class KVFallbackWarning(UserWarning):
    """``kv_format=hif4`` was narrowed to bf16 for a family whose recurrent
    state has no packed layout. A real warning (not a print) so callers and
    tests capture and assert on it; records carry ``kv_format_fallback``."""


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    cache_capacity: Optional[int] = None   # default: prompt + max_new
    decode_chunk: int = 0                  # tokens per jitted scan segment;
    #                                        0 = the whole budget in one scan
    eos_id: Optional[int] = None           # stop a request at this token
    kv_format: Optional[str] = None        # 'bf16' | 'hif4' KV cache storage;
    #                                        None = ctx.quant.kv.kv_format
    kv_pages: int = 0                      # > 0: page-pool scheduler with this
    #                                        many pool pages (hif4 KV only)
    kv_page_tokens: int = 64               # tokens per pool page
    prefix_sharing: bool = True            # hash-share prompt-prefix pages
    guard: Optional[GuardConfig] = None    # health sentinels + fault domains
    #                                        (None = unguarded; failures raise)
    journal_dir: Optional[str] = None      # write-ahead request journal +
    #                                        pool checkpoints live here
    #                                        (None = no crash safety)
    checkpoint_every: int = 0              # pool checkpoint cadence in decode
    #                                        chunks (paged scheduler; 0 = off)


def resolve_kv_format(cfg: ArchConfig, quant: QuantConfig,
                      serve_cfg: ServeConfig, *, verbose: bool = False,
                      warned: Optional[set] = None) -> str:
    """The KV storage this serve actually runs: ServeConfig overrides the
    QuantConfig KVCacheConfig; SSM-state families fall back to bf16 (the
    recurrent state has no packed layout — see the docs/EXECUTION.md
    matrix). Attention caches — including the audio self + read-only
    cross (encoder) caches — pack. ``verbose=True`` (the serve/launch
    entry points) emits a :class:`KVFallbackWarning` instead of narrowing
    silently; benchmark and dryrun records carry it as
    ``kv_format_fallback``. ``warned`` is a per-serve-call dedup set:
    the fallback warns once per (arch, requested format) per serve call,
    not once per admission/re-prefill that re-resolves the format."""
    from repro.core import kvcache

    fmt = serve_cfg.kv_format or quant.kv.kv_format
    assert fmt in kvcache.KV_FORMATS, fmt
    if fmt == "hif4" and cfg.family not in ("dense", "vlm", "moe", "audio"):
        key = (cfg.name, fmt)
        if verbose and (warned is None or key not in warned):
            if warned is not None:
                warned.add(key)
            warnings.warn(
                f"kv_format=hif4 has no packed layout for family "
                f"{cfg.family!r} (SSM recurrent state) — serving falls "
                f"back to bf16 KV", KVFallbackWarning, stacklevel=2)
        return "bf16"
    return fmt


def kv_format_fallback(cfg: ArchConfig, quant: QuantConfig,
                       serve_cfg: ServeConfig) -> bool:
    """True when the requested KV format was narrowed by family fallback —
    the flag benchmark/dryrun records carry so a silently-bf16 run is
    visible in artifacts, not just stdout."""
    requested = serve_cfg.kv_format or quant.kv.kv_format
    return resolve_kv_format(cfg, quant, serve_cfg) != requested


def _to_kernel_layout(params):
    """Re-layout every PackedW leaf K-major ONCE (same 4.5-bit payload,
    transposed) so the fused matmul tiles resident buffers per step instead
    of re-laying-out inside the decode scan body."""
    from repro.core.qlinear import PackedW

    return jax.tree_util.tree_map(
        lambda leaf: leaf.to_kernel_layout()
        if isinstance(leaf, PackedW) else leaf,
        params, is_leaf=lambda x: isinstance(x, PackedW),
    )


def prepare_params_for_serving(params: dict, cfg: ArchConfig,
                               quant, *, kernel_layout: bool = True) -> dict:
    """One-time offline conversion of block weights into the serving artifact.

    ``quant`` is a legacy global :class:`QuantConfig` (converted via the
    uniform-policy shim), a :class:`~repro.core.policy.QuantPolicy`, or an
    already-resolved :class:`~repro.core.policy.QuantPlan`. Per site, the
    resolved plan decides the artifact — there is no other packing
    predicate:

    * sites the plan marks ``packed`` become 4.5-bit PackedW buffers in
      the K-major kernel layout the fused matmul consumes
      (docs/FORMATS.md);
    * quantized-but-not-packed sites (qdq impl, non-HiF4 formats, or
      sites a rule flipped away from the packed path) get the offline
      fake-quant QDQ artifact along their true contraction axes;
    * everything else (embed/head/router under the default §IV rules,
      fmt='none' sites) stays full precision.

    ``kernel_layout=False`` keeps PackedW leaves in the artifact
    (output-major, on-disk) layout — what :func:`save_serving_artifact`
    checkpoints; serving always re-lays-out K-major once.
    """
    plan = lm.quant_plan(cfg, quant)
    if not plan.enabled:
        return params
    if packed_weight_bytes(params)[1]:
        # already packed (idempotent); honor the layout request — there is
        # no kernel->artifact inverse, so callers needing the artifact
        # layout must start from raw weights (save_serving_artifact asserts)
        return _to_kernel_layout(params) if kernel_layout else params
    out = dict(params)
    if plan.packed_paths:
        out = lm.pack_params_for_serving(out, cfg, plan)
    for key in STACKED_COLLECTIONS:
        if key in out:
            out[key] = quantize_params_offline(out[key], plan.base,
                                               plan=plan, prefix=key)
    # top-level untied head: a policy that quantizes it gets a real
    # offline artifact too (the uniform shim resolves it to fmt='none')
    site = plan.get("lm_head")
    if (site is not None and "lm_head" in out and site.quantize_offline
            and site.cfg.format() is not None):
        from repro.core.qlinear import _qdq_along

        out["lm_head"] = _qdq_along(out["lm_head"], site.cfg.format(),
                                    site.contract_axes)
    if plan.packed_paths and kernel_layout:
        return _to_kernel_layout(out)
    return out


def serving_ctx(ctx: ModelCtx) -> ModelCtx:
    """The model context decode runs under: weights already quantized
    offline (skip in-graph weight QDQ), no remat. With a policy plan
    attached, every site config gets the same offline flip."""
    qcfg = dataclasses.replace(ctx.quant, offline_weights=True)
    plan = ctx.plan.with_offline_weights() if ctx.plan is not None else None
    return dataclasses.replace(ctx, quant=qcfg, plan=plan, remat=False)


def save_serving_artifact(directory: str, params: dict, cfg: ArchConfig,
                          policy) -> str:
    """Write the deployment artifact: the policy-converted weights (PackedW
    leaves in the on-disk artifact layout, QDQ'd bf16 elsewhere) PLUS the
    policy itself, serialized into the checkpoint's ``extra.json`` — so an
    artifact can never be served under a different placement than it was
    packed with. ``params`` are the RAW trained weights; ``policy`` is a
    QuantPolicy/QuantPlan (or a legacy QuantConfig via the uniform shim).

    The checkpoint's ``extra.json`` also records an integrity block —
    per-PackedW-leaf sha256 over the codes and meta payloads plus the
    HiF4 format invariants (:mod:`repro.runtime.guard`) — which
    :func:`load_serving_artifact` re-verifies, so a bit-rotted artifact
    fails loudly at load instead of serving silently wrong tokens.
    """
    from repro.checkpoint import save_checkpoint

    if packed_weight_bytes(params)[1]:
        raise ArtifactLayoutError(
            f"save_serving_artifact({directory!r}) was handed an "
            "already-packed tree. Expected RAW (unpacked) trained weights: "
            "packed PackedW leaves may be in the K-major kernel layout, "
            "which has no inverse back to the on-disk artifact layout. "
            "To re-export, load the raw training weights and call "
            "save_serving_artifact(directory, raw_params, cfg, policy) — "
            "the policy conversion happens inside.")
    plan = lm.quant_plan(cfg, policy)
    artifact = prepare_params_for_serving(params, cfg, plan,
                                          kernel_layout=False)
    extra = {"family": cfg.family,
             "quant_policy": plan.policy.to_json_dict(),
             "integrity": guard_mod.artifact_integrity(artifact)}
    return save_checkpoint(directory, 0, artifact, extra)


def load_serving_artifact(directory: str, cfg: ArchConfig):
    """Restore (serving_params, policy) written by
    :func:`save_serving_artifact`. The policy is read FIRST and its
    resolved plan rebuilds the packed/dense tree structure the arrays load
    into; pass the params straight to :func:`serve` with a plan-carrying
    ModelCtx (prepare is idempotent on the packed tree and only re-lays-out
    K-major).

    Artifacts written with an integrity block (see
    :func:`save_serving_artifact`) are verified leaf-by-leaf after load;
    corruption raises :class:`repro.runtime.guard.ArtifactIntegrityError`
    naming the failing leaf. Older artifacts without the block load
    unverified.
    """
    import json
    import os

    from repro.checkpoint import latest_step, load_checkpoint
    from repro.core.policy import QuantPolicy

    step = latest_step(directory)
    if step is None:
        raise ArtifactNotFoundError(
            f"no serving artifact under {directory!r}: expected a "
            "step_<NNNNNNNN>/ directory holding manifest.json, the packed "
            "arrays, and extra.json with the serialized quant_policy. "
            "Re-export with repro.runtime.serve_loop.save_serving_artifact("
            "directory, raw_params, cfg, policy).")
    with open(os.path.join(directory, f"step_{step:08d}", "extra.json")) as f:
        extra = json.load(f)
    policy = QuantPolicy.from_json_dict(extra["quant_policy"])
    plan = lm.quant_plan(cfg, policy)
    specs = lm.packed_overlay(lm.abstract_params(cfg), plan)
    target = lm.realize_packed(
        specs, lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype))
    params, _ = load_checkpoint(directory, step, target)
    integrity = extra.get("integrity")
    if integrity is not None:
        guard_mod.verify_artifact_integrity(params, integrity, directory)
    return params, policy


def packed_weight_bytes(params) -> tuple[int, int]:
    """(packed payload bytes, packed value count) over all PackedW leaves."""
    from repro.core.qlinear import PackedW

    total = values = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, PackedW)
    ):
        if isinstance(leaf, PackedW):
            total += leaf.nbytes_packed
            values += leaf.n_values
    return total, values


def kv_cache_bytes(cache: dict) -> tuple[int, int]:
    """(resident KV-cache bytes, token slots) of a decode cache.

    Counts every attention KV entry: "kv" (transformer/hybrid families) or
    "self" + "cross" (audio). Token slots = B * capacity of the decode
    self-attention cache (one slot holds a token's K/V across ALL layers,
    so bytes/token = bytes / slots); the read-only cross cache contributes
    bytes but no slots. Works on bf16 and HiF4-packed caches alike.
    """
    from repro.core import kvcache

    total = 0
    slots = 0
    for entry, counts_slots in (("kv", True), ("self", True),
                                ("cross", False)):
        kv = cache.get(entry)
        if kv is None:
            continue
        for tensor in (kv["k"], kv["v"]):
            if kvcache.is_packed_kv(tensor):
                total += kvcache.packed_kv_nbytes(tensor)
                b = tensor["meta"].shape[1]          # (L, B, ...) stacked
                s = kvcache.seq_capacity(tensor)
            else:
                total += int(tensor.nbytes)
                _, b, s = tensor.shape[:3]
            if counts_slots:
                slots = b * s
    return total, slots


# ---------------------------------------------------------------------------
# Scan decode
# ---------------------------------------------------------------------------


def _decode_scan(params, token, cache, done, n_tokens: int, cfg: ArchConfig,
                 sctx: ModelCtx, eos_id: Optional[int]):
    """Greedy-decode ``n_tokens`` steps inside one lax.scan.

    token (B,) int32 is the last emitted token; done (B,) bool masks
    finished requests (their slots keep emitting eos/pad, and their cache
    writes are inert because outputs are masked). Returns
    (tokens (B, n_tokens), token, cache, done).
    """

    def body(carry, _):
        token, cache, done = carry
        logits, cache = lm.decode_step(params, token, cache, cfg, sctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if eos_id is not None:
            nxt = jnp.where(done, jnp.int32(eos_id), nxt)
            done = done | (nxt == eos_id)
        return (nxt, cache, done), nxt

    (token, cache, done), toks = jax.lax.scan(
        body, (token, cache, done), None, length=n_tokens
    )
    return jnp.swapaxes(toks, 0, 1), token, cache, done


def _decode_scan_guarded(params, token, cache, done, bad, n_tokens: int,
                         cfg: ArchConfig, sctx: ModelCtx,
                         eos_id: Optional[int]):
    """:func:`_decode_scan` with the health sentinels fused in.

    ``bad`` (B,) bool OR-accumulates a per-slot ``~isfinite(logits)``
    reduction every step (:func:`repro.runtime.guard.bad_logits`) —
    one extra (B, V) reduction carried in the scan state. After the scan,
    the SAME jitted program reduces the 0xFF E6M2 sentinel count over the
    packed KV leaves (per slot for the contiguous cache, per pool page
    for the paged pool; zeros for bf16 KV): corruption persists in the
    cache, so one end-of-chunk reduction sees everything a per-step one
    would, without a second dispatch or host sync. Both sentinels come
    back as ONE ``flags`` int32 vector — ``flags[:B]`` the NaN flags,
    ``flags[B:]`` the 0xFF counts — so the scheduler's existing per-chunk
    token pull grows by a single small leaf (host-transfer calls carry a
    large fixed cost; the guard_overhead gate holds because of this).
    The token stream is computed by exactly the same ops in the same
    order, so guarded outputs are bitwise identical to the unguarded
    scan. Returns (tokens (B, n_tokens), token, cache, done, flags).
    """

    def body(carry, _):
        token, cache, done, bad = carry
        logits, cache = lm.decode_step(params, token, cache, cfg, sctx)
        bad = bad | guard_mod.bad_logits(logits)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if eos_id is not None:
            nxt = jnp.where(done, jnp.int32(eos_id), nxt)
            done = done | (nxt == eos_id)
        return (nxt, cache, done, bad), nxt

    (token, cache, done, bad), toks = jax.lax.scan(
        body, (token, cache, done, bad), None, length=n_tokens
    )
    kv = cache.get("kv") if isinstance(cache, dict) else None
    if isinstance(kv, dict) and isinstance(kv.get("k"), dict) \
            and "meta" in kv["k"]:
        meta_nan = guard_mod.slot_meta_nan_counts(kv)
    else:
        meta_nan = jnp.zeros(token.shape, jnp.int32)
    flags = jnp.concatenate([bad.astype(jnp.int32), meta_nan])
    return jnp.swapaxes(toks, 0, 1), token, cache, done, flags


# jax.jit caches compiled executables per wrapper OBJECT, so building a
# fresh wrapper inside every serve() call would retrace+recompile the whole
# model per call. Key the wrappers on the values that change the traced
# graph (ArchConfig and QuantConfig are frozen/hashable; ShardCtx is not —
# its mesh identity + rules stand in for it). Bounded in practice: a
# handful of (arch, ctx, budget) combinations per process.
_JIT_CACHE: dict = {}


def _ctx_cache_key(ctx: ModelCtx):
    shard = ctx.shard
    mesh_key = None if shard.mesh is None else (
        tuple(shard.mesh.shape.items()), id(shard.mesh)
    )
    return (ctx.quant, ctx.plan, ctx.scope, mesh_key,
            tuple(sorted((k, tuple(v)) for k, v in shard.rules.items())),
            str(ctx.param_dtype), str(ctx.compute_dtype), ctx.remat,
            ctx.attn_q_chunk, ctx.attn_k_chunk, ctx.attn_impl,
            ctx.attn_kv_block)


def _jit_prefill(cfg: ArchConfig, sctx: ModelCtx):
    key = ("prefill", cfg, _ctx_cache_key(sctx))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda p, b: lm.prefill(p, b, cfg, sctx))
        _JIT_CACHE[key] = fn
    return fn


def _jit_quantize_kv(cfg: ArchConfig):
    key = ("quantize_kv", cfg)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda c: lm.quantize_kv_cache(c, cfg))
        _JIT_CACHE[key] = fn
    return fn


def _jit_decode_scan(cfg: ArchConfig, sctx: ModelCtx, n_tokens: int,
                     eos_id: Optional[int]):
    key = ("decode", cfg, _ctx_cache_key(sctx), n_tokens, eos_id)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            partial(_decode_scan, n_tokens=n_tokens, cfg=cfg, sctx=sctx,
                    eos_id=eos_id),
            donate_argnums=(2,),            # cache updates in place
        )
        _JIT_CACHE[key] = fn
    return fn


def _jit_decode_scan_guarded(cfg: ArchConfig, sctx: ModelCtx, n_tokens: int,
                             eos_id: Optional[int]):
    key = ("decode-guarded", cfg, _ctx_cache_key(sctx), n_tokens, eos_id)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            partial(_decode_scan_guarded, n_tokens=n_tokens, cfg=cfg,
                    sctx=sctx, eos_id=eos_id),
            donate_argnums=(2,),            # cache updates in place
        )
        _JIT_CACHE[key] = fn
    return fn


def build_decode_cache(cfg: ArchConfig, serving_params: dict, batch: dict,
                       sctx: ModelCtx, serve_cfg: ServeConfig, *,
                       quant=None, verbose: bool = False,
                       warned: Optional[set] = None):
    """Prefill and return (last-token logits, THE decode cache serve runs).

    The exact cache-build sequence :func:`serve` decodes against: prefill,
    then — when :func:`resolve_kv_format` says the serve really runs hif4 —
    pack the prefix ONCE (per-token groups: bit-identical to having
    appended the same tokens one at a time), then pad to capacity (zero
    padding of packed leaves is inert under the length mask). Exposed so
    tests and the scenario matrix can assert the format actually served —
    the ``kv_format_fallback`` flag must agree with these leaves.
    """
    quant = quant or sctx.quant
    kv_fmt = resolve_kv_format(cfg, quant, serve_cfg, verbose=verbose,
                               warned=warned)
    logits, cache = _jit_prefill(cfg, sctx)(serving_params, batch)
    if kv_fmt == "hif4":
        cache = _jit_quantize_kv(cfg)(cache)
    if cfg.family in ("dense", "vlm", "moe", "audio", "hybrid"):
        prompt_len = int(cache["pos"])
        cap = serve_cfg.cache_capacity or prompt_len + serve_cfg.max_new_tokens
        cache = lm.pad_cache(cache, cfg, cap)
    return logits, cache


def serve(
    cfg: ArchConfig,
    params: dict,
    batch: dict,                       # prefill inputs (tokens/embeds/frames)
    ctx: ModelCtx,
    serve_cfg: ServeConfig = ServeConfig(),
):
    """Greedy-decode ``max_new_tokens``; returns (B, T) int32 tokens.

    All requests advance in lockstep (shared position clock); decode is a
    single jitted scan per ``decode_chunk`` segment, not a dispatch per
    token. For heterogeneous request streams use :func:`serve_requests`.
    """
    sctx = serving_ctx(ctx)
    params = prepare_params_for_serving(params, cfg, ctx.plan or ctx.quant)
    logits, cache = build_decode_cache(cfg, params, batch, sctx, serve_cfg,
                                       quant=ctx.quant, verbose=True)

    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    done = jnp.zeros(token.shape, bool)
    if serve_cfg.eos_id is not None:
        done = done | (token == serve_cfg.eos_id)
    out = [token[:, None]]

    budget = serve_cfg.max_new_tokens - 1
    chunk = serve_cfg.decode_chunk or budget
    emitted = 0
    while emitted < budget:
        n = min(chunk, budget - emitted)
        step = _jit_decode_scan(cfg, sctx, n, serve_cfg.eos_id)
        toks, token, cache, done = step(params, token, cache, done)
        out.append(toks)
        emitted += n
        if serve_cfg.eos_id is not None and bool(jnp.all(done)):
            break
    toks = jnp.concatenate(out, axis=1)
    if toks.shape[1] < serve_cfg.max_new_tokens and serve_cfg.eos_id is not None:
        pad = jnp.full(
            (toks.shape[0], serve_cfg.max_new_tokens - toks.shape[1]),
            serve_cfg.eos_id, jnp.int32,
        )
        toks = jnp.concatenate([toks, pad], axis=1)
    return toks


# ---------------------------------------------------------------------------
# Continuous batching: slot-based admission over a shared decode batch
# ---------------------------------------------------------------------------


def _insert_slot(cache, slot_cache, token, slot_token, b: int):
    """Write a freshly prefilled request (batch 1) into batch slot ``b``.

    KV leaves are (L, B, S, Hkv, Dh) — insert along axis 1; the per-slot
    ``pos`` vector and last-token vector update at index ``b``.
    """

    def put(full, one):
        idx = (0, b) + (0,) * (full.ndim - 2)
        return jax.lax.dynamic_update_slice(full, one.astype(full.dtype), idx)

    new_kv = jax.tree_util.tree_map(put, cache["kv"], slot_cache["kv"])
    pos = cache["pos"].at[b].set(slot_cache["pos"].astype(jnp.int32))
    return (
        {"kv": new_kv, "pos": pos},
        token.at[b].set(slot_token),
    )


_insert_slot_jit = jax.jit(_insert_slot, static_argnums=(4,),
                           donate_argnums=(0,))


def _finalize_result(toks: list, budget: int, eos_id: Optional[int]):
    """Trim a slot's emitted tokens to the request's (budget,) result: drop
    over-emission past the budget, and past eos replace everything with eos
    padding (a finished request keeps emitting eos inside the chunked scan).
    """
    toks = toks[:budget]
    if eos_id is not None and eos_id in toks:
        stop = toks.index(eos_id) + 1
        toks = toks + [eos_id] * (budget - len(toks))
        toks = toks[:stop] + [eos_id] * (budget - stop)
    return jnp.asarray(toks, jnp.int32)


def _failed_result(budget: int, eos_id: Optional[int]) -> jnp.ndarray:
    """The (budget,) placeholder a rejected/quarantined request returns:
    eos fill when an eos is configured, else -1 (never a valid token)."""
    return jnp.full((budget,), eos_id if eos_id is not None else -1,
                    jnp.int32)


def _finalize_partial(toks: list, budget: int,
                      eos_id: Optional[int]) -> jnp.ndarray:
    """A timed-out request's partial tokens, padded to (budget,)."""
    fill = eos_id if eos_id is not None else -1
    toks = list(toks[:budget])
    return jnp.asarray(toks + [fill] * (budget - len(toks)), jnp.int32)


def _retry_fallback(cfg: ArchConfig, params: dict, prompt, ctx: ModelCtx,
                    serve_cfg: ServeConfig):
    """Quarantine retry: re-serve ONE request solo on the degradation
    path — qdq impl (dequantize-then-dot on the packed leaves) + bf16 KV —
    with the NaN sentinel carried through prefill and decode.

    Returns ((budget,) int32 tokens, healthy bool). The fallback path
    avoids both fused kernels and the packed cache, so a fault rooted in
    packed payloads or kernel dispatch cannot recur; a still-unhealthy
    retry means the fault is upstream (weights/inputs) and the request is
    quarantined for good.
    """
    fb_quant = dataclasses.replace(ctx.quant, impl="qdq", kv=kvcache.KV_BF16)
    fb_ctx = dataclasses.replace(ctx, quant=fb_quant, plan=None)
    fb_serve = dataclasses.replace(serve_cfg, kv_format="bf16", kv_pages=0,
                                   guard=None)
    sctx = serving_ctx(fb_ctx)
    params = prepare_params_for_serving(params, cfg, fb_quant)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32).reshape(1, -1)}
    logits, cache = build_decode_cache(cfg, params, batch, sctx, fb_serve,
                                       quant=fb_quant)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    bad = guard_mod.bad_logits(logits)
    done = jnp.zeros(token.shape, bool)
    if fb_serve.eos_id is not None:
        done = done | (token == fb_serve.eos_id)
    out = [token[:, None]]
    budget = fb_serve.max_new_tokens - 1
    if budget > 0:
        gstep = _jit_decode_scan_guarded(cfg, sctx, budget, fb_serve.eos_id)
        toks, token, cache, done, flags = gstep(params, token, cache, done,
                                                bad)
        out.append(toks)
        bad = flags[:1]                    # B=1; meta part is zeros (bf16)
    toks = [int(t) for t in jax.device_get(jnp.concatenate(out, axis=1))[0]]
    healthy = not bool(jax.device_get(bad)[0])
    return (_finalize_result(toks, fb_serve.max_new_tokens, fb_serve.eos_id),
            healthy)


def _open_journal(serve_cfg: ServeConfig, requests, *, resume: bool,
                  kind: str, chunk: int, **geometry):
    """(journal, recovery plan) for a serve call — (None, None) without a
    ``journal_dir``. On resume the OLD journal is replayed into the plan
    first; the new journal then stages at ``.tmp``, records its start
    event plus a ``done`` event per already-completed request (so a
    second crash still recovers them without re-serving), and only then
    atomically replaces the old file."""
    if serve_cfg.journal_dir is None:
        if resume:
            raise guard_mod.RecoveryError(
                "resume=True needs serve_cfg.journal_dir pointing at the "
                "crashed serve's journal")
        return None, None
    from repro.runtime import journal as journal_mod

    plan = None
    if resume:
        plan = journal_mod.recover(
            serve_cfg.journal_dir, requests,
            budget=serve_cfg.max_new_tokens, eos=serve_cfg.eos_id)
    journal = journal_mod.RequestJournal(serve_cfg.journal_dir)
    journal.append(
        "start", v=journal_mod.JOURNAL_VERSION, kind=kind,
        n_requests=len(requests), budget=serve_cfg.max_new_tokens,
        eos=serve_cfg.eos_id, chunk=chunk,
        prompts=[journal_mod.prompt_sha256(r) for r in requests],
        **geometry)
    if plan is not None:
        for rid in sorted(plan.completed):
            ent = plan.completed[rid]
            journal.append("done", rid=rid, status=ent["status"],
                           detail=ent["detail"], retries=ent["retries"],
                           toks=ent["toks"])
    journal.activate()
    return journal, plan


def _inject_completed(plan, queue, results, reports):
    """Feed a recovery plan's journaled terminal results straight into the
    result/report tables — completed work is never re-served."""
    for rid in sorted(plan.completed):
        ent = plan.completed[rid]
        queue.remove(rid)
        results[rid] = jnp.asarray(ent["toks"], jnp.int32)
        reports[rid].update(status=ent["status"], detail=ent["detail"])
        reports[rid]["retries"] = ent["retries"]


def _verify_recovery(plan, results, reports) -> int:
    """Recovered state is checked, not trusted: every re-served request
    that finished cleanly must reproduce its journaled token prefix
    bitwise (greedy decode + per-token-deterministic packed bits make the
    replay exact by construction — a mismatch means recovery restored the
    wrong bytes). Returns the number of verified prefixes."""
    verified = 0
    for rid in sorted(plan.emitted):
        if rid in plan.completed or reports[rid]["status"] != "ok":
            continue
        exp = plan.expected_prefix(rid)
        if not exp:
            continue
        got = [int(t) for t in jax.device_get(results[rid])][: len(exp)]
        if got != exp:
            raise guard_mod.RecoveryError(
                f"request {rid}: re-served output {got} contradicts its "
                f"journaled token prefix {exp} — recovered state failed "
                "replay verification")
        verified += 1
    return verified


def serve_requests(
    cfg: ArchConfig,
    params: dict,
    requests: Sequence[jnp.ndarray],   # per-request prompt token arrays (T,)
    ctx: ModelCtx,
    serve_cfg: ServeConfig = ServeConfig(),
    *,
    slots: int = 4,
    stats: Optional[dict] = None,      # filled with scheduler counters
    injector=None,                     # repro.runtime.faults.FaultInjector
    resume: bool = False,              # recover from serve_cfg.journal_dir
) -> list:
    """Continuous-batching scheduler: serve ``requests`` through a fixed
    number of decode ``slots``.

    Each request is prefilled individually (its true prompt length — no
    cross-request padding) and admitted into a free slot with its own cache
    position; the shared decode batch advances via the scan body with
    per-slot positions and done masks. When a request exhausts its budget
    (or hits eos) its slot is freed and the next queued request admitted.
    Per-request results are bit-identical to serving each request alone:
    batch elements never mix, and invalid cache tail slots are masked by
    the per-slot length.

    With ``serve_cfg.kv_pages > 0`` (hif4 KV only) the whole-slot cache is
    replaced by the paged pool scheduler (:func:`_serve_requests_paged`):
    admission is by page availability instead of slot count, identical
    prompt-prefix pages are shared copy-on-write, and pool exhaustion
    preempts the youngest sequence instead of rejecting the queue — see the
    docs/EXECUTION.md admission matrix.

    Transformer families only (the per-slot position clock lives in the KV
    cache); returns a list of (max_new_tokens,) int32 arrays, one per
    request, in submission order.

    With ``serve_cfg.guard`` set (:class:`repro.runtime.guard.GuardConfig`)
    each request becomes its own fault domain: the decode scan carries the
    NaN/Inf sentinel, packed KV is audited per chunk, and a faulty slot is
    quarantined — evicted, retried once on the qdq/bf16 fallback path —
    while the rest of the batch continues bitwise-unaffected. Per-request
    outcomes land in ``stats["reports"]`` (status vocabulary in
    docs/EXECUTION.md §Failure semantics). ``injector`` is the
    fault-injection hook (:class:`repro.runtime.faults.FaultInjector`);
    tests and ``--inject-fault`` use it to prove every guard fires.

    With ``serve_cfg.journal_dir`` set, every request lifecycle event is
    written through a crc32-framed write-ahead journal (fsync-batched per
    decode chunk) and — on the paged scheduler — the pool is periodically
    checkpointed (``serve_cfg.checkpoint_every`` chunks). After a process
    crash, calling again with ``resume=True`` rebuilds state from
    checkpoint-plus-journal-tail (:mod:`repro.runtime.journal`): finished
    requests' results are injected, checkpoint-covered residents restore
    their page bytes, everything else re-prefills from its prompt — and
    the resumed greedy outputs are verified bitwise against the journaled
    token prefixes (docs/EXECUTION.md §Crash recovery).
    """
    assert cfg.family in ("dense", "vlm", "moe"), (
        f"continuous batching supports KV-cache families, got {cfg.family!r}"
    )
    sctx = serving_ctx(ctx)
    params = prepare_params_for_serving(params, cfg, ctx.plan or ctx.quant)
    warned: set = set()                # KVFallbackWarning dedup, per call
    kv_fmt = resolve_kv_format(cfg, ctx.quant, serve_cfg, verbose=True,
                               warned=warned)
    # Resolve the jitted entry points ONCE per serve call — admission runs
    # between every decode chunk, and a dict probe per admitted request
    # (plus the partial/jit wrapper construction on a miss) is avoidable
    # scheduler overhead.
    prefill = _jit_prefill(cfg, sctx)
    quantize = _jit_quantize_kv(cfg) if kv_fmt == "hif4" else None

    if serve_cfg.kv_pages:
        assert kv_fmt == "hif4", (
            "the paged KV pool stores packed HiF4 pages; bf16 serving (or a "
            "family fallback) must use the whole-slot scheduler")
        return _serve_requests_paged(
            cfg, params, requests, sctx, serve_cfg, ctx=ctx,
            slots=slots, prefill=prefill, quantize=quantize, stats=stats,
            injector=injector, resume=resume)

    guard = serve_cfg.guard
    budget = serve_cfg.max_new_tokens
    max_prompt = max(int(r.shape[-1]) for r in requests)
    cap = serve_cfg.cache_capacity or max_prompt + budget
    B = min(slots, len(requests))
    chunk = serve_cfg.decode_chunk or max(1, budget // 4)
    journal, plan = _open_journal(serve_cfg, requests, resume=resume,
                                  kind="slots", chunk=chunk)

    # Shared decode state: zero cache at full capacity, per-slot positions.
    cache = lm.init_cache(cfg, B, cap, kv_format=kv_fmt)
    cache["pos"] = jnp.zeros((B,), jnp.int32)
    token = jnp.zeros((B,), jnp.int32)
    done = jnp.ones((B,), bool)                  # empty slots count as done

    queue = list(range(len(requests)))
    slot_req = [None] * B                        # request id per slot
    slot_toks: list[list] = [[] for _ in range(B)]
    admit_time = [0.0] * B
    results: list = [None] * len(requests)
    reports = {rid: guard_mod.new_report() for rid in range(len(requests))}
    if plan is not None:
        _inject_completed(plan, queue, results, reports)
    max_concurrent = 0
    chunk_idx = 0

    def jlog_done(rid):
        if journal is not None:
            rep = reports[rid]
            journal.append("done", rid=rid, status=rep["status"],
                           detail=rep["detail"], retries=rep["retries"],
                           toks=[int(t) for t in jax.device_get(results[rid])])

    def admit(b: int, cache, token):
        rid = queue.pop(0)
        prompt = jnp.asarray(requests[rid], jnp.int32).reshape(1, -1)
        logits, slot_cache = prefill(params, {"tokens": prompt})
        if quantize is not None:
            slot_cache = quantize(slot_cache)
        slot_cache = lm.pad_cache(slot_cache, cfg, cap)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
        cache, token = _insert_slot_jit(cache, slot_cache, token, first, b)
        slot_req[b] = rid
        slot_toks[b] = [int(first)]
        admit_time[b] = time.monotonic()
        if journal is not None:
            journal.append("admitted", rid=rid, src="prefill",
                           toks=slot_toks[b])
        return cache, token

    guarded = guard is not None and guard.nan_sentinel
    if guarded:
        gstep = _jit_decode_scan_guarded(cfg, sctx, chunk, serve_cfg.eos_id)
        zeros_bad = jnp.zeros((B,), bool)     # fresh carry, hoisted: the
        #                                       scan never donates it
    else:
        step = _jit_decode_scan(cfg, sctx, chunk, serve_cfg.eos_id)

    def retire(b: int):
        rid = slot_req[b]
        results[rid] = _finalize_result(slot_toks[b], budget,
                                        serve_cfg.eos_id)
        slot_req[b] = None
        jlog_done(rid)

    def quarantine(b: int, reason: str):
        """Evict the poisoned slot only; its neighbours' state is
        untouched (batch rows never mix), so the rest of the batch
        continues bitwise-unaffected. The slot's cache region needs no
        scrub: admission overwrites the full capacity slab."""
        rid = slot_req[b]
        slot_req[b] = None
        slot_toks[b] = []
        if guard.retry_fallback:
            res, healthy = _retry_fallback(cfg, params, requests[rid], ctx,
                                           serve_cfg)
            reports[rid]["retries"] += 1
            if healthy:
                results[rid] = res
                reports[rid].update(
                    status="retried",
                    detail=f"{reason}; re-served solo on the qdq/bf16 "
                           "fallback path")
                jlog_done(rid)
                return
        results[rid] = _failed_result(budget, serve_cfg.eos_id)
        reports[rid].update(status="quarantined", detail=reason)
        jlog_done(rid)

    while queue or any(r is not None for r in slot_req):
        # Admission: fill every free slot before the next decode segment.
        for b in range(B):
            if slot_req[b] is None and queue:
                cache, token = admit(b, cache, token)
                done = done.at[b].set(
                    serve_cfg.eos_id is not None
                    and slot_toks[b][0] == serve_cfg.eos_id
                )
                if injector is not None:
                    injector.crash_point("after_admit", chunk_idx=chunk_idx,
                                         rid=slot_req[b], journal=journal)
        max_concurrent = max(max_concurrent,
                             sum(r is not None for r in slot_req))
        if injector is not None:
            cache["kv"] = injector.poison_cache(cache["kv"], slot_req,
                                                chunk_idx)
        active = jnp.asarray([r is not None for r in slot_req])
        metav = None
        if guarded:
            toks, token, cache, done, flags = gstep(
                params, token, cache, done | ~active, zeros_bad)
            host_toks, flagsv = jax.device_get((toks, flags))
            badv = flagsv[:B].astype(bool)
            if guard.meta_audit and kv_fmt == "hif4":
                metav = flagsv[B:]
        else:
            toks, token, cache, done = step(params, token, cache,
                                            done | ~active)
            badv = None
            if (guard is not None and guard.meta_audit
                    and kv_fmt == "hif4"):
                metav = jax.device_get(
                    guard_mod.slot_meta_nan_jit(cache["kv"]))
            host_toks = jax.device_get(toks)
        chunk_idx += 1
        if journal is not None:
            journal.append("chunk", idx=chunk_idx - 1, emitted={
                slot_req[b]: [int(t) for t in host_toks[b]]
                for b in range(B) if slot_req[b] is not None})
        for b in range(B):
            if slot_req[b] is None:
                continue
            reason = None
            if badv is not None and bool(badv[b]):
                reason = "nan_logits: non-finite logits in the decode scan"
            if metav is not None and int(metav[b]):
                reason = (f"meta_nan: {int(metav[b])} E6M2 NaN sentinel(s) "
                          "in the slot's packed KV")
            if reason is not None:
                done = done.at[b].set(True)
                quarantine(b, reason)
                continue
            slot_toks[b].extend(int(t) for t in host_toks[b])
            if (guard is not None and guard.deadline_s is not None
                    and time.monotonic() - admit_time[b] > guard.deadline_s):
                rid = slot_req[b]
                results[rid] = _finalize_partial(slot_toks[b], budget,
                                                 serve_cfg.eos_id)
                reports[rid].update(
                    status="timeout",
                    detail=f"deadline: exceeded {guard.deadline_s}s")
                slot_req[b] = None
                slot_toks[b] = []
                done = done.at[b].set(True)
                jlog_done(rid)
                continue
            finished = len(slot_toks[b]) >= budget or (
                serve_cfg.eos_id is not None
                and serve_cfg.eos_id in slot_toks[b]
            )
            if finished:
                retire(b)
        if journal is not None:
            journal.commit()
        if injector is not None:
            injector.crash_point("mid_decode", chunk_idx=chunk_idx - 1,
                                 journal=journal)
    if journal is not None:
        journal.close()
    if plan is not None:
        verified = _verify_recovery(plan, results, reports)
        if stats is not None:
            stats["recovery"] = dict(plan.report(), verified=verified)
    if stats is not None:
        stats.update(scheduler="slots", max_concurrent=max_concurrent,
                     preemptions=0, shared_page_hits=0, evictions=0,
                     reports=reports,
                     **_report_counts(reports))
    return results


def _report_counts(reports: dict) -> dict:
    counts = {status: 0 for status in guard_mod.STATUS_NAMES}
    for rep in reports.values():
        counts[rep["status"]] += 1
    return {"quarantined": counts["quarantined"],
            "retried": counts["retried"],
            "rejected": counts["rejected"],
            "timeouts": counts["timeout"]}


# ---------------------------------------------------------------------------
# Paged continuous batching: page-pool admission + COW prefix sharing
# ---------------------------------------------------------------------------


def _pool_gather(pool, ids):
    return {"k": kvcache.gather_pages(pool["k"], ids),
            "v": kvcache.gather_pages(pool["v"], ids)}


_pool_gather_jit = jax.jit(_pool_gather)


def _pool_scatter(pool, pages_k, pages_v, src, dst):
    """Write logical pages ``src`` of the (L, n, F, P) blocks into pool
    pages ``dst`` (K and V together, pool donated)."""

    def sel(t):
        return {key: jnp.take(a, src, axis=1) for key, a in t.items()}

    return {"k": kvcache.scatter_pages(pool["k"], sel(pages_k), dst),
            "v": kvcache.scatter_pages(pool["v"], sel(pages_v), dst)}


_pool_scatter_jit = jax.jit(_pool_scatter, donate_argnums=(0,))


def _pool_copy(pool, src, dst):
    return {"k": kvcache.copy_page(pool["k"], src, dst),
            "v": kvcache.copy_page(pool["v"], src, dst)}


_pool_copy_jit = jax.jit(_pool_copy, donate_argnums=(0,))


def _pool_scrub(pool, ids):
    """Zero the freed pages of a quarantined slot so stale corruption
    cannot leak into the page's next owner."""
    return {"k": kvcache.scrub_pages(pool["k"], ids),
            "v": kvcache.scrub_pages(pool["v"], ids)}


_pool_scrub_jit = jax.jit(_pool_scrub, donate_argnums=(0,))


def _page_prefix_equal(pool, pid, page_k, page_v, count):
    """True iff pool page ``pid`` matches the candidate page blocks
    (L, F, P) byte-for-byte on the first ``count`` token columns — the
    share-time verification that makes prefix sharing exact by
    construction rather than by trust in the hash."""
    cols = jnp.arange(page_k["meta"].shape[-1]) < count

    def eq(pool_t, page):
        oks = [jnp.all(jnp.where(cols, pool_t[key][:, pid] == page[key],
                                 True))
               for key in ("codes", "meta", "tail")]
        return jnp.all(jnp.stack(oks))

    return jnp.logical_and(eq(pool["k"], page_k), eq(pool["v"], page_v))


_page_equal_jit = jax.jit(_page_prefix_equal)


def _serve_requests_paged(
    cfg: ArchConfig,
    params: dict,
    requests: Sequence[jnp.ndarray],
    sctx: ModelCtx,
    serve_cfg: ServeConfig,
    *,
    ctx: ModelCtx,
    slots: int,
    prefill,
    quantize,
    stats: Optional[dict] = None,
    injector=None,
    resume: bool = False,
) -> list:
    """Page-pool continuous batching (the :func:`serve_requests` backend
    for ``serve_cfg.kv_pages > 0``).

    The whole-slot contiguous cache is replaced by a fixed pool of
    ``kv_pages`` HiF4 pages of ``kv_page_tokens`` tokens each
    (repro.core.kvcache); per-slot page tables map logical page indices to
    pool pages and the decode step streams KV tiles through the table
    (repro.kernels.fused_attention paged grid). Scheduling:

    * **admission** — a queued request is admitted when its PROMPT pages
      fit (prompt pages shared with resident requests do not count), not
      when a whole max-capacity slot is free: memory is committed
      page-by-page as sequences actually grow;
    * **prefix sharing** — prompt pages whose cumulative token key hits
      the full-page hash (or whose tail matches a live partial page) are
      shared by refcount after byte-for-byte verification; a sharer that
      must append into a shared page copies it first (copy-on-write), so
      sharing never changes any request's bytes;
    * **eviction / preemption** — retired requests' full pages park in an
      LRU cache (free prefix hits for followers) and are evicted when the
      pool runs dry; if the pool is dry with no evictable page, the
      YOUNGEST resident request is preempted: its page bytes are
      snapshotted to host, its pages freed, and it re-enters the queue
      front to be restored verbatim later (decode-token KV cannot be
      re-prefilled, so bytes — not tokens — are what's saved).

    Per-request outputs remain bit-identical to solo serving with the same
    page-size KV tiling: pages partition the token axis exactly like the
    kernel's KV tiles, appends land in exclusively-owned pages, and fully
    masked tiles are exact no-ops in the online softmax.

    **Fault domains.** Preemption snapshots always carry an integrity
    fingerprint, verified before re-admission ever scatters bytes back
    into the pool; a corrupt snapshot is dropped and the request re-queued
    from its prompt (greedy decode is deterministic, so the recomputed
    result is exact — status ``retried``). With ``serve_cfg.guard`` set,
    the scan carries the NaN sentinel, every chunk audits live pages
    (0xFF meta counts always; per-page byte-sum checksums against the
    values recorded after the previous chunk, skipping pages the
    scheduler legitimately wrote in between), faulty slots are
    quarantined with their freed pages scrubbed, and pool starvation
    becomes a bounded-retry ``rejected`` status instead of an exception.
    The one audit blind spot: corruption landing in a page during the
    same chunk the scheduler wrote it is invisible to the checksum until
    the next chunk — the 0xFF meta and NaN sentinels still cover it.
    """
    P = serve_cfg.kv_page_tokens
    budget = serve_cfg.max_new_tokens
    eos = serve_cfg.eos_id
    n_req = len(requests)
    prompts = [jax.device_get(jnp.asarray(r, jnp.int32)).ravel().tolist()
               for r in requests]
    max_prompt = max(len(p) for p in prompts)
    cap = serve_cfg.cache_capacity or max_prompt + budget
    for p_toks in prompts:
        assert len(p_toks) + budget <= cap, (
            f"prompt {len(p_toks)} + budget {budget} exceeds capacity {cap}")
    maxp = kvcache.pages_for_tokens(cap, P)
    pool = kvcache.PagePool(serve_cfg.kv_pages, P)
    assert maxp <= pool.usable_pages, (
        f"one max-length sequence needs {maxp} pages but the pool has only "
        f"{pool.usable_pages} usable (kv_pages={serve_cfg.kv_pages} minus "
        f"the scratch page)")
    B = min(slots, n_req)

    cache = lm.init_paged_cache(cfg, B, serve_cfg.kv_pages, P, maxp)
    token = jnp.zeros((B,), jnp.int32)
    done = jnp.ones((B,), bool)

    guard = serve_cfg.guard
    chunk = serve_cfg.decode_chunk or max(1, budget // 4)
    guarded = guard is not None and guard.nan_sentinel
    if guarded:
        gstep = _jit_decode_scan_guarded(cfg, sctx, chunk, eos)
        zeros_bad = jnp.zeros((B,), bool)     # fresh carry, hoisted: the
        #                                       scan never donates it
    else:
        step = _jit_decode_scan(cfg, sctx, chunk, eos)
    if injector is not None:
        injector.steal_pages(pool)

    journal, plan = _open_journal(
        serve_cfg, requests, resume=resume, kind="paged", chunk=chunk,
        kv_pages=serve_cfg.kv_pages, page_tokens=P)

    queue = list(range(n_req))
    suspended: dict = {}               # rid -> preemption byte snapshot
    slot_req = [None] * B
    slot_toks: list[list] = [[] for _ in range(B)]
    slot_written: list[list] = [[] for _ in range(B)]  # tokens whose KV is
    #                                                    resident, in order
    slot_pages: list[list] = [[] for _ in range(B)]    # pool ids, logical
    admit_clock = [0] * B
    admit_time = [0.0] * B
    results: list = [None] * n_req
    reports = {rid: guard_mod.new_report() for rid in range(n_req)}
    if plan is not None:
        _inject_completed(plan, queue, results, reports)
        for rid, snap in plan.suspended.items():
            # checkpointed residents re-enter through the preemption
            # snapshot path; written is derived from the scheduler
            # invariant written == prompt + toks[:-1]
            suspended[rid] = dict(
                snap, toks=list(snap["toks"]),
                written=prompts[rid] + list(snap["toks"])[:-1])

    def jlog_done(rid):
        if journal is not None:
            rep = reports[rid]
            journal.append("done", rid=rid, status=rep["status"],
                           detail=rep["detail"], retries=rep["retries"],
                           toks=[int(t) for t in jax.device_get(results[rid])])
    admission_attempts: dict = {}      # rid -> failed empty-pool admissions
    clock = 0
    preempt_count = 0
    max_concurrent = 0
    peak_live = 0
    snapshot_drops = 0
    chunk_idx = 0
    # Page-checksum audit state: ``recorded`` maps pool page id -> the
    # byte-sum observed after the last chunk; ``dirty`` collects pages the
    # scheduler itself wrote since then (admission scatters, COW copies,
    # horizon allocs, chunk appends) — those are re-recorded, not compared.
    recorded: dict = {}
    dirty: set = set()

    def set_table_row(b, pids):
        row = jnp.zeros((maxp,), jnp.int32)
        if pids:
            row = row.at[: len(pids)].set(jnp.asarray(pids, jnp.int32))
        cache["pages"] = cache["pages"].at[b].set(row)

    def refresh_metadata(b):
        """Index slot ``b``'s OWNED pages for sharing: completed pages by
        their cumulative token key, the live tail page in the partial
        registry. The last table entry (logical page maxp-1) is never
        indexed: over-emission inside a request's final chunk clamps into
        it (masked, discarded tokens), so its bytes are not trusted."""
        rid = slot_req[b]
        written = slot_written[b]
        for j, pid in enumerate(slot_pages[b]):
            if j == maxp - 1 or pool.owner.get(pid) != rid:
                continue
            seg = written[j * P:(j + 1) * P]
            if len(seg) == P:
                pool.register_full(pid, tuple(written[: (j + 1) * P]))
            elif seg:
                pool.register_partial(pid, tuple(written[: j * P]), seg)

    def pick_victim():
        live = [b for b in range(B) if slot_req[b] is not None]
        if not live:
            return None
        return max(live, key=lambda b: admit_clock[b])

    def preempt(b):
        nonlocal preempt_count
        rid = slot_req[b]
        ids = jnp.asarray(slot_pages[b], jnp.int32)
        snap = jax.device_get(_pool_gather_jit(cache["kv"], ids))
        # fingerprint BEFORE the injector hook: the stamp models the bytes
        # as they left the device; host-side corruption after that is what
        # re-admission must catch
        crc = guard_mod.snapshot_fingerprint(snap)
        if injector is not None:
            snap = injector.poison_snapshot(snap, rid)
        suspended[rid] = {
            "pages": snap,                      # page BYTES, not tokens
            "crc32": crc,
            "token": int(jax.device_get(token[b])),
            "toks": slot_toks[b],
            "written": slot_written[b],
        }
        for pid in slot_pages[b]:
            pool.release(pid)
        slot_pages[b] = []
        slot_req[b] = None
        slot_toks[b] = []
        slot_written[b] = []
        set_table_row(b, [])                    # writes -> scratch page 0
        queue.insert(0, rid)
        preempt_count += 1
        if journal is not None:
            # no replay state: the snapshot lives only in process memory
            journal.append("preempted", rid=rid)

    def alloc_page(rid, requester_slot):
        """Allocate, preempting youngest-first when the pool is dry.
        Returns None when the requester itself was the victim."""
        while True:
            pid = pool.alloc(owner=rid)
            if pid is not None:
                return pid
            victim = pick_victim()
            if victim is None:
                raise PoolExhaustedError(
                    f"KV page pool exhausted: {pool.usable_pages} usable "
                    f"pages cannot hold even one resident sequence")
            preempt(victim)
            if victim == requester_slot:
                return None

    def try_admit(b, rid):
        nonlocal token, done, clock, snapshot_drops
        snap = suspended.get(rid)
        if snap is not None and not guard_mod.verify_snapshot(snap):
            # a truncated/flipped snapshot must never reach the pool:
            # drop it and fall through to the fresh-prompt path — greedy
            # decode is deterministic, so recomputing from the prompt
            # reproduces the request's exact result
            del suspended[rid]
            snapshot_drops += 1
            reports[rid]["retries"] += 1
            reports[rid].update(
                status="retried",
                detail="snapshot_integrity: preemption snapshot failed its "
                       "fingerprint at re-admission; re-queued from the "
                       "prompt")
            snap = None
        if snap is not None:
            n = snap["pages"]["k"]["meta"].shape[1]
            if pool.available() < n:
                return False
            pids = [pool.alloc(owner=rid) for _ in range(n)]
            cache["kv"] = _pool_scatter_jit(
                cache["kv"], snap["pages"]["k"], snap["pages"]["v"],
                jnp.arange(n, dtype=jnp.int32),
                jnp.asarray(pids, jnp.int32))
            dirty.update(pids)
            del suspended[rid]
            token = token.at[b].set(snap["token"])
            cache["pos"] = cache["pos"].at[b].set(len(snap["written"]))
            done = done.at[b].set(False)
            slot_toks[b] = snap["toks"]
            slot_written[b] = snap["written"]
        else:
            toks = prompts[rid]
            n_tok = len(toks)
            logits, slot_cache = prefill(
                params, {"tokens": jnp.asarray(toks, jnp.int32).reshape(1, -1)})
            slot_cache = quantize(slot_cache)
            kp = kvcache.split_pages(slot_cache["kv"]["k"], P)
            vp = kvcache.split_pages(slot_cache["kv"]["v"], P)
            n_pg = kvcache.pages_for_tokens(n_tok, P)
            share = [None] * n_pg
            if serve_cfg.prefix_sharing:
                for j in range(n_pg):
                    seg = toks[j * P:(j + 1) * P]
                    if len(seg) == P:
                        cand = pool.lookup_full(tuple(toks[: (j + 1) * P]))
                    else:
                        cand = pool.lookup_partial(tuple(toks[: j * P]), seg)
                    if cand is None:
                        continue
                    page_k = {key: a[:, j] for key, a in kp.items()}
                    page_v = {key: a[:, j] for key, a in vp.items()}
                    if bool(jax.device_get(_page_equal_jit(
                            cache["kv"], cand, page_k, page_v, len(seg)))):
                        share[j] = cand
            n_new = sum(1 for s in share if s is None)
            n_revive = sum(1 for s in share
                           if s is not None and s in pool.cached)
            if pool.available() < n_new + n_revive:
                return False
            # retain every shared page BEFORE allocating: alloc may evict
            # from the LRU cache, and a not-yet-retained candidate must
            # not be its victim
            for s in share:
                if s is not None:
                    pool.retain(s)
                    pool.shared_hits += 1
            pids = []
            own_src, own_dst = [], []
            for j in range(n_pg):
                if share[j] is not None:
                    pids.append(share[j])
                else:
                    pid = pool.alloc(owner=rid)
                    own_src.append(j)
                    own_dst.append(pid)
                    pids.append(pid)
            if own_dst:
                cache["kv"] = _pool_scatter_jit(
                    cache["kv"], kp, vp,
                    jnp.asarray(own_src, jnp.int32),
                    jnp.asarray(own_dst, jnp.int32))
                dirty.update(own_dst)
            first = int(jax.device_get(jnp.argmax(logits, axis=-1))[0])
            token = token.at[b].set(first)
            cache["pos"] = cache["pos"].at[b].set(n_tok)
            done = done.at[b].set(eos is not None and first == eos)
            slot_toks[b] = [first]
            slot_written[b] = list(toks)
        slot_req[b] = rid
        slot_pages[b] = pids
        set_table_row(b, pids)
        clock += 1
        admit_clock[b] = clock
        admit_time[b] = time.monotonic()
        refresh_metadata(b)
        if journal is not None:
            # an admitted record RESETS the rid's journaled emission to
            # its cumulative toks — uniform for fresh prefills ([first]),
            # snapshot restores, and checkpoint-recovered residents
            journal.append("admitted", rid=rid,
                           src="snapshot" if snap is not None else "prefill",
                           toks=[int(t) for t in slot_toks[b]])
        return True

    def provision(b):
        """Pre-chunk page work for slot ``b``: copy-on-write the page its
        next append lands in if it is shared, then allocate pages through
        the chunk horizon. Returns False if ``b`` itself got preempted."""
        rid = slot_req[b]
        pos_b = len(slot_written[b])
        cur = pos_b // P
        if cur < len(slot_pages[b]):
            pid = slot_pages[b][cur]
            if pool.owner.get(pid) != rid:
                if pool.ref.get(pid, 0) > 1:
                    new = alloc_page(rid, b)
                    if new is None:
                        return False
                    cache["kv"] = _pool_copy_jit(cache["kv"], pid, new)
                    dirty.add(new)
                    pool.release(pid)
                    slot_pages[b][cur] = new
                    cache["pages"] = cache["pages"].at[b, cur].set(new)
                else:
                    pool.owner[pid] = rid      # sole holder adopts in place
        last = min((pos_b + chunk - 1) // P, maxp - 1)
        for j in range(len(slot_pages[b]), last + 1):
            pid = alloc_page(rid, b)
            if pid is None:
                return False
            dirty.add(pid)
            slot_pages[b].append(pid)
            cache["pages"] = cache["pages"].at[b, j].set(pid)
        return True

    def release_slot(b):
        for pid in slot_pages[b]:
            pool.release(pid)                  # hashed full pages park LRU
        slot_pages[b] = []
        slot_req[b] = None
        slot_toks[b] = []
        slot_written[b] = []
        set_table_row(b, [])

    def retire(b):
        rid = slot_req[b]
        results[rid] = _finalize_result(slot_toks[b], budget, eos)
        release_slot(b)
        jlog_done(rid)

    def quarantine(b, reason):
        """Evict the poisoned slot only: drop its pool refs, scrub the
        pages that actually freed (shared pages survive for their other
        holders, whose own audits will catch them if THEY are the
        corrupted bytes), and retry the request once on the qdq/bf16
        fallback path. Neighbouring slots' pages and scan state are
        untouched — they continue bitwise-unaffected."""
        nonlocal done
        rid = slot_req[b]
        freed = []
        for pid in slot_pages[b]:
            pool.release(pid, keep_cached=False)
            if pid not in pool.ref:
                freed.append(pid)
                recorded.pop(pid, None)
        if freed:
            cache["kv"] = _pool_scrub_jit(cache["kv"],
                                          jnp.asarray(freed, jnp.int32))
            dirty.update(freed)
        slot_pages[b] = []
        slot_req[b] = None
        slot_toks[b] = []
        slot_written[b] = []
        set_table_row(b, [])
        done = done.at[b].set(True)
        if guard.retry_fallback:
            res, healthy = _retry_fallback(cfg, params, requests[rid], ctx,
                                           serve_cfg)
            reports[rid]["retries"] += 1
            if healthy:
                results[rid] = res
                reports[rid].update(
                    status="retried",
                    detail=f"{reason}; re-served solo on the qdq/bf16 "
                           "fallback path")
                jlog_done(rid)
                return
        results[rid] = _failed_result(budget, eos)
        reports[rid].update(status="quarantined", detail=reason)
        jlog_done(rid)

    def reject(rid, detail):
        queue.remove(rid)
        suspended.pop(rid, None)
        results[rid] = _failed_result(budget, eos)
        reports[rid].update(status="rejected", detail=detail)
        jlog_done(rid)

    while queue or any(r is not None for r in slot_req):
        # Admission: FIFO, page-fit driven — stop at the first request
        # whose prompt pages do not fit (no skip-ahead; completion order
        # stays deterministic).
        while queue:
            free_b = next((b for b in range(B) if slot_req[b] is None), None)
            if free_b is None:
                break
            head = queue[0]
            if not try_admit(free_b, head):
                break
            queue.pop(0)
            if injector is not None:
                injector.crash_point("after_admit", chunk_idx=chunk_idx,
                                     rid=head, journal=journal)
        if not any(r is not None for r in slot_req):
            # nothing resident AND the queue head still does not fit: with
            # no guard this is fatal; with one it becomes bounded
            # retry+backoff and then a per-request ``rejected`` status
            rid = queue[0]
            msg = (f"request {rid!r} cannot be admitted into an empty "
                   f"pool ({pool.usable_pages} usable pages, "
                   f"{pool.available()} allocatable)")
            if guard is None:
                raise PoolExhaustedError(msg)
            attempts = admission_attempts.get(rid, 0) + 1
            admission_attempts[rid] = attempts
            if attempts <= guard.max_admission_retries:
                reports[rid]["retries"] += 1
                if guard.admission_backoff_s:
                    time.sleep(guard.admission_backoff_s
                               * 2 ** (attempts - 1))
                continue
            reject(rid, "pool_exhausted: " + msg + " after "
                   f"{attempts - 1} retries")
            continue
        for b in range(B):
            if slot_req[b] is not None:
                provision(b)
        # counted AFTER provisioning: sequences actually decoding this
        # chunk, not admissions that provisioning preempted right back out
        max_concurrent = max(max_concurrent,
                             sum(r is not None for r in slot_req))
        peak_live = max(peak_live, pool.live_pages())
        if injector is not None:
            cache["kv"] = injector.poison_pool(cache["kv"], pool, slot_req,
                                               slot_pages, chunk_idx)
        active = jnp.asarray([r is not None for r in slot_req])
        if guarded:
            toks, token, cache, done, flags = gstep(
                params, token, cache, done | ~active, zeros_bad)
            host_toks, flagsv = jax.device_get((toks, flags))
            badv = flagsv[:B].astype(bool)
            pagemeta = flagsv[B:]              # per-pool-page 0xFF counts
        else:
            toks, token, cache, done = step(params, token, cache,
                                            done | ~active)
            badv = pagemeta = None
            host_toks = jax.device_get(toks)
        chunk_idx += 1
        # 1) account this chunk's KV writes (and mark their pages dirty)
        chunk_emitted = {}
        for b in range(B):
            if slot_req[b] is None:
                continue
            new = [int(t) for t in host_toks[b]]
            chunk_emitted[slot_req[b]] = new
            # this chunk wrote KV for the previously pending token plus
            # every emission except the newest (still pending)
            pending = slot_toks[b][-1]
            n0 = len(slot_written[b])
            slot_written[b].extend([pending] + new[:-1])
            slot_toks[b].extend(new)
            n1 = len(slot_written[b])
            for j in range(n0 // P, (n1 - 1) // P + 1):
                # over-emission past the table clamps into the last entry
                dirty.add(slot_pages[b][min(j, len(slot_pages[b]) - 1)])
        if journal is not None:
            journal.append("chunk", idx=chunk_idx - 1, emitted=chunk_emitted)
        # 2) audit live pages BEFORE retiring anything, so a final-chunk
        #    fault cannot slip out with the request. The per-page 0xFF
        #    counts come fused out of the guarded scan; only the checksum
        #    audit needs a second (sums-only) reduction.
        faulty = {}
        if (guard is not None and guard.meta_audit and pagemeta is None):
            pagemeta = jax.device_get(
                guard_mod.slot_meta_nan_jit(cache["kv"]))
        sums = None
        if guard is not None and guard.page_checksums:
            sums = jax.device_get(
                guard_mod.pool_page_sums_jit(cache["kv"]))
        if guard is not None:
            for b in range(B):
                if slot_req[b] is None:
                    continue
                for pid in slot_pages[b]:
                    if (guard.meta_audit and pagemeta is not None
                            and int(pagemeta[pid])):
                        faulty[b] = (f"meta_nan: page {pid} carries "
                                     f"{int(pagemeta[pid])} E6M2 "
                                     "NaN sentinel(s)")
                        break
                    if (sums is not None and pid in recorded
                            and pid not in dirty
                            and int(sums[pid]) != recorded[pid]):
                        faulty[b] = (f"page_checksum: settled page {pid} "
                                     "changed outside the scheduler")
                        break
        for b in range(B):
            if slot_req[b] is not None and b not in faulty and badv is not None \
                    and bool(badv[b]):
                faulty[b] = "nan_logits: non-finite logits in the decode scan"
        for b, reason in faulty.items():
            quarantine(b, reason)
        # 3) re-record checksums for the pages still live, then settle
        if sums is not None:
            for b in range(B):
                if slot_req[b] is None:
                    continue
                for pid in slot_pages[b]:
                    recorded[pid] = int(sums[pid])
        dirty.clear()
        # 4) sharing metadata, deadlines, retirement
        for b in range(B):
            if slot_req[b] is None:
                continue
            refresh_metadata(b)
            if (guard is not None and guard.deadline_s is not None
                    and time.monotonic() - admit_time[b] > guard.deadline_s):
                rid = slot_req[b]
                results[rid] = _finalize_partial(slot_toks[b], budget, eos)
                reports[rid].update(
                    status="timeout",
                    detail=f"deadline: exceeded {guard.deadline_s}s")
                release_slot(b)
                done = done.at[b].set(True)
                jlog_done(rid)
                continue
            finished = len(slot_toks[b]) >= budget or (
                eos is not None and eos in slot_toks[b])
            if finished:
                retire(b)
        # 5) durability: periodic pool checkpoint, then ONE fsync for the
        #    whole chunk's records
        if journal is not None:
            if (serve_cfg.checkpoint_every > 0
                    and chunk_idx % serve_cfg.checkpoint_every == 0
                    and any(r is not None for r in slot_req)):
                from repro.runtime import journal as journal_mod
                residents = {}
                for b in range(B):
                    rid = slot_req[b]
                    if rid is None:
                        continue
                    ids = jnp.asarray(slot_pages[b], jnp.int32)
                    residents[rid] = {
                        "pages": jax.device_get(
                            _pool_gather_jit(cache["kv"], ids)),
                        "token": int(jax.device_get(token[b])),
                        "toks": [int(t) for t in slot_toks[b]],
                    }
                fname, digest = journal_mod.save_pool_checkpoint(
                    serve_cfg.journal_dir, chunk_idx, residents)
                if injector is not None:
                    # the .npz is on disk but its journal record is not:
                    # crash_during_checkpoint leaves an orphan recovery
                    # must ignore
                    injector.crash_point("during_checkpoint",
                                         chunk_idx=chunk_idx - 1,
                                         journal=journal)
                journal.append(
                    "checkpoint", chunk=chunk_idx, file=fname, sha256=digest,
                    residents={rid: {"token": ent["token"],
                                     "toks": ent["toks"]}
                               for rid, ent in residents.items()})
            journal.commit()
        if injector is not None:
            injector.crash_point("mid_decode", chunk_idx=chunk_idx - 1,
                                 journal=journal)
    if journal is not None:
        journal.close()
    holders = {f"slot{b}": slot_pages[b] for b in range(B) if slot_pages[b]}
    if injector is not None and injector.held_pages:
        holders["__fault_injector__"] = list(injector.held_pages)
    audit = pool.audit(holders=holders)
    if plan is not None:
        verified = _verify_recovery(plan, results, reports)
        if stats is not None:
            stats["recovery"] = dict(plan.report(), verified=verified)
    if stats is not None:
        stats.update(
            scheduler="paged", max_concurrent=max_concurrent,
            preemptions=preempt_count, evictions=pool.evictions,
            shared_page_hits=pool.shared_hits,
            pages_total=serve_cfg.kv_pages, page_tokens=P,
            peak_live_pages=peak_live,
            pool_bytes=serve_cfg.kv_pages * kvcache.page_nbytes(
                cfg.attn.n_kv_heads, cfg.attn.d_head, P, cfg.n_layers),
            snapshot_drops=snapshot_drops, pool_audit=audit,
            reports=reports,
            **_report_counts(reports))
    return results
