"""Fault-tolerant training loop.

* resumes from the latest valid checkpoint (params + opt state + data-
  iterator state), bit-deterministically — kill the process anywhere and
  the restarted run produces the same trajectory (tested);
* async checkpoints (serialization overlaps compute);
* step-time straggler monitor: flags steps slower than ``straggler_factor``
  x the trailing median — on real fleets this feeds the reschedule signal;
* optional HiF4-compressed data-parallel gradient all-reduce (beyond-paper,
  see optim/grad_compress.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, load_checkpoint
from repro.configs.base import ArchConfig
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.models.common import ModelCtx
from repro.models.params import shardings_from_specs
from repro.optim.adamw import AdamWConfig, adamw_init_specs
from repro.models.params import init_from_specs


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    log_every: int = 10
    straggler_factor: float = 3.0
    num_microbatches: int = 1
    seed: int = 0
    data_noise: float = 0.05


def train(
    cfg: ArchConfig,
    ctx: ModelCtx,
    loop: TrainLoopConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    on_step: Optional[Callable[[int, dict], None]] = None,
):
    """Returns (params, opt_state, history dict)."""
    opt_cfg = opt_cfg or AdamWConfig(
        lr=1e-3, total_steps=loop.steps,
        warmup_steps=max(1, loop.steps // 10),
    )
    data = SyntheticLMDataset(cfg.vocab, loop.seq_len, loop.global_batch,
                              seed=loop.seed, noise=loop.data_noise)

    pspecs = lm.abstract_params(cfg)
    ospecs = adamw_init_specs(pspecs)
    p_shard = shardings_from_specs(pspecs, ctx.shard)
    o_shard = shardings_from_specs(ospecs, ctx.shard)

    start_step = 0
    params = opt_state = None
    if loop.checkpoint_dir:
        s = latest_step(loop.checkpoint_dir)
        if s is not None:
            target = jax.eval_shape(
                lambda: (
                    init_from_specs(pspecs, jax.random.PRNGKey(0)),
                    init_from_specs(ospecs, jax.random.PRNGKey(0)),
                )
            )
            (params, opt_state), extra = load_checkpoint(
                loop.checkpoint_dir, s, target,
                shardings=(p_shard, o_shard) if ctx.shard.mesh is not None else None,
            )
            data.load_state_dict(extra["data"])
            start_step = int(extra["step"])
    if params is None:
        params = init_from_specs(pspecs, jax.random.PRNGKey(loop.seed))
        opt_state = init_from_specs(ospecs, jax.random.PRNGKey(0))

    step_fn = jax.jit(
        make_train_step(cfg, ctx, opt_cfg,
                        num_microbatches=loop.num_microbatches),
        donate_argnums=(0, 1),
    )

    mgr = CheckpointManager(loop.checkpoint_dir) if loop.checkpoint_dir else None
    history = {"loss": [], "step_time": [], "stragglers": []}
    times: list[float] = []

    for step in range(start_step, loop.steps):
        batch = data.batch_at(step)
        data.step = step + 1
        t0 = time.time()
        params, opt_state, stats = step_fn(params, opt_state, batch)
        loss = float(stats["loss"])
        dt = time.time() - t0
        times.append(dt)
        history["loss"].append(loss)
        history["step_time"].append(dt)
        # straggler detection against the trailing median
        if len(times) >= 5:
            med = float(np.median(times[-20:]))
            if dt > loop.straggler_factor * med:
                history["stragglers"].append(step)
        if on_step:
            on_step(step, {"loss": loss, "time": dt})
        if mgr and (step + 1) % loop.checkpoint_every == 0:
            mgr.save_async(step + 1, (params, opt_state),
                           {"step": step + 1, "data": data.state_dict()})
    if mgr:
        mgr.save_async(loop.steps, (params, opt_state),
                       {"step": loop.steps, "data": data.state_dict()})
        mgr.wait()
    return params, opt_state, history
