from repro.sharding.rules import NO_SHARD, ShardCtx  # noqa: F401
