"""Logical-axis sharding rules with divisibility fallback.

Models annotate tensors with *logical* axis names ("batch", "heads",
"ff", ...). A :class:`ShardCtx` resolves them against a concrete mesh:
any logical axis whose dimension does not divide the product of its mesh
axes is replicated instead (dropped from the spec). This is what lets the
same model code lower on a 1-device CPU (everything replicated), a 256-chip
pod, and a 512-chip multi-pod mesh without per-arch special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes it shards over (in order)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),       # data parallel
    "seq": (),                      # unsharded by default (SP optional)
    "act_seq": ("model",),          # Megatron-style SP: residual-stream seq
    #                                 at layer boundaries shards over TP axis
    #                                 so remat-saved activations fit HBM
    "kv_seq": ("model",),           # context-parallel KV cache (decode)
    "embed": (),                    # d_model replicated by default
    "heads": ("model",),            # TP over attention heads
    "attn_q_chunks": ("model",),    # vec_q flash: q-chunk axis over TP when
    #                                 heads don't divide the model axis
    "kv_heads": ("model",),         # TP over KV heads
    "ff": ("model",),               # TP over FFN hidden
    "experts": ("model",),          # EP over experts
    "vocab": ("model",),            # TP over vocab for embed/head
    "ssm_inner": ("model",),        # TP over mamba d_inner
    "layers": (),                   # stacked-layer axis never sharded
    "opt_shard": ("pod", "data"),   # ZeRO-1 axis for optimizer state
    # FSDP weight sharding (large models): shard the non-TP weight axis
    # (usually d_model) over the DP axes; XLA inserts per-layer all-gathers.
    "fsdp": ("data",),
}


@dataclasses.dataclass
class ShardCtx:
    """Resolves logical specs against a mesh; None mesh = no-op (CPU tests)."""

    mesh: Optional[Mesh] = None
    rules: dict[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def with_rules(self, **overrides: tuple[str, ...]) -> "ShardCtx":
        r = dict(self.rules)
        r.update(overrides)
        return ShardCtx(mesh=self.mesh, rules=r)

    # -- resolution ---------------------------------------------------------

    def _axes_for(self, logical: Optional[str], dim: int) -> Optional[tuple[str, ...]]:
        if logical is None or self.mesh is None:
            return None
        mesh_axes = tuple(
            a for a in self.rules.get(logical, ()) if a in self.mesh.shape
        )
        if not mesh_axes:
            return None
        total = 1
        for a in mesh_axes:
            total *= self.mesh.shape[a]
        if dim % total != 0:
            # divisibility fallback: try a prefix of the axes, else replicate
            for cut in range(len(mesh_axes) - 1, 0, -1):
                sub = mesh_axes[:cut]
                t = 1
                for a in sub:
                    t *= self.mesh.shape[a]
                if dim % t == 0:
                    return sub
            return None
        return mesh_axes

    def pspec(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set[str] = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self._axes_for(name, dim)
            if axes is None or any(a in used for a in axes):
                parts.append(None)
            else:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(
        self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]
    ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(logical_axes, shape))

    # -- in-graph constraint -------------------------------------------------

    def constrain(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        """with_sharding_constraint on a traced value (no-op without mesh)."""
        if self.mesh is None:
            return x
        s = self.sharding(logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(x, s)


NO_SHARD = ShardCtx(mesh=None)
