"""Per-architecture smoke tests: reduced configs, one train loss + a short
prefill->decode roll on CPU. Asserts output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.core.qlinear import QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx

B, S = 2, 64
CTX = ModelCtx(quant=QuantConfig(fmt="hif4"), remat=False,
               attn_q_chunk=32, attn_k_chunk=32)
CTX_NOQ = ModelCtx(remat=False, attn_q_chunk=32, attn_k_chunk=32)


def _train_batch(cfg, key):
    kt, ke = jax.random.split(key)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(ke, (B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        }
    if cfg.embeds_input:
        return {
            "embeds": jax.random.normal(ke, (B, S, cfg.d_model), jnp.bfloat16),
            "labels": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        }
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab)}


def _prefill_batch(cfg, key):
    b = _train_batch(cfg, key)
    b.pop("labels", None)
    if cfg.family == "audio":
        b.pop("tokens", None)
    return b


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_arch(name).reduced()
            params = lm.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", all_archs())
def test_train_loss(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _train_batch(cfg, jax.random.PRNGKey(1))
    loss = jax.jit(lambda p, b: lm.train_loss(p, b, cfg, CTX))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # quantized loss should be close to (not wildly off from) the bf16 loss
    loss_bf16 = jax.jit(lambda p, b: lm.train_loss(p, b, cfg, CTX_NOQ))(
        params, batch
    )
    assert abs(float(loss) - float(loss_bf16)) < 1.0, (
        f"{arch}: hif4 {loss} vs bf16 {loss_bf16}"
    )


@pytest.mark.parametrize("arch", all_archs())
def test_train_grads_finite(arch, arch_setup):
    """Gradients must be finite and NONZERO with quantization enabled —
    regression guard for the round()-has-zero-grad STE bug that silently
    DCE'd the whole backward pass."""
    cfg, params = arch_setup(arch)
    batch = _train_batch(cfg, jax.random.PRNGKey(2))
    grads = jax.jit(jax.grad(lambda p: lm.train_loss(p, batch, cfg, CTX)))(
        params
    )
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    # at least 90% of param tensors receive nonzero gradient signal
    nz = [float(jnp.max(jnp.abs(g))) > 0 for g in flat]
    assert sum(nz) >= 0.9 * len(nz), f"{arch}: {sum(nz)}/{len(nz)} nonzero"


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_decode(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _prefill_batch(cfg, jax.random.PRNGKey(3))
    logits, cache = jax.jit(lambda p, b: lm.prefill(p, b, cfg, CTX))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    cache = lm.pad_cache(cache, cfg, S + 8)
    step = jax.jit(lambda p, t, c: lm.decode_step(p, t, c, cfg, CTX))
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = step(params, token, cache)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_decode_matches_prefill_dense():
    """Teacher-forced decode must reproduce full-forward logits (bf16 tol).

    This is the strongest correctness property of the cache path: running
    the same tokens through prefill+decode and through one full forward
    must agree position by position.
    """
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    ctx = CTX_NOQ
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, 16), 0, cfg.vocab)

    # full forward: logits for every position
    x = lm.embed_tokens(params, tokens, cfg, ctx)
    h, _ = lm._backbone(params, x, cfg, ctx, mode="train")
    full_logits = lm.lm_logits(params, h, cfg, ctx)          # (B, 16, V)

    # prefill on the first 8, then teacher-forced decode of the rest
    logits, cache = lm.prefill(params, {"tokens": tokens[:, :8]}, cfg, ctx)
    cache = lm.pad_cache(cache, cfg, 16)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 7]), rtol=0.05, atol=0.05
    )
    for t in range(8, 16):
        logits, cache = lm.decode_step(params, tokens[:, t], cache, cfg, ctx)
        if t < 15:
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full_logits[:, t]),
                rtol=0.05, atol=0.05,
            )


def test_decode_matches_prefill_ssm():
    """Same teacher-forcing property for the recurrent (Mamba2) path."""
    cfg = get_arch("mamba2-1.3b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    ctx = CTX_NOQ
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, 64), 0, cfg.vocab)

    x = lm.embed_tokens(params, tokens, cfg, ctx)
    h, _ = lm._backbone(params, x, cfg, ctx, mode="train")
    full_logits = lm.lm_logits(params, h, cfg, ctx)

    logits, cache = lm.prefill(params, {"tokens": tokens[:, :32]}, cfg, ctx)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 31]), rtol=0.06, atol=0.06
    )
    for t in range(32, 40):
        logits, cache = lm.decode_step(params, tokens[:, t], cache, cfg, ctx)
        if t < 63:
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full_logits[:, t]),
                rtol=0.06, atol=0.06,
            )


def test_vec_q_model_equivalence():
    """The vec_q attention path (§Perf iteration 1) must produce the same
    loss as scan_q — it's a scheduling/sharding change, not a math change."""
    import dataclasses

    cfg = get_arch("qwen1.5-4b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(9), (B, S), 0,
                                          cfg.vocab)}
    l_scan = lm.train_loss(params, batch, cfg, CTX_NOQ)
    ctx_vec = dataclasses.replace(CTX_NOQ, attn_impl="vec_q")
    l_vec = lm.train_loss(params, batch, cfg, ctx_vec)
    np.testing.assert_allclose(float(l_scan), float(l_vec), rtol=2e-3)

    g_scan = jax.grad(lambda p: lm.train_loss(p, batch, cfg, CTX_NOQ))(params)
    g_vec = jax.grad(lambda p: lm.train_loss(p, batch, cfg, ctx_vec))(params)
    n_scan = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                 for x in jax.tree_util.tree_leaves(g_scan)) ** 0.5
    n_vec = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                for x in jax.tree_util.tree_leaves(g_vec)) ** 0.5
    np.testing.assert_allclose(n_scan, n_vec, rtol=5e-3)
