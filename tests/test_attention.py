"""Flash attention: forward vs naive softmax oracle, custom-VJP gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    AttnChunking,
    decode_attention,
    flash_attention,
    flash_mha,
    flash_mha_vec,
)


def naive_attention(q, k, v, causal=True):
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D) / (D ** 0.5)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


CHUNKS = AttnChunking(q_chunk=16, k_chunk=32)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_forward_matches_naive(causal, hkv):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 2, 64, 4, 16
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, hkv, D)
    v = _rand(keys[2], B, S, hkv, D)
    got = flash_attention(q, k, v, causal=causal, chunking=CHUNKS)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_grads_match_naive(causal):
    """The custom VJP must match autodiff-of-naive to numerical tolerance."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, Hkv, D)
    v = _rand(keys[2], B, S, Hkv, D)

    def loss_flash(q, k, v):
        o = flash_mha(q, k, v, causal, 0, CHUNKS)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_naive(q, k, v):
        o = naive_attention(q, k, v, causal=causal)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_naive = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_flash, g_naive, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=3e-5,
            err_msg=f"d{name} mismatch (causal={causal})",
        )


def test_grads_match_uneven_chunks():
    """Chunk shapes that don't align q and kv chunk boundaries."""
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, Hkv, D = 1, 96, 2, 1, 8
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, Hkv, D)
    v = _rand(keys[2], B, S, Hkv, D)
    ch = AttnChunking(q_chunk=32, k_chunk=48)

    def f(fn):
        def loss(q, k, v):
            return jnp.sum(fn(q, k, v) ** 2)
        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    g1 = f(lambda q, k, v: flash_mha(q, k, v, True, 0, ch))
    g2 = f(lambda q, k, v: naive_attention(q, k, v, causal=True))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_vec_q_forward_matches_naive(causal):
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, Hkv, D)
    v = _rand(keys[2], B, S, Hkv, D)
    got = flash_mha_vec(q, k, v, causal, 0, CHUNKS)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_vec_q_grads_match_naive(causal):
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, Hkv, D)
    v = _rand(keys[2], B, S, Hkv, D)

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(jnp.sin(fn(q, k, v).astype(jnp.float32)))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g_vec = loss(lambda q, k, v: flash_mha_vec(q, k, v, causal, 0, CHUNKS))
    g_naive = loss(lambda q, k, v: naive_attention(q, k, v, causal=causal))
    for a, b, name in zip(g_vec, g_naive, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=3e-5,
            err_msg=f"vec_q d{name} (causal={causal})",
        )


def test_decode_matches_full():
    """decode_attention over a cache == last row of full attention."""
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, Hkv, D = 2, 32, 4, 2, 16
    q = _rand(keys[0], B, S, H, D)
    k = _rand(keys[1], B, S, Hkv, D)
    v = _rand(keys[2], B, S, Hkv, D)
    full = naive_attention(q, k, v, causal=True)
    got = decode_attention(q[:, -1], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1]), atol=2e-5)
