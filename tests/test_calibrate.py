"""Calibration subsystem: frontier-search properties + probe/emit e2e.

Three layers of pinning:

* pure search (no model): hypothesis property tests — raising the byte
  target never increases total error and never decreases total bytes
  (the greedy applies a PREFIX of one fixed move order), hull dominance,
  budget semantics, and ``assignment_cost`` agreement;
* policy JSON strictness: ``QuantPolicy.from_json_dict`` rejects unknown
  top-level and rule keys loudly (a typo'd key must never silently yield
  the default policy), and provenance survives the round-trip;
* probe + emit e2e (slow): one real calibration run on the reduced dense
  arch — tap capture through the real forward, searched policy emitted,
  reloaded via ``get_policy``, resolved, and served through a prefill +
  decode step with packed weights.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.calibrate.search import (
    FormatOption,
    SiteScore,
    _hull,
    assignment_cost,
    frontier_search,
)
from repro.core.policy import QuantPolicy, QuantRule, get_policy

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:          # property tests skip; the rest still run
    hypothesis = st = None


# ---------------------------------------------------------------------------
# search: property tests (satellite: frontier monotonicity)
# ---------------------------------------------------------------------------

FMTS = ("bf16", "hif4", "nvfp4", "mxfp4", "int8")
BPV = {"bf16": 2.0, "int8": 1.0, "nvfp4": 0.75, "mxfp4": 0.75,
       "hif4": 0.5625}

if hypothesis is not None:
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=60, derandomize=True)
    hypothesis.settings.load_profile("ci")

    @st.composite
    def site_tables(draw):
        n_sites = draw(st.integers(min_value=1, max_value=6))
        sites = []
        for i in range(n_sites):
            fmts = draw(st.sets(st.sampled_from(FMTS), min_size=1,
                                max_size=5))
            opts = tuple(
                FormatOption(f, BPV[f],
                             draw(st.floats(min_value=0.0, max_value=10.0,
                                            allow_nan=False)))
                for f in sorted(fmts))
            sites.append(SiteScore(
                path=f"site{i}",
                n_values=draw(st.integers(min_value=64, max_value=8192)),
                options=opts))
        return sites

    @hypothesis.given(site_tables(),
                      st.floats(min_value=0.4, max_value=2.2),
                      st.floats(min_value=0.0, max_value=0.8))
    def test_frontier_monotone_in_target(sites, t_lo, dt):
        """Raising --target-bpv never increases error nor shrinks bytes."""
        lo = frontier_search(sites, t_lo)
        hi = frontier_search(sites, t_lo + dt)
        assert hi.total_error <= lo.total_error + 1e-9
        assert hi.total_bytes >= lo.total_bytes - 1e-9

    @hypothesis.given(site_tables(), st.floats(min_value=0.4, max_value=2.2))
    def test_frontier_internal_consistency(sites, target):
        """Totals match the assignment, budget semantics hold, and the
        curve is monotone (bytes strictly down, error up)."""
        r = frontier_search(sites, target)
        b, e = assignment_cost(sites, r.assignment)
        assert abs(b - r.total_bytes) < 1e-6
        assert abs(e - r.total_error) < 1e-6
        n_total = sum(s.n_values for s in sites)
        if r.feasible:
            assert r.total_bytes <= target * n_total + 1e-6
        else:
            # infeasible = even the cheapest point exceeds the budget: the
            # returned assignment IS the cheapest (last curve point)
            assert abs(r.total_bytes - r.curve[-1]["total_bytes"]) < 1e-6
        for a, c in zip(r.curve, r.curve[1:]):
            assert c["total_bytes"] < a["total_bytes"]
            assert c["total_error"] >= a["total_error"] - 1e-9


def test_hull_dominance():
    h = _hull([
        FormatOption("bf16", 2.0, 0.0),
        FormatOption("worse-same-bytes", 2.0, 1.0),     # dominated
        FormatOption("bigger-and-worse", 3.0, 0.5),     # dominated
        FormatOption("hif4", 0.5625, 0.3),
        FormatOption("concave", 1.0, 0.29),             # off the hull
    ])
    assert [o.fmt for o in h] == ["bf16", "hif4"]
    # ratios non-decreasing as bytes shrink
    for a, b in zip(h, h[1:]):
        assert b.bytes_per_value < a.bytes_per_value
        assert b.error > a.error


def test_greedy_stops_at_budget():
    sites = [
        SiteScore("a", 1000, (FormatOption("bf16", 2.0, 0.0),
                              FormatOption("hif4", 0.5625, 1.0))),
        SiteScore("b", 1000, (FormatOption("bf16", 2.0, 0.0),
                              FormatOption("hif4", 0.5625, 5.0))),
    ]
    # budget allows quantizing only one site: the cheaper-error one moves
    r = frontier_search(sites, 1.3)
    assert r.feasible
    assert r.assignment == {"a": "hif4", "b": "bf16"}
    # full curve still walks both moves
    assert len(r.curve) == 3
    # generous budget: nothing moves
    r2 = frontier_search(sites, 2.0)
    assert r2.assignment == {"a": "bf16", "b": "bf16"}
    assert r2.total_error == 0.0


def test_assignment_cost_unknown_fmt_falls_back():
    s = SiteScore("a", 100, (FormatOption("bf16", 2.0, 0.5),
                             FormatOption("hif4", 0.5625, 1.0)))
    b, e = assignment_cost([s], {"a": "int8"})     # not offered
    assert (b, e) == (200.0, 0.5 * 100)            # min-error option


# ---------------------------------------------------------------------------
# policy JSON strictness (satellite: from_json_dict rejects unknown keys)
# ---------------------------------------------------------------------------

def test_from_json_dict_rejects_unknown_top_level_key():
    d = {"name": "x", "ruels": [{"pattern": "*", "fmt": "hif4"}]}
    with pytest.raises(ValueError, match="ruels"):
        QuantPolicy.from_json_dict(d)


def test_from_json_dict_rejects_unknown_rule_key():
    d = {"rules": [{"pattern": "*", "fmt": "hif4", "weights_onyl": True}]}
    with pytest.raises(ValueError, match="weights_onyl"):
        QuantPolicy.from_json_dict(d)


def test_from_json_dict_accepts_all_known_keys_and_roundtrips():
    pol = QuantPolicy(
        rules=(QuantRule("*", fmt="none"),
               QuantRule("blocks.mlp.wg", fmt="hif4", weights_only=True)),
        name="rt").with_provenance({"tool": "test", "n": 1})
    d = pol.to_json_dict()
    back = QuantPolicy.from_json_dict(json.loads(json.dumps(d)))
    assert back.rules == pol.rules
    assert back.name == "rt"
    assert back.provenance_dict() == {"tool": "test", "n": 1}


def test_get_policy_file_rejects_typo_key(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"rules": [], "kv_fromat": "hif4"}))
    with pytest.raises(ValueError, match="kv_fromat"):
        get_policy(str(p))


# ---------------------------------------------------------------------------
# emit: assignment -> policy file -> get_policy -> resolved plan
# ---------------------------------------------------------------------------

def test_emit_policy_roundtrip_resolves_to_assignment(tmp_path):
    from repro.calibrate.emit import emit_policy
    from repro.configs import get_arch
    from repro.models import lm

    cfg = get_arch("qwen1.5-0.5b").reduced()
    assignment = {"blocks.attn.wq": "bf16", "blocks.attn.wk": "hif4",
                  "blocks.attn.wv": "hif4", "blocks.attn.wo": "bf16",
                  "blocks.mlp.wg": "hif4", "blocks.mlp.wu": "bf16",
                  "blocks.mlp.wo": "hif4"}
    out = str(tmp_path / "policy.json")
    emit_policy(assignment, name="t", kv_format="hif4",
                provenance={"tool": "test"}, out=out)
    pol = get_policy(out, impl="packed")
    assert pol.provenance_dict()["tool"] == "test"
    assert pol.kv.kv_format == "hif4"
    plan = lm.quant_plan(cfg, pol)
    want_packed = {p for p, f in assignment.items() if f == "hif4"}
    assert plan.packed_paths == frozenset(want_packed)
    for path, fmt in assignment.items():
        got = plan.at(path).fmt
        assert got == ("none" if fmt == "bf16" else fmt), (path, got)


# ---------------------------------------------------------------------------
# probe + calibrate e2e on the real model (slow)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def calib(tmp_path_factory):
    from repro.calibrate import calibrate

    d = tmp_path_factory.mktemp("calib")
    out = str(d / "searched.json")
    report = str(d / "report.json")
    summary = calibrate("qwen1.5-0.5b", target_bpv=0.7, out=out,
                        report_out=report, log=lambda *_: None)
    return summary, out, report


@pytest.mark.slow
def test_probe_tap_captures_all_matmul_sites():
    from repro.calibrate.probe import probe_sites
    from repro.configs import get_arch

    cfg = get_arch("qwen1.5-0.5b").reduced()
    res = probe_sites(cfg, n_batches=1, batch=1, seq_len=32,
                      log=lambda *_: None)
    by_path = {r["path"]: r for r in res.rows}
    # every body matmul site scored with real activations; embed (a
    # gather, never consumed by the engine funnel) excluded from both
    # capture and budget; tied lm_head captured but out of budget
    body = {"blocks.attn.wq", "blocks.attn.wk", "blocks.attn.wv",
            "blocks.attn.wo", "blocks.mlp.wg", "blocks.mlp.wu",
            "blocks.mlp.wo"}
    for p in body:
        assert by_path[p]["captured"] and by_path[p]["in_budget"]
        errs = by_path[p]["errors"]
        assert errs["hif4"] > 0 and errs["bf16"] == 0.0
        # HiGPTQ rounding must not be WORSE than direct-cast on the
        # calibration set it optimizes (allow float-mean slack)
        assert errs["hif4"] <= errs["hif4_direct"] * 1.25
    assert not by_path["embed"]["in_budget"]
    assert not by_path["embed"]["captured"]
    assert by_path["lm_head"]["captured"]
    assert not by_path["lm_head"]["in_budget"]      # tied: no tensor
    assert res.n_calib_rows > 0


@pytest.mark.slow
def test_calibrate_emits_within_budget_and_beats_fallback(calib):
    summary, out, report_path = calib
    assert summary["feasible"]
    # (b) budget met as measured by the resolved plan's packed residency
    assert summary["achieved_bpv"] <= 0.7
    # (c) frontier claim on the same calibration set: at the fallback's
    # byte budget the searched assignment's error is <= the preset's
    # (checked properly by the matrix gate; here: the baseline entry
    # exists and the searched-at-equal-bytes run is reproducible)
    fb = summary["baselines"]["sensitive-fallback"]
    assert fb["total_bytes"] > summary["total_bytes"]    # 0.7 < 0.99375
    rep = json.load(open(report_path))
    assert rep["search"]["assignment"] == summary["assignment"]
    assert len(rep["pareto_curve"]) >= 2
    # curve bytes strictly decreasing, error non-decreasing
    curve = rep["pareto_curve"]
    assert all(b["total_bytes"] < a["total_bytes"]
               for a, b in zip(curve, curve[1:]))


@pytest.mark.slow
def test_calibrate_at_fallback_budget_pareto_dominates(calib):
    """The acceptance comparison: search AT the fallback preset's byte
    residency -> <= its bytes and <= its error on the same score table."""
    from repro.calibrate.search import frontier_search
    _, _, report_path = calib
    rep = json.load(open(report_path))
    sites = []
    for r in rep["sites"]:
        if not r["in_budget"]:
            continue
        opts = [FormatOption("bf16", 2.0, 0.0)]
        if r["packable"]:
            opts.append(FormatOption("hif4", 0.5625, r["errors"]["hif4"]))
        sites.append(SiteScore(r["path"], r["n_values"], tuple(opts)))
    fb = rep["baselines"]["sensitive-fallback"]
    f = frontier_search(sites, fb["total_bytes"]
                        / sum(s.n_values for s in sites))
    assert f.feasible
    assert f.total_bytes <= fb["total_bytes"]
    assert f.total_error <= fb["total_error"] + 1e-6


@pytest.mark.slow
def test_searched_policy_serves_end_to_end(calib):
    """The emitted file rides the real packed serve loop untouched."""
    from repro.runtime.scenario import Scenario, run_scenarios

    _, out, _ = calib
    rec = run_scenarios(
        (Scenario(name="searched-e2e", arch="qwen1.5-0.5b", impl="packed",
                  kv_format="hif4", policy=out, batch=1, prompt_len=8,
                  new_tokens=4,
                  expect=("kv:hif4", "kv:no-fallback")),),
        repeats=2, log=lambda *_: None)[0]
    assert rec["dispatch_ok"], rec["dispatch_failures"]
    assert rec["decode_step_ms"] > 0
    assert rec["roofline"]["weight_bytes"] > 0
