"""Kill-and-recover end-to-end: every crash fault class, bitwise.

The acceptance bar of docs/EXECUTION.md §Crash recovery: for EVERY crash
class in ``repro.runtime.faults.CRASH_CLASSES``, a journaled serve killed
at that point and resumed from its journal dir produces outputs BITWISE
identical to the same serve never interrupted — and the resumed serve
*proves* it (``stats["recovery"]["verified"]`` counts the re-served
requests whose outputs were checked against their journaled prefixes).
Journal/replay/checkpoint units live in tests/test_journal.py; these
tests drive ``serve_requests`` (both schedulers) with real crashes.

Same markers and geometry as tests/test_faults.py (faults marker job in
CI; jit-compile heavy, so slow too)."""
import dataclasses
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.qlinear import QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.faults import (CRASH_CLASSES, FaultInjector, FaultSpec,
                                  SimulatedCrash)
from repro.runtime.guard import GuardConfig, JournalError, RecoveryError
from repro.runtime.serve_loop import ServeConfig, serve_requests

pytestmark = [pytest.mark.faults, pytest.mark.slow]

CFG = get_arch("qwen1.5-0.5b").reduced()
P, BUDGET, CAP = 8, 6, 32


def _ctx(impl="packed", kv="hif4"):
    return ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl,
                                      kv=kvcache.KVCacheConfig(kv)),
                    remat=False, attn_q_chunk=2, attn_k_chunk=2)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reqs():
    """Three requests sharing a 12-token prefix (even lengths: the
    attention chunking needs prompt lengths divisible by 2)."""
    prefix = jax.random.randint(jax.random.PRNGKey(5), (12,), 0, CFG.vocab)
    return [jnp.concatenate([prefix, jax.random.randint(
        jax.random.PRNGKey(30 + i), (4 + 2 * i,), 0, CFG.vocab)])
        for i in range(3)]


def _paged_sc(jdir=None, checkpoint_every=2):
    return ServeConfig(max_new_tokens=BUDGET, decode_chunk=2,
                       cache_capacity=CAP, kv_format="hif4",
                       kv_pages=12, kv_page_tokens=P, guard=GuardConfig(),
                       journal_dir=jdir, checkpoint_every=checkpoint_every)


@pytest.fixture(scope="module")
def paged_baseline(params, reqs):
    """The never-interrupted run every recovery compares against."""
    return serve_requests(CFG, params, reqs, _ctx(), _paged_sc(), slots=3)


def _assert_bitwise(results, baseline):
    for i in range(len(baseline)):
        np.testing.assert_array_equal(np.asarray(results[i]),
                                      np.asarray(baseline[i]))


# ---------------------------------------------------------------------------
# Journal overhead path: journaled == unjournaled, audit clean
# ---------------------------------------------------------------------------


def test_journaled_serve_matches_unjournaled_bitwise(params, reqs,
                                                     paged_baseline,
                                                     tmp_path):
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(),
                         _paged_sc(str(tmp_path)), slots=3, stats=stats)
    _assert_bitwise(res, paged_baseline)
    assert all(r["status"] == "ok" for r in stats["reports"].values())
    # the journal records the full lifecycle and the pool audits clean
    assert os.path.getsize(tmp_path / "serve.journal") > 0
    assert glob.glob(str(tmp_path / "ckpt_*.npz")), \
        "checkpoint_every=2 over 3 chunks must write at least one"
    assert stats["pool_audit"]["live"] == 0


def test_resume_of_a_finished_serve_reserves_nothing(params, reqs,
                                                     paged_baseline,
                                                     tmp_path):
    sc = _paged_sc(str(tmp_path))
    serve_requests(CFG, params, reqs, _ctx(), sc, slots=3)
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(), sc, slots=3,
                         stats=stats, resume=True)
    _assert_bitwise(res, paged_baseline)
    rec = stats["recovery"]
    assert rec["completed"] == len(reqs)
    assert rec["replayed"] == rec["re_prefilled"] == 0
    assert rec["verified"] == 0            # nothing re-served to verify
    assert all(r["status"] == "ok" for r in stats["reports"].values())


def test_resume_without_journal_raises_typed(params, reqs, tmp_path):
    with pytest.raises(JournalError, match="nothing to resume"):
        serve_requests(CFG, params, reqs, _ctx(), _paged_sc(str(tmp_path)),
                       slots=3, resume=True)
    with pytest.raises(RecoveryError, match="journal_dir"):
        serve_requests(CFG, params, reqs, _ctx(), _paged_sc(None),
                       slots=3, resume=True)


# ---------------------------------------------------------------------------
# Kill-and-recover, every crash class
# ---------------------------------------------------------------------------


def _crash_then_resume(params, reqs, jdir, spec):
    sc = _paged_sc(jdir)
    inj = FaultInjector(spec)
    with pytest.raises(SimulatedCrash):
        serve_requests(CFG, params, reqs, _ctx(), sc, slots=3,
                       injector=inj)
    assert inj.fired, "crash point never reached — geometry regressed"
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(), sc, slots=3,
                         stats=stats, resume=True)
    assert all(r["status"] == "ok" for r in stats["reports"].values())
    assert stats["pool_audit"]["live"] == 0
    return res, stats


@pytest.mark.parametrize("kind", CRASH_CLASSES)
def test_crash_class_killed_and_recovered_bitwise(params, reqs,
                                                  paged_baseline,
                                                  tmp_path, kind):
    spec = FaultSpec(kind=kind, target_request=1, after_chunk=1)
    res, stats = _crash_then_resume(params, reqs, str(tmp_path), spec)
    _assert_bitwise(res, paged_baseline)
    rec = stats["recovery"]
    # every re-served request's output was CHECKED against its journaled
    # token prefix — recovery is verified, not trusted
    assert rec["verified"] >= 1, rec
    assert rec["completed"] + rec["replayed"] + rec["re_prefilled"] >= 1


def test_crash_after_admit_reprefills_from_prompt(params, reqs,
                                                  paged_baseline, tmp_path):
    """Death right after the admit record: no checkpoint exists yet, so
    the admitted requests re-enter from their prompts."""
    res, stats = _crash_then_resume(
        params, reqs, str(tmp_path), FaultSpec(kind="crash_after_admit",
                                               target_request=1))
    _assert_bitwise(res, paged_baseline)
    rec = stats["recovery"]
    assert rec["replayed"] == 0 and rec["re_prefilled"] >= 1


def test_crash_mid_decode_replays_from_checkpoint(params, reqs,
                                                  paged_baseline, tmp_path):
    """Death after chunk 1's record: the chunk-2 checkpoint is durable,
    so residents resume from their checkpointed pages, not the prompt."""
    res, stats = _crash_then_resume(
        params, reqs, str(tmp_path), FaultSpec(kind="crash_mid_decode",
                                               after_chunk=1))
    _assert_bitwise(res, paged_baseline)
    assert stats["recovery"]["replayed"] >= 1, stats["recovery"]


def test_crash_during_checkpoint_ignores_orphan_npz(params, reqs,
                                                    paged_baseline,
                                                    tmp_path):
    """The .npz hits disk but its journal record never commits: the
    orphaned file must be ignored (the record is the commit point) and
    recovery degrades to re-prefill."""
    sc = _paged_sc(str(tmp_path))
    inj = FaultInjector(FaultSpec(kind="crash_during_checkpoint"))
    with pytest.raises(SimulatedCrash):
        serve_requests(CFG, params, reqs, _ctx(), sc, slots=3,
                       injector=inj)
    orphans = glob.glob(str(tmp_path / "ckpt_*.npz"))
    assert orphans, "crash fired before the npz was staged"
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(), sc, slots=3,
                         stats=stats, resume=True)
    _assert_bitwise(res, paged_baseline)
    rec = stats["recovery"]
    assert rec["replayed"] == 0 and rec["re_prefilled"] >= 1


def test_journal_truncation_drops_torn_tail_and_recovers(params, reqs,
                                                         paged_baseline,
                                                         tmp_path):
    res, stats = _crash_then_resume(
        params, reqs, str(tmp_path), FaultSpec(kind="journal_truncation",
                                               after_chunk=1, bits=20))
    _assert_bitwise(res, paged_baseline)
    assert stats["recovery"]["dropped_bytes"] > 0, stats["recovery"]


def test_crash_resume_is_deterministic(params, reqs, tmp_path):
    """Two independent crash+resume cycles with the same spec produce the
    same recovery report shape and identical outputs."""
    runs = []
    for sub in ("a", "b"):
        d = tmp_path / sub
        res, stats = _crash_then_resume(
            params, reqs, str(d), FaultSpec(kind="crash_mid_decode",
                                            after_chunk=1))
        runs.append((res, stats["recovery"]))
    _assert_bitwise(runs[0][0], runs[1][0])
    for key in ("completed", "replayed", "re_prefilled", "verified"):
        assert runs[0][1][key] == runs[1][1][key]


# ---------------------------------------------------------------------------
# Whole-slot scheduler (contiguous cache): same contract, no checkpoints
# ---------------------------------------------------------------------------


def test_slot_scheduler_crash_and_resume_bitwise(params, reqs, tmp_path):
    ctx = _ctx(impl="qdq", kv="bf16")
    def sc(jdir=None):
        return ServeConfig(max_new_tokens=BUDGET, decode_chunk=2,
                           cache_capacity=CAP, kv_format="bf16",
                           journal_dir=jdir)
    baseline = serve_requests(CFG, params, reqs, ctx, sc(), slots=2)
    inj = FaultInjector(FaultSpec(kind="crash_mid_decode", after_chunk=1))
    with pytest.raises(SimulatedCrash):
        serve_requests(CFG, params, reqs, ctx, sc(str(tmp_path)), slots=2,
                       injector=inj)
    assert inj.fired
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, sc(str(tmp_path)),
                         slots=2, stats=stats, resume=True)
    _assert_bitwise(res, baseline)
    rec = stats["recovery"]
    assert rec["verified"] >= 1
    assert rec["replayed"] == 0            # slot scheduler: no checkpoints
    assert all(r["status"] == "ok" for r in stats["reports"].values())
