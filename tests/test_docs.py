"""Tier-1 guard for the docs lint (tools/check_docs.py): README and docs
must not reference symbols or files that no longer exist."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools import check_docs


def test_docs_reference_live_symbols():
    errors = check_docs.run()
    assert not errors, "\n".join(errors)


def test_lint_catches_dead_references(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `repro.core.kvcache.no_such_symbol` and "
                   "docs/NO_SUCH_FILE.md\n")
    errors = check_docs.check_file(str(bad))
    assert len(errors) == 2


def test_lint_checks_matrix_gate_names(tmp_path):
    """Documented gates must exist in benchmarks.matrix.GATE_NAMES — a
    doc claiming a gate check_matrix_gates does not enforce fails."""
    ok = tmp_path / "ok.md"
    ok.write_text("enforced as gate:`dispatch_ok` and "
                  "gate:`trajectory_regression`\n")
    assert check_docs.check_file(str(ok)) == []
    bad = tmp_path / "bad.md"
    bad.write_text("enforced as gate:`no_such_gate`\n")
    errors = check_docs.check_file(str(bad))
    assert len(errors) == 1 and "no_such_gate" in errors[0]


def test_lint_checks_fault_class_names(tmp_path):
    """Documented fault/crash classes must exist in
    repro.runtime.faults.FAULT_CLASSES — a recovery matrix naming a
    class the injector cannot fire fails."""
    ok = tmp_path / "ok.md"
    ok.write_text("killed at fault:`crash_mid_decode`, torn by "
                  "fault:`journal_truncation`\n")
    assert check_docs.check_file(str(ok)) == []
    bad = tmp_path / "bad.md"
    bad.write_text("killed at fault:`power_loss`\n")
    errors = check_docs.check_file(str(bad))
    assert len(errors) == 1 and "power_loss" in errors[0]
