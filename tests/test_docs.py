"""Tier-1 guard for the docs lint (tools/check_docs.py): README and docs
must not reference symbols or files that no longer exist."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools import check_docs


def test_docs_reference_live_symbols():
    errors = check_docs.run()
    assert not errors, "\n".join(errors)


def test_lint_catches_dead_references(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `repro.core.kvcache.no_such_symbol` and "
                   "docs/NO_SUCH_FILE.md\n")
    errors = check_docs.check_file(str(bad))
    assert len(errors) == 2
