"""Execution-engine dispatch: impl='packed' and impl='pallas' must agree
with impl='qdq' — same quantized values, different execution — at the
matmul level, through a full transformer forward, and through the scan
decode / continuous-batching serving stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import engine, hif4
from repro.core.qlinear import PackedW, QuantConfig, quantize_params_offline
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.serve_loop import (
    ServeConfig,
    prepare_params_for_serving,
    serve,
    serve_requests,
    serving_ctx,
)

CFG = get_arch("qwen1.5-0.5b").reduced()


def _ctx(impl):
    return ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl), remat=False,
                    attn_q_chunk=32, attn_k_chunk=32)


def _operands(m=8, k=128, n=96, seed=0):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * 0.1).astype(
        jnp.bfloat16)
    w = (jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n)) * 0.05).astype(
        jnp.bfloat16)
    return x, w


# ---------------------------------------------------------------------------
# Matmul-level equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["packed", "pallas"])
def test_engine_matmul_matches_qdq(impl):
    x, w = _operands()
    ref = engine.matmul(x, w, engine.EngineCtx(
        quant=QuantConfig(fmt="hif4", impl="qdq")))
    pw = PackedW.from_dense(w, (0,))
    got = engine.matmul(x, pw, engine.EngineCtx(
        quant=QuantConfig(fmt="hif4", impl=impl)))
    # same HiF4 values contracted; bf16-output rounding is the only slack
    np.testing.assert_allclose(
        np.asarray(got, jnp.float32), np.asarray(ref, jnp.float32),
        rtol=0.02, atol=0.01)


def test_pallas_dense_equals_exact_fixed_point():
    """The pallas path IS the §III.B flow: f32-accumulated group dot of the
    quantized operands, bit-exact up to the final bf16 output cast."""
    x, w = _operands()
    got = engine.matmul(x, w, engine.EngineCtx(
        quant=QuantConfig(fmt="hif4", impl="pallas")))
    exact = hif4.qdq(x.astype(jnp.float32), axis=-1) @ hif4.qdq(
        w.astype(jnp.float32), axis=0)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(exact.astype(jnp.bfloat16)))


def test_pallas_fallbacks_to_qdq():
    """Non-HiF4 formats and weights_only cannot run the integer kernels;
    dispatch must fall back to the qdq path, not error."""
    import dataclasses

    x, w = _operands()
    for cfg in (QuantConfig(fmt="nvfp4", impl="pallas"),
                QuantConfig(fmt="hif4", impl="pallas", weights_only=True)):
        got = engine.matmul(x, w, engine.EngineCtx(quant=cfg))
        ref = engine.matmul(x, w, engine.EngineCtx(
            quant=dataclasses.replace(cfg, impl="qdq")))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_packedw_reshape_validates():
    _, w = _operands()
    pw = PackedW.from_dense(w, (0,))        # shape2d (128, 96)
    assert pw.reshape(128, -1) is pw
    assert pw.reshape(-1, 96) is pw
    with pytest.raises(AssertionError):
        pw.reshape(96, -1)                  # transposed layout
    with pytest.raises(AssertionError):
        pw.reshape(64, 2, 96)               # not the 2-D packed layout
    with pytest.raises(AssertionError):
        pw.reshape(128, 100)                # wrong element count


def test_packed_residency_bytes_per_value():
    _, w = _operands(k=256, n=128)
    pw = PackedW.from_dense(w, (0,))
    assert pw.nbytes_packed / pw.n_values == hif4.BITS_PER_VALUE / 8


# ---------------------------------------------------------------------------
# Model-level equivalence (small transformer forward)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["packed", "pallas"])
def test_transformer_forward_path_equivalence(impl):
    # packed executes the identical bf16 dot on identical quantized values
    # (tight tolerance); pallas accumulates every group dot in f32 inside
    # the kernel where qdq's dot emits bf16 partials, and the difference
    # compounds across layers (looser tolerance, same quantized values).
    tol = dict(rtol=0.02, atol=0.02) if impl == "packed" else dict(
        rtol=0.05, atol=0.08)
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, CFG.vocab)

    ref_params = dict(params)
    ref_params["blocks"] = quantize_params_offline(
        params["blocks"], QuantConfig(fmt="hif4"))
    ref_ctx = serving_ctx(_ctx("qdq"))
    ref_logits, _ = lm.prefill(ref_params, {"tokens": tokens}, CFG, ref_ctx)

    packed_params = prepare_params_for_serving(
        params, CFG, QuantConfig(fmt="hif4", impl=impl))
    ctx = serving_ctx(_ctx(impl))
    logits, cache = lm.prefill(packed_params, {"tokens": tokens}, CFG, ctx)

    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               **tol)

    # and a decode step stays on the same path
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache = lm.pad_cache(cache, CFG, 24)
    logits2, _ = lm.decode_step(packed_params, tok, cache, CFG, ctx)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_moe_packed_serving_excludes_experts():
    """MoE expert weights flow through the batched-expert einsum (no packed
    dispatch): packing must leave them dense, and serving must still run."""
    cfg = get_arch("phi3.5-moe-42b-a6.6b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    packed = prepare_params_for_serving(
        params, cfg, QuantConfig(fmt="hif4", impl="packed"))
    moe_leaves = packed["blocks"]["moe"]
    assert not any(isinstance(v, PackedW) for v in moe_leaves.values())
    # attention weights DO pack
    assert isinstance(packed["blocks"]["attn"]["wq"], PackedW)

    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    toks = serve(cfg, params, {"tokens": tokens}, _ctx("packed"),
                 ServeConfig(max_new_tokens=4))
    assert toks.shape == (2, 4)


# ---------------------------------------------------------------------------
# Scan decode vs python-loop decode
# ---------------------------------------------------------------------------


def test_scan_decode_matches_python_loop():
    params = lm.init_params(CFG, jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, CFG.vocab)
    ctx = serving_ctx(_ctx("qdq"))
    n_new = 6

    # python loop (the old serve shape): one decode_step call per token
    logits, cache = lm.prefill(params, {"tokens": tokens}, CFG, ctx)
    cache = lm.pad_cache(cache, CFG, 8 + n_new)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    loop_out = [tok]
    for _ in range(n_new - 1):
        logits, cache = lm.decode_step(params, tok, cache, CFG, ctx)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        loop_out.append(tok)
    loop_toks = np.asarray(jnp.stack(loop_out, axis=1))

    # scan path (what serve() runs)
    scan_toks = np.asarray(serve(
        CFG, params, {"tokens": tokens}, _ctx("qdq"),
        ServeConfig(max_new_tokens=n_new)))
    np.testing.assert_array_equal(scan_toks, loop_toks)

    # chunked scan must not change results either
    chunk_toks = np.asarray(serve(
        CFG, params, {"tokens": tokens}, _ctx("qdq"),
        ServeConfig(max_new_tokens=n_new, decode_chunk=2)))
    np.testing.assert_array_equal(chunk_toks, loop_toks)


# ---------------------------------------------------------------------------
# Continuous batching scheduler
# ---------------------------------------------------------------------------


def test_scheduler_matches_solo_serving():
    """Slot-admitted requests (varying prompt lengths, fewer slots than
    requests) must produce exactly the tokens of serving each alone."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [
        jax.random.randint(jax.random.PRNGKey(10 + i), (8 + 4 * i,), 0,
                           CFG.vocab)
        for i in range(3)
    ]
    ctx = _ctx("packed")
    sc = ServeConfig(max_new_tokens=6, decode_chunk=2)
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=2)
    assert len(res) == len(reqs)
    for i, r in enumerate(reqs):
        solo = serve(CFG, params, {"tokens": r[None, :]}, ctx,
                     ServeConfig(max_new_tokens=6))
        np.testing.assert_array_equal(np.asarray(res[i]), np.asarray(solo[0]))


# ---------------------------------------------------------------------------
# HiF4-packed KV cache (kv_format="hif4"): closeness, parity, residency
# ---------------------------------------------------------------------------


def test_hif4_kv_decode_matches_bf16_cache():
    """Packed-cache decode must track bf16-cache decode within the
    documented tolerance (docs/FORMATS.md: rtol=0.05, atol=0.1 on
    logits — the KV quantization error), over several appended steps."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, CFG.vocab)
    ctx = serving_ctx(_ctx("qdq"))

    logits, cache = lm.prefill(params, {"tokens": tokens}, CFG, ctx)
    cache_bf = lm.pad_cache(cache, CFG, 24)
    cache_pk = lm.pad_cache(lm.quantize_kv_cache(cache, CFG), CFG, 24)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    tok_bf = tok_pk = tok
    for _ in range(5):
        l_bf, cache_bf = lm.decode_step(params, tok_bf, cache_bf, CFG, ctx)
        l_pk, cache_pk = lm.decode_step(params, tok_pk, cache_pk, CFG, ctx)
        np.testing.assert_allclose(np.asarray(l_pk), np.asarray(l_bf),
                                   rtol=0.05, atol=0.1)
        tok_bf = jnp.argmax(l_bf, -1).astype(jnp.int32)
        tok_pk = jnp.argmax(l_pk, -1).astype(jnp.int32)


def test_hif4_kv_serve_config_wiring():
    """ServeConfig.kv_format and QuantConfig.kv both select the packed
    cache, and the two spellings serve identical tokens."""
    from repro.core.kvcache import KVCacheConfig

    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8),
                                            0, CFG.vocab)}
    via_serve_cfg = serve(CFG, params, prompts, _ctx("packed"),
                          ServeConfig(max_new_tokens=4, kv_format="hif4"))
    ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl="packed",
                                     kv=KVCacheConfig("hif4")),
                   remat=False, attn_q_chunk=32, attn_k_chunk=32)
    via_quant_cfg = serve(CFG, params, prompts, ctx,
                          ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(np.asarray(via_serve_cfg),
                                  np.asarray(via_quant_cfg))


def test_scheduler_matches_solo_serving_hif4_kv():
    """Continuous batching over a PACKED cache must stay bit-identical to
    solo serving: a token's packed bits depend only on its own K/V vector,
    never on its slot, neighbours, or cache capacity."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [
        jax.random.randint(jax.random.PRNGKey(20 + i), (8 + 4 * i,), 0,
                           CFG.vocab)
        for i in range(3)
    ]
    ctx = _ctx("packed")
    sc = ServeConfig(max_new_tokens=6, decode_chunk=2, kv_format="hif4")
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=2)
    for i, r in enumerate(reqs):
        solo = serve(CFG, params, {"tokens": r[None, :]}, ctx,
                     ServeConfig(max_new_tokens=6, kv_format="hif4"))
        np.testing.assert_array_equal(np.asarray(res[i]), np.asarray(solo[0]))


def test_flash_mha_vec_packed_matches_dense():
    """The packed vec-q flash variant (per-tile dequantize inside the KV
    scan) must match the dense recurrence run on the dequantized cache."""
    from repro.core import kvcache
    from repro.models import attention as attn_mod

    B, Sq, Sk, Hkv, rep, D = 2, 8, 32, 2, 2, 32
    q = (jax.random.normal(jax.random.PRNGKey(0), (B, Sq, Hkv * rep, D))
         * 0.3).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.PRNGKey(1), (B, Sk, Hkv, D))
         * 0.3).astype(jnp.bfloat16)
    v = (jax.random.normal(jax.random.PRNGKey(2), (B, Sk, Hkv, D))
         * 0.3).astype(jnp.bfloat16)
    pk, pv = kvcache.quantize_kv(k), kvcache.quantize_kv(v)
    kd = kvcache.dequantize_kv(pk, Hkv, D)
    vd = kvcache.dequantize_kv(pv, Hkv, D)
    chunking = attn_mod.AttnChunking(q_chunk=4, k_chunk=8)
    valid = jnp.asarray([Sk, Sk // 2], jnp.int32)

    got = attn_mod.flash_mha_vec_packed(
        q, pk, pv, Hkv, D, causal=True, q_offset=Sk - Sq,
        kv_valid_len=valid, chunking=chunking)
    want, _ = attn_mod._flash_fwd_impl(
        q, kd, vd, True, Sk - Sq, valid, chunking)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=0.02, atol=0.01)
