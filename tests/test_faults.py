"""End-to-end fault injection through the guarded serve stack.

The acceptance bar of the failure-semantics docs (docs/EXECUTION.md): for
EVERY fault class in ``repro.runtime.faults.FAULT_CLASSES``, the injected
fault is (a) detected — the victim request ends ``retried`` /
``quarantined`` / ``rejected``, never silently wrong — and (b) contained
— every surviving request's output is BITWISE identical to the same serve
with no injector. Detector units live in tests/test_guard.py; these tests
drive the schedulers (``serve_requests``, both backends) with a real
:class:`repro.runtime.faults.FaultInjector`.

All tests carry the ``faults`` marker (CI runs them as their own job)
and they are jit-compile heavy, so they are ``slow`` too."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.qlinear import QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.faults import FaultInjector, FaultSpec, parse_fault
from repro.runtime.guard import GuardConfig, PoolExhaustedError
from repro.runtime.serve_loop import ServeConfig, serve, serve_requests

pytestmark = [pytest.mark.faults, pytest.mark.slow]

CFG = get_arch("qwen1.5-0.5b").reduced()
P, BUDGET, CAP = 8, 6, 32


def _ctx(impl="packed", kv="hif4"):
    return ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl,
                                      kv=kvcache.KVCacheConfig(kv)),
                    remat=False, attn_q_chunk=2, attn_k_chunk=2)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reqs():
    """Three requests sharing a 12-token prefix; prompts > P tokens so the
    first owned page is settled by the time after_chunk >= 1 fires."""
    prefix = jax.random.randint(jax.random.PRNGKey(5), (12,), 0, CFG.vocab)
    return [jnp.concatenate([prefix, jax.random.randint(
        jax.random.PRNGKey(30 + i), (4 + 2 * i,), 0, CFG.vocab)])
        for i in range(3)]


def _paged_sc(guard=GuardConfig(), kv_pages=12):
    return ServeConfig(max_new_tokens=BUDGET, decode_chunk=2,
                       cache_capacity=CAP, kv_format="hif4",
                       kv_pages=kv_pages, kv_page_tokens=P, guard=guard)


@pytest.fixture(scope="module")
def paged_baseline(params, reqs):
    """The uninjected guarded run all containment tests compare against —
    itself asserted bitwise equal to the UNguarded scheduler, so a clean
    guard pass changes nothing."""
    base = serve_requests(CFG, params, reqs, _ctx(), _paged_sc(guard=None),
                          slots=3)
    stats: dict = {}
    guarded = serve_requests(CFG, params, reqs, _ctx(), _paged_sc(),
                             slots=3, stats=stats)
    for a, b in zip(base, guarded):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(r["status"] == "ok" for r in stats["reports"].values())
    assert stats["quarantined"] == stats["retried"] == stats["rejected"] == 0
    return guarded


def _assert_contained(results, stats, injector, baseline, victim):
    """The fault fired, the victim never silently produced wrong tokens,
    and every survivor is bitwise identical to the uninjected run."""
    assert injector.fired, injector.events
    rep = stats["reports"][victim]
    assert rep["status"] in ("retried", "quarantined"), rep
    assert rep["detail"], rep
    for i in range(len(baseline)):
        if i == victim:
            continue
        assert stats["reports"][i]["status"] == "ok"
        np.testing.assert_array_equal(np.asarray(results[i]),
                                      np.asarray(baseline[i]))
    if rep["status"] == "retried":
        # the qdq/bf16 fallback retry re-serves solo and greedy decode is
        # deterministic — a recovered victim is EXACT, not approximate
        np.testing.assert_array_equal(np.asarray(results[victim]),
                                      np.asarray(baseline[victim]))
    return rep


# ---------------------------------------------------------------------------
# Packed-page corruption (paged scheduler): code_flip / meta_flip /
# page_corruption
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,seed", [
    ("code_flip", 0),       # finite value perturbation: checksum-only
    ("meta_flip", 3),       # seed=3 flips bit 2 — low word, checksum-only
    ("meta_flip", 7),       # different bit draw (may hit the E6M2 byte)
    ("page_corruption", 1),  # multi-flip + forced 0xFF: every sentinel
])
def test_page_fault_detected_and_contained(params, reqs, paged_baseline,
                                           kind, seed):
    inj = FaultInjector(FaultSpec(kind=kind, seed=seed, target_request=1,
                                  after_chunk=1))
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(), _paged_sc(), slots=3,
                         stats=stats, injector=inj)
    rep = _assert_contained(res, stats, inj, paged_baseline, victim=1)
    detector = rep["detail"].split(":")[0]
    assert detector in ("page_checksum", "meta_nan", "nan_logits"), rep
    if kind == "code_flip":
        # values perturb silently (finite): ONLY the checksum can see it
        assert detector == "page_checksum", rep


def test_same_spec_same_fault_same_bits(params, reqs, paged_baseline):
    """Determinism: one FaultSpec injects the identical fault both runs —
    identical events log and identical outputs for every request."""
    runs = []
    for _ in range(2):
        inj = FaultInjector(FaultSpec(kind="meta_flip", seed=3,
                                      target_request=1, after_chunk=1))
        stats: dict = {}
        res = serve_requests(CFG, params, reqs, _ctx(), _paged_sc(),
                             slots=3, stats=stats, injector=inj)
        runs.append((res, stats, inj))
    assert runs[0][2].events == runs[1][2].events
    assert runs[0][1]["reports"] == runs[1][1]["reports"]
    for a, b in zip(runs[0][0], runs[1][0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# nan_activation (whole-slot scheduler, bf16 KV)
# ---------------------------------------------------------------------------


def test_nan_activation_detected_and_contained(params, reqs):
    ctx = _ctx(impl="qdq", kv="bf16")
    sc = ServeConfig(max_new_tokens=BUDGET, decode_chunk=2,
                     cache_capacity=CAP, kv_format="bf16")
    base = serve_requests(CFG, params, reqs, ctx, sc, slots=2)
    scg = dataclasses.replace(sc, guard=GuardConfig())
    inj = FaultInjector(FaultSpec(kind="nan_activation", seed=0,
                                  target_request=0, after_chunk=1))
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, scg, slots=2, stats=stats,
                         injector=inj)
    rep = _assert_contained(res, stats, inj, base, victim=0)
    assert rep["detail"].startswith("nan_logits"), rep


def test_nan_activation_without_retry_quarantines(params, reqs):
    """retry_fallback=False: detection still fires but the victim ends
    quarantined with an eos/-1 fill instead of recovering."""
    ctx = _ctx(impl="qdq", kv="bf16")
    scg = ServeConfig(max_new_tokens=BUDGET, decode_chunk=2,
                      cache_capacity=CAP, kv_format="bf16",
                      guard=GuardConfig(retry_fallback=False))
    inj = FaultInjector(FaultSpec(kind="nan_activation", seed=0,
                                  target_request=0, after_chunk=1))
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, scg, slots=2, stats=stats,
                         injector=inj)
    assert stats["reports"][0]["status"] == "quarantined"
    assert stats["quarantined"] == 1
    assert res[0].shape == (BUDGET,)   # padded fill, never silent garbage


# ---------------------------------------------------------------------------
# pool_starvation (admission failure semantics)
# ---------------------------------------------------------------------------


def test_pool_starvation_guarded_rejects(params, reqs):
    inj = FaultInjector(FaultSpec(kind="pool_starvation", seed=0))
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, _ctx(), _paged_sc(), slots=3,
                         stats=stats, injector=inj)
    assert inj.fired
    assert stats["rejected"] == len(reqs)
    for i in range(len(reqs)):
        rep = stats["reports"][i]
        assert rep["status"] == "rejected"
        assert rep["retries"] == GuardConfig().max_admission_retries
        assert res[i].shape == (BUDGET,)


def test_pool_starvation_unguarded_raises_typed(params, reqs):
    inj = FaultInjector(FaultSpec(kind="pool_starvation", seed=0))
    with pytest.raises(PoolExhaustedError):
        serve_requests(CFG, params, reqs, _ctx(), _paged_sc(guard=None),
                       slots=3, injector=inj)


# ---------------------------------------------------------------------------
# snapshot_truncation (preemption snapshot integrity)
# ---------------------------------------------------------------------------


def _solo(params, r, P, cap, budget):
    solo_ctx = dataclasses.replace(_ctx(), attn_kv_block=P)
    sc = ServeConfig(max_new_tokens=budget, cache_capacity=cap,
                     kv_format="hif4")
    return serve(CFG, params, {"tokens": r[None, :]}, solo_ctx, sc)[0]


@pytest.mark.parametrize("bits", [0, 1])   # 0 = truncate, 1 = bit flip
def test_snapshot_corruption_requeues_bitwise(params, bits):
    """The preemption geometry of test_paged_kv, with the victim's host
    snapshot corrupted AFTER its fingerprint was stamped: re-admission
    must detect it, drop the snapshot, and re-serve from the prompt —
    still bitwise equal to solo serving (greedy decode is deterministic)."""
    Pp, budget, cap = 4, 8, 16
    reqs2 = [jax.random.randint(jax.random.PRNGKey(15 + i), (8,), 0,
                                CFG.vocab) for i in range(2)]
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2,
                     cache_capacity=cap, kv_format="hif4", kv_pages=6,
                     kv_page_tokens=Pp, guard=GuardConfig())
    # 5 usable pages, each sequence needs 4: the younger slot (request 1)
    # is preempted mid-admission
    inj = FaultInjector(FaultSpec(kind="snapshot_truncation", seed=0,
                                  target_request=1, bits=bits))
    stats: dict = {}
    res = serve_requests(CFG, params, reqs2, _ctx(), sc, slots=2,
                         stats=stats, injector=inj)
    assert stats["preemptions"] >= 1
    assert inj.fired, "preemption never happened — geometry regressed"
    assert stats["snapshot_drops"] >= 1
    rep = stats["reports"][1]
    assert rep["status"] == "retried"
    assert rep["detail"].startswith("snapshot_integrity"), rep
    for i, r in enumerate(reqs2):
        np.testing.assert_array_equal(
            np.asarray(res[i]), np.asarray(_solo(params, r, Pp, cap,
                                                 budget)))


# ---------------------------------------------------------------------------
# Launcher spec syntax
# ---------------------------------------------------------------------------


def test_parse_fault_spec():
    spec = parse_fault("meta_flip:seed=3,target_request=1,after_chunk=2")
    assert spec == FaultSpec(kind="meta_flip", seed=3, target_request=1,
                             after_chunk=2)
    assert parse_fault("pool_starvation") == FaultSpec("pool_starvation")
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault("bitrot:seed=1")
