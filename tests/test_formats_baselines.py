"""Tests for the baseline formats (NVFP4, NVFP4+PTS, MXFP4) and the
paper's comparative claims (Fig. 3 MSE ratios, Table II features)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mxfp4, nvfp4
from repro.core import rounding as R
from repro.core.formats import get_format
from repro.core.metrics import mse


class TestE2M1:
    def test_grid(self):
        xs = jnp.asarray([0.0, 0.2, 0.3, 0.6, 0.9, 1.2, 1.8, 2.4, 3.6, 5.1, 7.0])
        q = np.asarray(R.quantize_e2m1(xs))
        for v in q:
            assert v in R.E2M1_VALUES

    def test_rne_ties(self):
        # 0.25 ties 0 (even code) vs 0.5 -> 0; 2.5 ties 2 (even) vs 3 -> 2
        assert float(R.quantize_e2m1(jnp.float32(0.25))) == 0.0
        assert float(R.quantize_e2m1(jnp.float32(2.5))) == 2.0
        assert float(R.quantize_e2m1(jnp.float32(5.0))) == 4.0

    def test_codec_roundtrip(self):
        vals = jnp.asarray([v for v in R.E2M1_VALUES] + [-v for v in R.E2M1_VALUES])
        back = R.decode_e2m1(R.encode_e2m1(vals))
        np.testing.assert_array_equal(np.abs(np.asarray(back)), np.abs(np.asarray(vals)))


class TestE4M3:
    def test_max_saturation(self):
        assert float(R.round_e4m3(jnp.float32(1e6))) == 448.0

    def test_subnormals(self):
        assert float(R.round_e4m3(jnp.float32(2.0 ** -9))) == 2.0 ** -9
        # below half the min subnormal -> 0
        assert float(R.round_e4m3(jnp.float32(2.0 ** -11))) == 0.0

    def test_known_values(self):
        for v in (1.0, 1.125, 240.0, 448.0, 0.0625):
            assert float(R.round_e4m3(jnp.float32(v))) == v


class TestNVFP4:
    def test_table2_constants(self):
        assert nvfp4.MAX_POS == 2.0 ** 11 * 1.3125
        assert nvfp4.MIN_POS == 2.0 ** -10

    def test_peak_normalized_to_6(self):
        rng = np.random.default_rng(0)
        v = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        g = nvfp4.quantize_groups(v)
        peak = jnp.max(jnp.abs(g.e2m1), axis=-1)
        assert float(jnp.median(peak)) == 6.0

    def test_overflow_crash_vs_pts(self):
        """Paper Fig. 3: above 2688 direct-cast clips, PTS recovers."""
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((64, 64)) * 5000.0, jnp.float32)
        direct = nvfp4.qdq(x)
        pts = nvfp4.qdq_pts(x)
        e_direct = float(mse(x, direct))
        e_pts = float(mse(x, pts))
        assert e_direct > 5 * e_pts

    def test_pts_identity_in_range(self):
        """PTS ~ no-op when the tensor already peaks near 2688."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((32, 64)) * 400.0, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(nvfp4.qdq_pts(x)),
            np.asarray(nvfp4.qdq(x * (2688.0 / float(jnp.max(jnp.abs(x))))))
            / (2688.0 / float(jnp.max(jnp.abs(x)))),
            rtol=1e-6,
        )


class TestMXFP4:
    def test_power_of_two_scale(self):
        rng = np.random.default_rng(3)
        v = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
        g = mxfp4.quantize_groups(v)
        logs = np.log2(np.asarray(g.scale))
        np.testing.assert_array_equal(logs, np.round(logs))

    def test_scale_is_ocp_spec(self):
        # amax = 5.0 -> floor(log2 5) = 2 -> shared exp = 0 -> scale 1
        v = jnp.zeros((1, 32)).at[0, 0].set(5.0)
        g = mxfp4.quantize_groups(v)
        assert float(g.scale[0]) == 1.0


class TestPaperFig3:
    """The paper's quantization-error experiment, exactly as specified."""

    @pytest.mark.parametrize("x_exp", [0, 4, 9, 13, 17])
    def test_mse_ordering(self, x_exp):
        sigma = 0.01 * 2.0 ** x_exp
        key = jax.random.PRNGKey(x_exp)
        mat = jax.random.normal(key, (1024, 1024), jnp.float32) * sigma
        mat = mat.astype(jnp.bfloat16).astype(jnp.float32)
        e_h = float(mse(mat, get_format("hif4").qdq(mat)))
        e_n = float(mse(mat, get_format("nvfp4").qdq(mat)))
        e_m = float(mse(mat, get_format("mxfp4").qdq(mat)))
        # HiF4 lowest everywhere; NVFP4 < MXFP4 only inside NVFP4's range
        # window (at the edges NVFP4 fluctuates above MXFP4 — Fig. 3)
        assert e_h < e_n and e_h < e_m
        if 2 <= x_exp <= 15:
            assert e_n < e_m

    def test_stable_region_ratios(self):
        """Paper: HiF4 : NVFP4 : MXFP4 = 1 : 1.32 : 1.89 (+-5%)."""
        key = jax.random.PRNGKey(42)
        mat = jax.random.normal(key, (1024, 1024), jnp.float32) * (0.01 * 2.0 ** 8)
        mat = mat.astype(jnp.bfloat16).astype(jnp.float32)
        e_h = float(mse(mat, get_format("hif4").qdq(mat)))
        r_n = float(mse(mat, get_format("nvfp4").qdq(mat))) / e_h
        r_m = float(mse(mat, get_format("mxfp4").qdq(mat))) / e_h
        assert r_n == pytest.approx(1.32, rel=0.05)
        assert r_m == pytest.approx(1.89, rel=0.05)

    def test_nvfp4_edge_blowup_hif4_stable(self):
        """Near format bounds NVFP4 direct-cast degrades; HiF4 does not."""
        key = jax.random.PRNGKey(7)
        base = jax.random.normal(key, (512, 512), jnp.float32)
        hif4_fmt, nv = get_format("hif4"), get_format("nvfp4")

        def rel(fmt, m):
            return float(mse(m, fmt.qdq(m)) / jnp.mean(jnp.square(m)))

        mid = base * (0.01 * 2.0 ** 8)
        hot = base * (0.01 * 2.0 ** 22)   # beyond NVFP4 22-binade window
        assert rel(nv, hot) > 10 * rel(nv, mid)          # NVFP4 blows up
        assert rel(hif4_fmt, hot) < 1.5 * rel(hif4_fmt, mid)  # HiF4 stable


@st.composite
def tensors(draw):
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((4, 128)) * scale, jnp.float32)


class TestQDQInvariants:
    @hypothesis.given(tensors())
    @hypothesis.settings(deadline=None, max_examples=25)
    def test_qdq_never_worse_than_signal(self, x):
        """Quantization error energy must stay below signal energy for all
        formats that cover the tensor's range (sanity invariant)."""
        for name in ("hif4", "mxfp4", "nvfp4_pts"):
            y = get_format(name).qdq(x)
            assert float(mse(x, y)) < float(jnp.mean(jnp.square(x)))

    @hypothesis.given(tensors())
    @hypothesis.settings(deadline=None, max_examples=25)
    def test_sign_preservation(self, x):
        for name in ("hif4", "nvfp4", "mxfp4"):
            y = get_format(name).qdq(x)
            prod = np.asarray(x) * np.asarray(y)
            assert (prod >= -1e-12).all()  # never flips sign
