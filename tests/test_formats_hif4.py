"""Unit + property tests for the HiF4 format (paper SS II, Table I/II, Alg. 1)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hif4, qlinear
from repro.core import rounding as R

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=50, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _rand_groups(seed, n=8, scale=1.0):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n, hif4.GROUP_SIZE)).astype(np.float32) * scale
    # inputs are BF16 per Algorithm 1
    return jnp.asarray(v, jnp.bfloat16).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Table I / Table II constants
# ---------------------------------------------------------------------------


class TestFormatConstants:
    def test_e6m2_range(self):
        assert float(R.round_e6m2(jnp.float32(1e30))) == 2.0 ** 15 * 1.5
        assert float(R.round_e6m2(jnp.float32(1e-30))) == 2.0 ** -48

    def test_e6m2_never_nan_pattern(self):
        # 2^15 * 1.75 would encode as the NaN pattern; rounding must avoid it
        v = R.round_e6m2(jnp.float32(2.0 ** 15 * 1.75))
        assert float(v) == 2.0 ** 15 * 1.5
        assert int(R.encode_e6m2(v)) != R.E6M2_NAN_BITS

    def test_table2_max_min(self):
        assert hif4.MAX_POS == 2.0 ** 18 * 1.3125
        assert hif4.MIN_POS == 2.0 ** -50

    def test_global_dynamic_range_69_binades(self):
        # Table II: [-50, 18] exponent span
        assert np.isclose(np.log2(hif4.MAX_POS) - np.log2(hif4.MIN_POS), 68.39, atol=0.1)

    def test_s1p2_grid(self):
        xs = jnp.linspace(-2.5, 2.5, 101)
        q = R.quantize_s1p2(xs)
        assert float(jnp.max(jnp.abs(q))) == 1.75
        assert np.allclose(np.asarray(q) % 0.25, 0)

    def test_s1p2_rne_ties(self):
        # 0.125 is a tie between 0.0 (even) and 0.25 (odd) -> 0.0
        assert float(R.quantize_s1p2(jnp.float32(0.125))) == 0.0
        # 0.375 ties between 0.25 (odd) and 0.5 (even) -> 0.5
        assert float(R.quantize_s1p2(jnp.float32(0.375))) == 0.5

    def test_e6m2_codec_roundtrip(self):
        codes = jnp.arange(255, dtype=jnp.uint8)  # skip NaN code 255
        vals = R.decode_e6m2(codes)
        back = R.encode_e6m2(vals)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    def test_e6m2_reciprocal_matches_lut_semantics(self):
        """The reciprocal must factor as 2^-E * LUT[M]: only then can the
        paper's 4-entry-LUT + exponent-subtraction instruction realize it."""
        # bf16 (7 mantissa bits) RNE of 1/1.M:
        lut = {0: 1.0, 1: 0.80078125, 2: 0.66796875, 3: 0.5703125}
        for m, frac in lut.items():
            v = jnp.float32(1 + m * 0.25)
            assert float(R.e6m2_reciprocal_bf16(v)) == frac
        # separability over the full exponent range (all non-NaN codes)
        codes = jnp.arange(255, dtype=jnp.uint8)
        vals = R.decode_e6m2(codes)
        rec = np.asarray(R.e6m2_reciprocal_bf16(vals))
        eb = np.asarray(codes >> 2).astype(np.int32) - 48
        mm = np.asarray(codes & 0x3)
        expect = np.asarray([lut[int(m)] for m in mm]) * np.exp2(-eb.astype(np.float64))
        np.testing.assert_array_equal(rec.astype(np.float64), expect)


# ---------------------------------------------------------------------------
# Algorithm 1 semantics
# ---------------------------------------------------------------------------


class TestAlgorithm1:
    def test_intra_group_normalization(self):
        """Scale maps group peak near 7 = intra-structure max (Alg.1 line 8)."""
        v = _rand_groups(0, n=64)
        g = hif4.quantize_groups(v)
        vmax = jnp.max(jnp.abs(v), axis=-1)
        norm = vmax / g.e6m2
        # RNE on E6M2 has <=12.5% relative error; peak lands in [6.1, 8.0]
        assert float(jnp.min(norm)) > 6.0
        assert float(jnp.max(norm)) < 8.1

    def test_peak_element_saturates_hierarchy(self):
        """The group's peak element must use both micro-exponent levels."""
        v = _rand_groups(1, n=32)
        g = hif4.quantize_groups(v)
        i = jnp.argmax(jnp.abs(v), axis=-1)
        lvl2 = jnp.take_along_axis(g.e1_8, i[:, None] // 8, axis=-1)[:, 0]
        lvl3 = jnp.take_along_axis(g.e1_16, i[:, None] // 4, axis=-1)[:, 0]
        # peak normalized to ~7 > 4 => E1_8 = 1; /2 >= 2 => E1_16 = 1
        assert np.all(np.asarray(lvl2) == 1)
        assert np.all(np.asarray(lvl3) == 1)

    def test_all_zero_group(self):
        g = hif4.quantize_groups(jnp.zeros((1, 64), jnp.float32))
        out = hif4.dequantize_groups(g)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        assert float(g.e6m2[0]) == R.E6M2_MIN  # no zero in E6M2

    def test_constant_group_exact(self):
        """Powers of two in a flat group should reconstruct near-exactly."""
        v = jnp.full((1, 64), 2.0 ** -3, jnp.float32)
        out = hif4.dequantize_groups(hif4.quantize_groups(v))
        np.testing.assert_allclose(np.asarray(out), 2.0 ** -3, rtol=0.08)

    def test_quantization_error_bound(self):
        """|err| <= half step at the element's effective scale (+bf16 eps)."""
        v = _rand_groups(2, n=128)
        g = hif4.quantize_groups(v)
        out = hif4.dequantize_groups(g)
        shift = jnp.repeat(g.e1_8, 8, -1) + jnp.repeat(g.e1_16, 4, -1)
        step = g.e6m2[:, None] * jnp.exp2(shift.astype(jnp.float32)) * 0.25
        err = jnp.abs(out - v)
        # elements can clamp at 1.75 when the scale rounded down; exclude
        # clamps. The bf16 multiply in Alg.1 line 16 adds up to ~2^-8
        # relative error on top of the half-step rounding bound.
        clamped = jnp.abs(g.s1p2) == 1.75
        bound = 0.5 * step + jnp.abs(v) * 2.0 ** -7 + 1e-6
        ok = jnp.where(clamped, True, err <= bound)
        assert bool(jnp.all(ok))

    def test_wide_dynamic_range_no_crash(self):
        """69-binade global range: extreme tensors stay finite (vs NVFP4)."""
        for exp in (-45, -20, 0, 14):
            v = _rand_groups(3, n=4, scale=2.0 ** exp)
            out = hif4.dequantize_groups(hif4.quantize_groups(v))
            assert bool(jnp.all(jnp.isfinite(out)))
            rel = float(
                jnp.mean(jnp.square(out - v)) / jnp.maximum(jnp.mean(jnp.square(v)), 1e-38)
            )
            assert rel < 0.02, f"exp={exp} rel={rel}"


# ---------------------------------------------------------------------------
# Packing / int-flow properties (hypothesis)
# ---------------------------------------------------------------------------


@st.composite
def group_arrays(draw):
    n = draw(st.integers(1, 4))
    scale = draw(st.sampled_from([2.0 ** e for e in range(-40, 15, 5)]))
    arr = draw(
        hnp.arrays(
            np.float32,
            (n, hif4.GROUP_SIZE),
            elements=st.floats(-4.0, 4.0, width=32),
        )
    )
    return jnp.asarray(arr * scale, jnp.bfloat16).astype(jnp.float32)


class TestNativeBf16Path:
    @hypothesis.given(group_arrays())
    def test_bf16_native_bitwise_equals_f32_simulated(self, v):
        """The native-bf16 Algorithm 1 must agree BITWISE with the
        explicitly-emulated f32 path on bf16 inputs (every intermediate is
        bf16-representable) — this is what makes the 2x QDQ-traffic
        optimization a free lunch."""
        g32 = hif4.quantize_groups(v)                      # f32-simulated
        g16 = hif4.quantize_groups(v.astype(jnp.bfloat16))  # native
        np.testing.assert_array_equal(np.asarray(g32.e6m2), np.asarray(g16.e6m2))
        np.testing.assert_array_equal(np.asarray(g32.e1_8), np.asarray(g16.e1_8))
        np.testing.assert_array_equal(np.asarray(g32.e1_16), np.asarray(g16.e1_16))
        np.testing.assert_array_equal(
            np.asarray(g32.s1p2), np.asarray(g16.s1p2).astype(np.float32)
        )
        d32 = hif4.dequantize_groups(g32)
        d16 = hif4.dequantize_groups(g16)
        np.testing.assert_array_equal(
            np.asarray(d32), np.asarray(d16).astype(np.float32)
        )


class TestPackingAndIntFlow:
    @hypothesis.given(group_arrays())
    def test_pack_unpack_roundtrip(self, v):
        g = hif4.quantize_groups(v)
        g2 = hif4.unpack_groups(hif4.pack_groups(g))
        np.testing.assert_array_equal(np.asarray(g.e6m2), np.asarray(g2.e6m2))
        np.testing.assert_array_equal(np.asarray(g.e1_8), np.asarray(g2.e1_8))
        np.testing.assert_array_equal(np.asarray(g.e1_16), np.asarray(g2.e1_16))
        np.testing.assert_array_equal(np.asarray(g.s1p2), np.asarray(g2.s1p2))

    @hypothesis.given(group_arrays())
    def test_absorbed_int_exact(self, v):
        """Int view must reproduce dequantized values exactly (SS III.B)."""
        g = hif4.quantize_groups(v)
        ints, scale = hif4.to_absorbed_int(g)
        recon = scale[:, None] * ints.astype(jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(recon), np.asarray(hif4.dequantize_groups(g))
        )

    @hypothesis.given(group_arrays())
    def test_absorbed_int_range(self, v):
        """Absorbed ints fit the 5-bit-shifted-int8 budget |q| <= 28."""
        ints, _ = hif4.to_absorbed_int(hif4.quantize_groups(v))
        assert int(jnp.max(jnp.abs(ints.astype(jnp.int32)))) <= 28

    def test_fixed_point_dot_equals_dequant_dot(self):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.standard_normal(64), jnp.bfloat16).astype(jnp.float32)
        b = jnp.asarray(rng.standard_normal(64), jnp.bfloat16).astype(jnp.float32)
        fp = float(qlinear.hif4_dot_fixed_point(a, b))
        da = hif4.dequantize_groups(hif4.quantize_groups(a.reshape(1, 64)))
        db = hif4.dequantize_groups(hif4.quantize_groups(b.reshape(1, 64)))
        ref = float(jnp.sum(da * db))
        assert fp == pytest.approx(ref, rel=1e-6)


class TestTensorQDQ:
    def test_axis_handling(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((3, 128, 5)), jnp.float32)
        y0 = hif4.qdq(x, axis=1)
        # grouping along axis=1 must equal transposing and grouping last axis
        y1 = jnp.moveaxis(hif4.qdq(jnp.moveaxis(x, 1, -1), axis=-1), -1, 1)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1))

    def test_padding_path(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 100)), jnp.float32)
        y = hif4.qdq(x, axis=-1)  # 100 -> padded to 128 internally
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_requantization_contracts(self):
        """HiF4 is not bit-idempotent (clamped peaks re-scale the group on a
        second pass — same as NVFP4), but requantization error must be much
        smaller than first-pass error and must not drift."""
        from repro.core.metrics import mse

        x = jnp.asarray(np.random.default_rng(2).standard_normal((16, 256)), jnp.float32)
        y = hif4.qdq(x)
        z = hif4.qdq(y)
        assert float(mse(y, z)) < 0.3 * float(mse(x, y))
        assert float(mse(x, z)) < 1.5 * float(mse(x, y))
