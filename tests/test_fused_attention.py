"""Fused HiF4 flash decode-attention: bit-exactness and dispatch.

The serving claim (docs/EXECUTION.md): streaming the 4.5-bit KV cache
through the kernel changes WHERE the bits expand, never what is computed.
The normalized online-softmax recurrence degenerates to the flat masked
softmax of ``decode_attention`` at a single KV tile, so there — across
lengths that exercise the tile mask (S=1, 63, 64, 65, capacity-1), B=1 vs
full scheduler slots, GQA head ratios, head-spanning 64-groups, and the
partial-group staging tail — the Pallas kernel (interpret mode, runs in
tier-1 CI on CPU), its straight-line XLA twin, and ``decode_attention`` on
the materialized bf16 cache must be BITWISE identical. Multi-tile runs
keep kernel == twin bitwise (same recurrence, same tiling) and are
float-close to the flat path (f32 sum reassociation only) — mirroring the
single-K-step anchor of ``tests/test_fused_matmul.py``. NaN metadata
(E6M2 0xFF) must propagate identically everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, kvcache
from repro.core.qlinear import QuantConfig
from repro.kernels.fused_attention import (
    fused_decode_attention,
    fused_decode_attention_xla,
    heads_per_block,
    kernel_compatible,
    select_kv_block,
)
from repro.models.attention import decode_attention, decode_attention_packed


def _setup(B, S, Hkv, rep, D, seed=0, kernel_layout=True):
    """Packed K/V caches + the materialized bf16 cache of the same bits."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = (jax.random.normal(ks[0], (B, Hkv * rep, D)) * 0.3).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (B, S, Hkv, D)) * 0.3).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[2], (B, S, Hkv, D)) * 0.3).astype(jnp.bfloat16)
    pk, pv = kvcache.quantize_kv(k), kvcache.quantize_kv(v)
    if kernel_layout:
        pk, pv = kvcache.to_kernel_layout(pk), kvcache.to_kernel_layout(pv)
    kd = kvcache.dequantize_kv(pk, Hkv, D)
    vd = kvcache.dequantize_kv(pv, Hkv, D)
    return q, pk, pv, kd, vd


# capacity 128; lengths exercise the mask at tile edges and the last slot
CAP = 128
LENGTHS = [1, 63, 64, 65, CAP - 1]


# ---------------------------------------------------------------------------
# Bit-exactness: kernel == twin == materialized flat decode (single tile)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hkv,rep,D", [
    (1, 2, 1, 64),      # B=1 solo serving, MHA
    (1, 2, 4, 64),      # GQA 4:1
    (4, 2, 2, 64),      # full scheduler slots
    (4, 4, 1, 32),      # the benchmark geometry: a 64-group spans 2 heads
    (2, 4, 2, 32),      # head-spanning groups + GQA
])
def test_single_tile_bit_exact(B, Hkv, rep, D):
    """One KV tile covering the cache: the recurrence IS the flat masked
    softmax — kernel, twin, and materialized-bf16 decode agree bitwise."""
    q, pk, pv, kd, vd = _setup(B, CAP, Hkv, rep, D)
    length = jnp.asarray((LENGTHS * B)[:B], jnp.int32)
    flat = decode_attention(q, kd, vd, length)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D, block_kv=CAP)
    kern = fused_decode_attention(q, pk, pv, length, n_kv_heads=Hkv,
                                  d_head=D, block_kv=CAP, interpret=True)
    np.testing.assert_array_equal(np.asarray(twin), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(flat))


@pytest.mark.parametrize("length", LENGTHS)
def test_lengths_exercise_tile_mask_bit_exact(length):
    """Every boundary length (S=1, 63, 64, 65, capacity-1) on the
    single-tile anchor, B=1."""
    q, pk, pv, kd, vd = _setup(1, CAP, 2, 2, 64, seed=length)
    lv = jnp.asarray([length], jnp.int32)
    flat = decode_attention(q, kd, vd, lv)
    twin = fused_decode_attention_xla(q, pk, pv, lv, 2, 64, block_kv=CAP)
    kern = fused_decode_attention(q, pk, pv, lv, n_kv_heads=2, d_head=64,
                                  block_kv=CAP, interpret=True)
    np.testing.assert_array_equal(np.asarray(twin), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(flat))


@pytest.mark.parametrize("block", [32, 64])
def test_multi_tile_kernel_equals_twin(block):
    """Tiled KV (the bounded-working-set regime): kernel and twin run the
    identical recurrence — bitwise — and reassociate the f32 sums vs the
    flat path by at most bf16-probability rounding."""
    B, Hkv, rep, D = 4, 2, 2, 64
    q, pk, pv, kd, vd = _setup(B, CAP, Hkv, rep, D, seed=7)
    length = jnp.asarray(LENGTHS[1:], jnp.int32)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D,
                                      block_kv=block)
    kern = fused_decode_attention(q, pk, pv, length, n_kv_heads=Hkv,
                                  d_head=D, block_kv=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(twin))
    flat = decode_attention(q, kd, vd, length)
    np.testing.assert_allclose(
        np.asarray(twin, jnp.float32), np.asarray(flat, jnp.float32),
        rtol=0.01, atol=0.005)


def test_staging_tail_twin_bit_exact():
    """F % 64 != 0 (d_head=24, Hkv=3 -> G=1, T=8): the kernel cannot tile
    the bf16 staging tail, but the twin must still be bitwise identical to
    the materialized flat decode — tail features return bit-identical."""
    B, Hkv, rep, D = 2, 3, 2, 24
    q, pk, pv, kd, vd = _setup(B, 64, Hkv, rep, D, seed=3)
    assert pk["tail"].shape[-2] == 8                 # kernel layout (B, T, S)
    assert not kernel_compatible(pk, Hkv, D)
    length = jnp.asarray([64, 33], jnp.int32)
    flat = decode_attention(q, kd, vd, length)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D, block_kv=64)
    np.testing.assert_array_equal(np.asarray(twin), np.asarray(flat))


def test_artifact_layout_twin_matches_kernel_layout():
    """The twin serves either cache layout; the layouts carry the same
    bits, so the outputs are bitwise identical."""
    B, Hkv, rep, D = 2, 2, 2, 64
    q, pk, pv, _, _ = _setup(B, 64, Hkv, rep, D, kernel_layout=False)
    assert not kvcache.is_kernel_layout(pk)
    length = jnp.asarray([64, 17], jnp.int32)
    art = fused_decode_attention_xla(q, pk, pv, length, Hkv, D, block_kv=32)
    kl = fused_decode_attention_xla(
        q, kvcache.to_kernel_layout(pk), kvcache.to_kernel_layout(pv),
        length, Hkv, D, block_kv=32)
    np.testing.assert_array_equal(np.asarray(art), np.asarray(kl))


def test_nan_codes_propagate_on_every_path():
    """E6M2 0xFF metadata (never produced, but corrupted bits must decode
    identically everywhere) poisons the poisoned head's output to NaN on
    kernel, twin, and the materialized flat path alike — and leaves other
    batch rows untouched."""
    B, Hkv, rep, D = 2, 2, 2, 64
    q, pk, pv, _, _ = _setup(B, 64, Hkv, rep, D, seed=11)
    # poison one valid token's group metadata in K, batch row 0, head 0
    meta = pk["meta"]                                # (B, G, S), G = Hkv*D/64
    pk = dict(pk, meta=meta.at[0, 0, 3].set(jnp.uint32(0xFF) << 24))
    kd = kvcache.dequantize_kv(pk, Hkv, D)
    vd = kvcache.dequantize_kv(pv, Hkv, D)
    length = jnp.full((B,), 64, jnp.int32)
    flat = decode_attention(q, kd, vd, length)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D, block_kv=64)
    kern = fused_decode_attention(q, pk, pv, length, n_kv_heads=Hkv,
                                  d_head=D, block_kv=64, interpret=True)
    flat_np = np.asarray(flat, jnp.float32)
    assert np.isnan(flat_np[0, :rep]).all()          # head 0 of row 0 poisoned
    assert np.isfinite(flat_np[1]).all()             # row 1 untouched
    # compare in f32: numpy's NaN-position equality does not engage for the
    # ml_dtypes bfloat16 dtype (NaN == NaN would count as a mismatch)
    np.testing.assert_array_equal(np.asarray(twin, jnp.float32), flat_np)
    np.testing.assert_array_equal(np.asarray(kern, jnp.float32), flat_np)
    # ...and masked-out NaN tokens must NOT poison anything
    pk_masked_len = jnp.asarray([3, 64], jnp.int32)  # token 3 now invalid
    out = fused_decode_attention_xla(q, pk, pv, pk_masked_len, Hkv, D,
                                     block_kv=64)
    assert np.isfinite(np.asarray(out, jnp.float32)).all()


def test_nonfused_fallback_matches_twin_tolerance():
    """decode_attention_packed (the models-level bounded fallback, vec-q
    recurrence) stays float-close to the twin: same quantized values,
    different online-softmax association."""
    B, Hkv, rep, D = 2, 2, 2, 64
    q, pk, pv, _, _ = _setup(B, 64, Hkv, rep, D, seed=5)
    length = jnp.asarray([64, 20], jnp.int32)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D)
    fb = decode_attention_packed(q, pk, pv, length, Hkv, D)
    np.testing.assert_allclose(np.asarray(fb, jnp.float32),
                               np.asarray(twin, jnp.float32),
                               rtol=0.01, atol=0.005)


# ---------------------------------------------------------------------------
# Engine dispatch: each (impl x backend x kv_format) cell
# ---------------------------------------------------------------------------


def test_attention_dispatch_matrix():
    """Each (impl x backend x cache geometry) cell lands on the intended
    path: the Pallas kernel ONLY for impl packed/pallas on a
    kernel-tileable cache on TPU; the XLA twin everywhere else."""
    _, pk, _, _, _ = _setup(1, 64, 2, 2, 64)
    _, pk_tail, _, _, _ = _setup(1, 64, 3, 2, 24)    # staging tail
    cases = [
        # (impl, cache, interpret(off-TPU), expect_fused)
        ("packed", pk, False, True),
        ("pallas", pk, False, True),
        ("packed", pk, True, False),                 # off-TPU -> twin
        ("pallas", pk, True, False),
        ("qdq", pk, False, False),                   # qdq impl -> twin
        ("packed", pk_tail, False, False),           # staging tail -> twin
    ]
    for impl, cache, interpret, want in cases:
        hkv, dh = (2, 64) if cache is pk else (3, 24)
        info = engine.attention_dispatch_info(
            QuantConfig(fmt="hif4", impl=impl), cache,
            n_kv_heads=hkv, d_head=dh, interpret=interpret)
        assert info["fused"] == want, (impl, interpret, info)
        assert info["block_kv"] == select_kv_block(64)
    # artifact layout is twin-only even on TPU
    _, art, _, _, _ = _setup(1, 64, 2, 2, 64, kernel_layout=False)
    info = engine.attention_dispatch_info(
        QuantConfig(fmt="hif4", impl="packed"), art,
        n_kv_heads=2, d_head=64, interpret=False)
    assert not info["fused"] and "artifact layout" in info["execution"]
    # ...and each twin reason names its actual cause (the launcher print)
    info = engine.attention_dispatch_info(
        QuantConfig(fmt="hif4", impl="qdq"), pk,
        n_kv_heads=2, d_head=64, interpret=False)
    assert "impl=qdq" in info["execution"]
    info = engine.attention_dispatch_info(
        QuantConfig(fmt="hif4", impl="packed"), pk_tail,
        n_kv_heads=3, d_head=24, interpret=False)
    assert "staging tail" in info["execution"]


def test_engine_attention_decode_runs_twin_off_tpu():
    """engine.attention_decode (what attn_decode dispatches to) must equal
    the twin bitwise off-TPU, for every impl."""
    B, Hkv, rep, D = 2, 2, 2, 64
    q, pk, pv, _, _ = _setup(B, 64, Hkv, rep, D, seed=9)
    length = jnp.asarray([64, 12], jnp.int32)
    want = fused_decode_attention_xla(q, pk, pv, length, Hkv, D)
    for impl in ("qdq", "packed", "pallas"):
        got = engine.attention_decode(
            q, pk, pv, length, Hkv, D,
            engine.EngineCtx(quant=QuantConfig(fmt="hif4", impl=impl)))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_attn_decode_bf16_path_untouched(monkeypatch):
    """bf16 caches never reach the packed dispatch: attn_decode keeps the
    dense decode_attention path byte-for-byte; packed caches always route
    through engine.attention_decode."""
    from repro.configs import get_arch
    from repro.models import lm, transformer as tf
    from repro.models.common import ModelCtx

    cfg = get_arch("qwen1.5-0.5b").reduced()
    ctx = ModelCtx(quant=QuantConfig(fmt="hif4", impl="packed"), remat=False,
                   attn_q_chunk=32, attn_k_chunk=32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    _, cache = lm.prefill(params, {"tokens": tokens}, cfg, ctx)
    cache_bf = lm.pad_cache(cache, cfg, 12)
    cache_pk = lm.pad_cache(lm.quantize_kv_cache(cache, cfg), cfg, 12)

    calls = []
    real = engine.attention_decode

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(tf.qengine, "attention_decode", spy)
    tok = jnp.zeros((2,), jnp.int32)
    lm.decode_step(params, tok, {"kv": cache_bf["kv"], "pos": cache_bf["pos"]},
                   cfg, ctx)
    assert not calls                                 # bf16: never dispatched
    lm.decode_step(params, tok, {"kv": cache_pk["kv"], "pos": cache_pk["pos"]},
                   cfg, ctx)
    assert len(calls) == 1                           # packed: dispatched (the
    #                                                  layer loop is one scan
    #                                                  trace, so one call)


# ---------------------------------------------------------------------------
# Tiling / geometry helpers
# ---------------------------------------------------------------------------


def test_select_kv_block_regimes():
    assert select_kv_block(64) == 64                 # whole cache, one tile
    assert select_kv_block(256) == 256
    assert select_kv_block(1024) == 256              # stream 256-slot tiles
    assert select_kv_block(96) == 96
    for s in (24, 63, 100, 640, 509, 1018):
        assert s % select_kv_block(s) == 0           # tiles hold whole slots
    # awkward capacities must not degrade to 1-token tile storms: a prime
    # capacity takes one whole-cache tile, 2x a prime takes two tiles
    assert select_kv_block(509) == 509
    assert select_kv_block(1018) == 509
    assert select_kv_block(514) == 257               # 2 x 257: 2 is degenerate


def test_awkward_capacity_all_paths():
    """A prime cache capacity (no divisor near the tile target) must still
    serve on every path — twin, kernel, and the models-level fallback —
    and stay bitwise vs the flat path (whole-cache single tile)."""
    B, S, Hkv, rep, D = 1, 131, 2, 2, 64             # 131 prime > tail of 128
    q, pk, pv, kd, vd = _setup(B, S, Hkv, rep, D, seed=13)
    length = jnp.asarray([S - 1], jnp.int32)
    flat = decode_attention(q, kd, vd, length)
    twin = fused_decode_attention_xla(q, pk, pv, length, Hkv, D)
    kern = fused_decode_attention(q, pk, pv, length, n_kv_heads=Hkv,
                                  d_head=D, interpret=True)
    fb = decode_attention_packed(q, pk, pv, length, Hkv, D)
    np.testing.assert_array_equal(np.asarray(twin), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(flat))
    np.testing.assert_allclose(np.asarray(fb, jnp.float32),
                               np.asarray(flat, jnp.float32),
                               rtol=0.01, atol=0.005)


def test_heads_per_block_alignment():
    assert heads_per_block(64) == 1
    assert heads_per_block(128) == 1
    assert heads_per_block(32) == 2                  # a 64-group spans 2 heads
    assert heads_per_block(16) == 4
    # kernel_compatible needs head blocks to divide the head count — which
    # a tail-free F implies; an odd head count at d_head=32 always carries
    # a staging tail, so it is twin-routed either way
    _, pk, _, _, _ = _setup(1, 64, 4, 1, 32)
    assert kernel_compatible(pk, 4, 32)
    _, pk3, _, _, _ = _setup(1, 64, 3, 1, 32)        # F = 96: G=1, T=32
    assert pk3["tail"].shape[-2] == 32
    assert not kernel_compatible(pk3, 3, 32)
