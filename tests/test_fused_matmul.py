"""Fused dequantize-in-kernel packed matmul: bit-exactness and dispatch.

The serving claim (docs/EXECUTION.md): consuming the 4.5-bit PackedW
payload directly inside the kernel changes WHERE the bits expand, never
what is computed. So across the serving shape matrix — decode M=1..4,
prefill M >= 256, non-square N, stacked-layer weights — the fused kernel
(interpret mode, runs in tier-1 CI on CPU) must be bitwise identical to

  * its straight-line XLA twin (what the engine serves off-TPU),
  * materializing the absorbed-int operand first and running the plain
    quantized kernel (``packed_to_absorbed`` + ``bfp_matmul_quantized``),

and float-close (f32 rounding only) to ``PackedW.dequantize()`` + dense
f32 dot — the dequantize reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, hif4
from repro.core.qlinear import PackedW, QuantConfig
from repro.kernels import ref
from repro.kernels.bfp_matmul import (
    GROUP,
    K_GRID_AXIS,
    bfp_matmul_quantized,
    select_block_sizes,
)
from repro.kernels.fused_matmul import (
    absorbed_activation,
    fused_packed_matmul,
    fused_packed_matmul_xla,
)

# decode (M=1..4), prefill (M>=256), non-square N, odd group counts
SHAPES = [
    (1, 128, 96),      # decode, single request
    (2, 192, 64),      # decode, K = 3 groups
    (4, 256, 160),     # decode, the benchmark batch
    (3, 128, 256),     # decode, N > K
    (256, 256, 128),   # prefill
    (320, 128, 96),    # prefill, M not a power of two
]


def _packed(k, n, seed=0):
    w = (jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.05).astype(
        jnp.bfloat16)
    return w, PackedW.from_dense(w, (0,))


def _activation(m, k, seed=1):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * 0.1).astype(
        jnp.bfloat16)
    return x, absorbed_activation(x)


# ---------------------------------------------------------------------------
# Layout round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", SHAPES[:3])
def test_kernel_layout_same_bits_same_values(m, k, n):
    """K-major re-layout preserves payload size, value grid, and bytes."""
    _, pw = _packed(k, n)
    kw = pw.to_kernel_layout()
    assert kw.kernel_layout and kw.codes.shape == (k // 2, n)
    assert kw.meta.shape == (k // GROUP, n)
    assert kw.nbytes_packed == pw.nbytes_packed
    assert kw.n_values == pw.n_values == k * n
    np.testing.assert_array_equal(np.asarray(kw.dequantize()),
                                  np.asarray(pw.dequantize()))
    # idempotent
    assert kw.to_kernel_layout() is kw


def test_expand_meta_km_scale_parity_all_codes():
    """Every E6M2 code — the full [-48, 15] exponent range and the NaN
    pattern 0xFF — must decode on the kernel-tile path exactly like the
    artifact path (rounding.decode_e6m2). Catches both a dropped NaN and
    any approximate power-of-two construction (jnp.exp2 is NOT exact
    across this range)."""
    from repro.core import rounding as R

    codes = jnp.arange(256, dtype=jnp.uint32)
    _, scale = hif4.expand_meta_km((codes << 24).reshape(1, -1))
    ref_scale = R.decode_e6m2(codes.astype(jnp.uint8)) * 0.25
    np.testing.assert_array_equal(np.asarray(scale)[0],
                                  np.asarray(ref_scale))


def test_absorbed_int_km_matches_unpack_path():
    """The in-kernel bit helpers == unpack_groups + to_absorbed_int."""
    _, pw = _packed(256, 96)
    codes_km, meta_km = pw.kernel_operands()
    ints, scale = hif4.absorbed_int_km(codes_km, meta_km)
    g = hif4.unpack_groups(hif4.HiF4Packed(pw.codes, pw.meta))
    ints_ref, scale_ref = hif4.to_absorbed_int(g)           # (n, k/64, 64)
    np.testing.assert_array_equal(np.asarray(ints),
                                  np.asarray(ints_ref.reshape(96, 256).T))
    np.testing.assert_array_equal(
        np.asarray(scale), np.asarray(scale_ref.astype(jnp.float32).T))


# ---------------------------------------------------------------------------
# Bit-exactness across the shape matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_fused_kernel_bit_exact(m, k, n):
    _, pw = _packed(k, n, seed=m)
    _, (ai, asc) = _activation(m, k, seed=m + 1)
    codes_km, meta_km = pw.kernel_operands()

    # single K-step so kernel/twin/materialized share one group reduction
    got = fused_packed_matmul(ai, asc, codes_km, meta_km, block_k=k,
                              interpret=True)
    twin = fused_packed_matmul_xla(ai, asc, codes_km, meta_km)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(twin))

    wi, wsc = engine.packed_to_absorbed(pw)
    materialized = bfp_matmul_quantized(ai, asc, wi, wsc, block_k=k,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(materialized))

    # dequantize reference: float-close at f32 rounding (the flat f32 dot
    # associates the K reduction differently; values are identical)
    a_deq = ref.hif4_dequantize_ref(ai, asc)
    want = np.asarray(a_deq) @ np.asarray(pw.dequantize().astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_fused_kernel_multi_k_step():
    """Tiled K accumulation (the revisit pattern) stays float-identical in
    value to the single-step contraction."""
    m, k, n = 8, 512, 96
    _, pw = _packed(k, n)
    _, (ai, asc) = _activation(m, k)
    codes_km, meta_km = pw.kernel_operands()
    one = fused_packed_matmul(ai, asc, codes_km, meta_km, block_k=k,
                              interpret=True)
    tiled = fused_packed_matmul(ai, asc, codes_km, meta_km, block_k=128,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(one),
                               rtol=1e-6, atol=1e-7)


def test_stacked_layer_weights_slice_like_scan():
    """A stacked kernel-layout PackedW sliced per layer (what lax.scan does
    to the pytree leaves) contracts exactly like packing that layer alone."""
    L, k, n, m = 3, 128, 96, 4
    ws = [(jax.random.normal(jax.random.PRNGKey(10 + i), (k, n)) * 0.05)
          .astype(jnp.bfloat16) for i in range(L)]
    per_layer = [PackedW.from_dense(w, (0,)).to_kernel_layout() for w in ws]
    stacked = PackedW(
        jnp.stack([p.codes for p in per_layer]),
        jnp.stack([p.meta for p in per_layer]),
        (k, n), jnp.bfloat16, (None, None), kernel_layout=True)
    x, (ai, asc) = _activation(m, k, seed=7)
    for i in range(L):
        layer = jax.tree_util.tree_map(lambda b, i=i: b[i], stacked)
        got = fused_packed_matmul_xla(ai, asc, *layer.kernel_operands())
        want = fused_packed_matmul_xla(ai, asc, *per_layer[i].kernel_operands())
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["packed", "pallas"])
@pytest.mark.parametrize("layout", ["artifact", "kernel"])
def test_engine_routes_packedw_to_fused(impl, layout):
    """impl=packed and impl=pallas on a PackedW both serve the fused
    contraction (off-TPU: the XLA twin), in either payload layout."""
    m, k, n = 4, 128, 96
    x, (ai, asc) = _activation(m, k)
    _, pw = _packed(k, n)
    if layout == "kernel":
        pw = pw.to_kernel_layout()
    got = engine.matmul(x, pw, engine.EngineCtx(
        quant=QuantConfig(fmt="hif4", impl=impl)))
    want = fused_packed_matmul_xla(ai, asc, *pw.kernel_operands())
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want.astype(jnp.bfloat16)))


def test_engine_fused_fallbacks_dequantize():
    """weights_only / non-HiF4 fmt / qdq impl cannot run the fused kernel
    (it inherently quantizes activations): they must take the
    dequantize-then-dot path unchanged."""
    m, k, n = 4, 128, 96
    x, _ = _activation(m, k)
    _, pw = _packed(k, n)
    for cfg in (QuantConfig(fmt="hif4", impl="packed", weights_only=True),
                QuantConfig(fmt="nvfp4", impl="packed"),
                QuantConfig(fmt="hif4", impl="qdq")):
        got = engine.matmul(x, pw, engine.EngineCtx(quant=cfg))
        wd = pw.dequantize()
        from repro.core.qlinear import quantize_activation
        xq = quantize_activation(x, cfg, axis=-1)
        want = jax.lax.dot_general(
            xq, wd, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=x.dtype).astype(x.dtype)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_xla_twin_caps_intermediate(monkeypatch):
    """Off-TPU, a contraction whose (K/64, M, N) batched-dot intermediate
    exceeds the cap must route to dequantize-then-dot (memory safety at
    large-M prefill), and stay numerically close to the fused result."""
    m, k, n = 16, 256, 96
    x, _ = _activation(m, k)
    _, pw = _packed(k, n)
    ectx = engine.EngineCtx(quant=QuantConfig(fmt="hif4", impl="packed"))
    fused = engine.matmul(x, pw, ectx)
    monkeypatch.setattr(engine, "_XLA_FUSED_PART_BYTES_MAX", 0)
    fallback = engine.matmul(x, pw, ectx)
    # fallback is the bf16-accumulated dequantize dot: same quantized
    # values, different accumulation — bf16-rounding close, and bitwise
    # equal to the explicit _packed_matmul path
    np.testing.assert_allclose(
        np.asarray(fallback, jnp.float32), np.asarray(fused, jnp.float32),
        rtol=0.02, atol=0.01)
    want = engine._packed_matmul(x, pw, ectx, contract_x=-1, accum_dtype=None)
    np.testing.assert_array_equal(np.asarray(fallback), np.asarray(want))


def test_packed_dispatch_info():
    _, pw = _packed(128, 96)
    q = QuantConfig(fmt="hif4", impl="packed")
    info = engine.packed_dispatch_info(q, pw, decode_m=4, prefill_m=128)
    assert info["fused"]
    # off-TPU: the XLA twin, no tiling to report
    assert "XLA" in info["execution"] and info["decode_blocks"] is None
    kernel = engine.packed_dispatch_info(q, pw, decode_m=4, prefill_m=128,
                                         interpret=False)
    assert kernel["fused"] and kernel["decode_blocks"] == (4, 96, 128)
    off = engine.packed_dispatch_info(
        QuantConfig(fmt="hif4", impl="qdq"), pw, decode_m=4, prefill_m=128)
    assert not off["fused"]


# ---------------------------------------------------------------------------
# Block-size selection
# ---------------------------------------------------------------------------


def test_select_block_sizes_regimes():
    # decode: whole M, wide N, deep K
    bm, bn, bk = select_block_sizes(4, 1024, 2048)
    assert bm == 4 and bn == 512 and bk == 1024
    # prefill: square-ish MXU tiles
    bm, bn, bk = select_block_sizes(512, 1024, 2048)
    assert bm == 256 and bn == 256 and bk == 512
    # everything divides and holds whole groups
    for m, n, k in [(1, 96, 128), (7, 160, 192), (300, 96, 448)]:
        bm, bn, bk = select_block_sizes(m, n, k)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bk % GROUP == 0


def test_k_axis_is_innermost_grid_axis():
    """The accumulator-revisit invariant the kernels assert: K must be the
    last grid axis so consecutive steps revisit one output tile."""
    assert K_GRID_AXIS == 2  # grid is (M/bm, N/bn, K/bk)
    # and a multi-K-step quantized matmul is numerically right (the revisit
    # pattern actually accumulates rather than overwrites)
    m, k, n = 8, 512, 32
    x = (jax.random.normal(jax.random.PRNGKey(2), (m, k)) * 0.1).astype(
        jnp.float32)
    w = (jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.1).astype(
        jnp.float32)
    ai, asc = ref.hif4_quantize_ref(x)
    bi, bsc = ref.hif4_quantize_ref(jnp.asarray(w).T)
    got = bfp_matmul_quantized(ai, asc, bi.T, bsc.T, block_m=8, block_n=16,
                               block_k=128, interpret=True)
    want = ref.bfp_matmul_from_quantized_ref(ai, asc, bi.T, bsc.T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
