"""Unit tests for the guard layer (repro.runtime.guard): typed serving
exceptions, artifact integrity checksums, the device-side sentinels the
guarded decode scan fuses in, and preemption-snapshot fingerprints.

End-to-end fault detection/containment through the schedulers lives in
tests/test_faults.py (marked ``faults``); these tests pin each detector
in isolation so a fault-suite failure localizes immediately."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import hif4, kvcache
from repro.core.policy import get_policy
from repro.core.qlinear import PackedW, QuantConfig
from repro.models import lm
from repro.runtime import guard
from repro.runtime.serve_loop import (
    load_serving_artifact,
    save_serving_artifact,
)

CFG = get_arch("qwen1.5-0.5b").reduced()


# ---------------------------------------------------------------------------
# Typed exceptions
# ---------------------------------------------------------------------------


def test_exception_hierarchy():
    """Every typed serving error funnels through ServeError, which stays a
    RuntimeError so pre-existing handlers keep working."""
    for exc in (guard.PoolExhaustedError, guard.SnapshotIntegrityError,
                guard.ArtifactError):
        assert issubclass(exc, guard.ServeError)
    for exc in (guard.ArtifactNotFoundError, guard.ArtifactLayoutError,
                guard.ArtifactIntegrityError):
        assert issubclass(exc, guard.ArtifactError)
    assert issubclass(guard.ServeError, RuntimeError)


def test_load_missing_artifact_is_typed(tmp_path):
    with pytest.raises(guard.ArtifactNotFoundError, match="no serving"):
        load_serving_artifact(str(tmp_path / "nope"), CFG)


def test_save_packed_tree_is_typed(tmp_path):
    """save_serving_artifact must refuse an already-packed tree with the
    typed layout error (kernel layout has no inverse), not a bare assert."""
    from repro.runtime.serve_loop import prepare_params_for_serving

    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    policy = get_policy("uniform:hif4", impl="packed",
                        kv=kvcache.KVCacheConfig("hif4"))
    packed = prepare_params_for_serving(params, CFG, policy)
    with pytest.raises(guard.ArtifactLayoutError, match="already-packed"):
        save_serving_artifact(str(tmp_path / "art"), packed, CFG, policy)


# ---------------------------------------------------------------------------
# Artifact integrity (per-leaf sha256 + format invariants)
# ---------------------------------------------------------------------------


def _packed_leaf(seed=0, k=128, n=8):
    w = (jax.random.normal(jax.random.PRNGKey(seed), (k, n))
         * 0.3).astype(jnp.bfloat16)
    return PackedW.from_dense(w)


def test_artifact_integrity_roundtrip_and_corruption():
    tree = {"a": _packed_leaf(0), "b": _packed_leaf(1)}
    rec = guard.artifact_integrity(tree)
    assert rec["version"] == guard.INTEGRITY_VERSION
    assert len(rec["leaves"]) == 2
    guard.verify_artifact_integrity(tree, rec, "mem")   # clean: no raise

    # one flipped bit in one codes byte fails that leaf's sha256
    leaf = tree["a"]
    codes = np.array(leaf.codes, copy=True)
    codes.reshape(-1)[7] ^= np.uint8(1 << 3)
    bad = dict(tree, a=dataclasses.replace(leaf, codes=jnp.asarray(codes)))
    with pytest.raises(guard.ArtifactIntegrityError, match="codes_sha256"):
        guard.verify_artifact_integrity(bad, rec, "mem")

    # a leaf with no recorded checksum is an error too (tampered manifest)
    with pytest.raises(guard.ArtifactIntegrityError, match="no integrity"):
        guard.verify_artifact_integrity(
            tree, {"version": 1, "leaves": {}}, "mem")


def test_packed_invariants_catch_meta_nan():
    """Algorithm 1 never emits the 0xFF E6M2 sentinel, so its presence in
    an artifact is flagged even WITHOUT a recorded checksum."""
    leaf = _packed_leaf(2)
    assert guard.packed_invariants("w", leaf) == []
    meta = np.array(leaf.meta, copy=True)
    meta.reshape(-1)[0] |= np.uint32(0xFF << 24)
    poisoned = dataclasses.replace(leaf, meta=jnp.asarray(meta))
    errs = guard.packed_invariants("w", poisoned)
    assert errs and "NaN sentinel" in errs[0]


@pytest.mark.slow
def test_serving_artifact_save_load_verifies(tmp_path):
    """End-to-end: the exported artifact carries the integrity block and a
    byte flipped in a stored packed payload fails the load loudly."""
    import json
    import os

    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    policy = get_policy("uniform:hif4", impl="packed",
                        kv=kvcache.KVCacheConfig("hif4"))
    directory = str(tmp_path / "artifact")
    save_serving_artifact(directory, params, CFG, policy)
    loaded, pol = load_serving_artifact(directory, CFG)   # clean: verifies
    assert pol.name == policy.name

    step_dir = os.path.join(directory, "step_00000000")
    with open(os.path.join(step_dir, "extra.json")) as f:
        extra = json.load(f)
    assert extra["integrity"]["leaves"], "no packed leaves recorded"
    # corrupt the stored payloads on disk (arrays are opaque arr_NNNNN.npy
    # blobs; flipping the tail byte of each guarantees a packed codes/meta
    # buffer took a hit without decoding the manifest's tree layout)
    for fn in sorted(os.listdir(step_dir)):
        if fn.startswith("arr_") and fn.endswith(".npy"):
            path = os.path.join(step_dir, fn)
            blob = bytearray(open(path, "rb").read())
            blob[-1] ^= 0x10             # payload tail, clear of the header
            open(path, "wb").write(bytes(blob))
    with pytest.raises(guard.ArtifactIntegrityError):
        load_serving_artifact(directory, CFG)


# ---------------------------------------------------------------------------
# Device-side sentinels
# ---------------------------------------------------------------------------


def test_bad_logits_flags_only_poisoned_slots():
    lg = jnp.zeros((3, 16), jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(guard.bad_logits(lg)),
                                  [False, False, False])
    lg = lg.at[1, 5].set(jnp.nan)
    lg = lg.at[2, 0].set(jnp.inf)
    np.testing.assert_array_equal(np.asarray(guard.bad_logits(lg)),
                                  [False, True, True])


def _contiguous_packed_kv(B=2, S=24, Hkv=2, Dh=64, seed=0):
    def one(s):
        kv = (jax.random.normal(jax.random.PRNGKey(s), (1, B, S, Hkv, Dh))
              * 0.3).astype(jnp.bfloat16)
        return kvcache.to_kernel_layout(kvcache.quantize_kv(kv))
    return {"k": one(seed), "v": one(seed + 1)}


def test_slot_meta_nan_counts_localize_to_slot():
    kv = _contiguous_packed_kv()
    counts = np.asarray(guard.slot_meta_nan_counts(kv))
    np.testing.assert_array_equal(counts, [0, 0])     # Alg. 1 never emits
    k = dict(kv["k"])
    k["meta"] = k["meta"].at[0, 1, 0, 3].set(jnp.uint32(0xFF << 24))
    counts = np.asarray(guard.slot_meta_nan_counts({"k": k, "v": kv["v"]}))
    np.testing.assert_array_equal(counts, [0, 1])     # only slot 1 flagged


@pytest.mark.parametrize("leaf,bit", [("codes", 0), ("codes", 7),
                                      ("meta", 0), ("meta", 31)])
def test_page_checksum_catches_any_single_bit(leaf, bit):
    """The detection guarantee behind the per-chunk audit: ONE flipped bit
    anywhere in a page changes that page's checksum and no other's —
    including low codes bits that perturb values silently (finite, no NaN),
    which no other sentinel can see."""
    pool = kvcache.init_page_pool(1, 2, 64, 5, 8)
    # non-trivial contents: scatter a quantized block into page 2
    kv = (jax.random.normal(jax.random.PRNGKey(3), (1, 1, 8, 2, 64))
          * 0.3).astype(jnp.bfloat16)
    pk = kvcache.split_pages(kvcache.to_kernel_layout(kvcache.quantize_kv(kv)),
                             8)
    k = {key: pool["k"][key].at[:, 2].set(a[:, 0])
         for key, a in pk.items()}
    before = np.asarray(guard.pool_page_sums({"k": k, "v": pool["v"]}))
    flipped = dict(k)
    one = jnp.asarray(1 << bit, flipped[leaf].dtype)
    flipped[leaf] = flipped[leaf].at[0, 2, 1, 4].set(
        flipped[leaf][0, 2, 1, 4] ^ one)
    after = np.asarray(guard.pool_page_sums({"k": flipped, "v": pool["v"]}))
    assert after[2] != before[2]
    mask = np.ones(5, bool)
    mask[2] = False
    np.testing.assert_array_equal(after[mask], before[mask])


def test_pool_page_stats_flags_nan_page():
    pool = kvcache.init_page_pool(1, 2, 64, 4, 8)
    k = dict(pool["k"])
    k["meta"] = k["meta"].at[0, 3, 0, 0].set(jnp.uint32(hif4.META_NAN << 24))
    stats = guard.pool_page_stats({"k": k, "v": pool["v"]})
    np.testing.assert_array_equal(np.asarray(stats["meta_nan"]),
                                  [0, 0, 0, 1])


@pytest.mark.parametrize("bit", range(32))
def test_meta_bit_flip_nan_or_group_local_exhaustive(bit):
    """Deterministic twin of the Hypothesis property in
    tests/test_hif4_properties.py (which skips when hypothesis is absent):
    every one of the 32 meta bits, flipped in one group, either poisons
    that group with NaN (E6M2 became 0xFF) or perturbs only that group —
    all other groups decode bitwise identically on both decode paths."""
    n, g = 3, 1
    x = np.asarray((jax.random.normal(jax.random.PRNGKey(9),
                                      (n, hif4.GROUP_SIZE)) * 0.3)
                   .astype(jnp.float32))
    p = hif4.quantize_packed(jnp.asarray(x))
    meta = np.asarray(p.meta).copy()
    meta[g] ^= np.uint32(1 << bit)
    bad = hif4.HiF4Packed(codes=p.codes, meta=jnp.asarray(meta))

    clean_pk = np.asarray(hif4.dequantize_packed(p), np.float32)
    flip_pk = np.asarray(hif4.dequantize_packed(bad), np.float32)
    codes_km = jnp.asarray(np.asarray(p.codes).reshape(n * 32, 1))
    flip_km = np.asarray(hif4.dequantize_km(
        codes_km, jnp.asarray(meta.reshape(n, 1)),
        dtype=jnp.float32)).reshape(n, hif4.GROUP_SIZE)

    for flip in (flip_pk, flip_km):
        others = np.ones(n, bool)
        others[g] = False
        np.testing.assert_array_equal(flip[others], clean_pk[others])
        if (meta[g] >> 24) == hif4.META_NAN:
            assert np.all(np.isnan(flip[g]))
        else:
            assert np.all(np.isfinite(flip[g]))


# ---------------------------------------------------------------------------
# Preemption-snapshot fingerprints
# ---------------------------------------------------------------------------


def _snapshot(seed=0):
    rng = np.random.default_rng(seed)
    pages = {}
    for t in ("k", "v"):
        pages[t] = {
            "codes": rng.integers(0, 256, (1, 2, 64, 8), dtype=np.uint8),
            "meta": rng.integers(0, 2**32, (1, 2, 2, 8), dtype=np.uint32),
            "tail": np.zeros((1, 2, 0, 8), np.float32),
        }
    return pages


def test_snapshot_fingerprint_detects_flip_and_truncation():
    pages = _snapshot()
    crc = guard.snapshot_fingerprint(pages)
    assert guard.snapshot_fingerprint(_snapshot()) == crc   # deterministic
    snap = {"pages": pages, "crc32": crc}
    assert guard.verify_snapshot(snap)

    flipped = _snapshot()
    flipped["k"]["codes"][0, 1, 3, 2] ^= np.uint8(1)
    assert not guard.verify_snapshot({"pages": flipped, "crc32": crc})

    truncated = {t: {key: a[:, :-1] for key, a in leaves.items()}
                 for t, leaves in _snapshot().items()}
    assert not guard.verify_snapshot({"pages": truncated, "crc32": crc})
    # mangled structure is "corrupt", not a crash
    assert not guard.verify_snapshot({"pages": {"k": {}}, "crc32": crc})
