"""Hypothesis property tests for repro.core.hif4 round-trip invariants.

Randomized shapes / magnitudes / group boundaries pin the properties the
scenario matrix and the packed serving stack rest on: exact power-of-two
group scales (scale equivariance), 0xFF-metadata NaN propagation through
EVERY decode path, bit-level pack/unpack idempotence, and bulk-pack ==
token-at-a-time append for the KV cache. Deterministic ci profile, same
importorskip guards as the tier-1 hypothesis tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import hif4, kvcache
from repro.core import rounding as R

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=50, derandomize=True)
hypothesis.settings.load_profile("ci")


@st.composite
def group_batches(draw, min_scale=-20, max_scale=8):
    """(n, 64) f32 arrays on the bf16 grid, group magnitudes randomized
    across power-of-two decades (well inside the E6M2 scale range)."""
    n = draw(st.integers(min_value=1, max_value=4))
    scale = 2.0 ** draw(st.integers(min_value=min_scale, max_value=max_scale))
    arr = draw(hnp.arrays(
        np.float32, (n, hif4.GROUP_SIZE),
        elements=st.floats(min_value=-4.0, max_value=4.0, width=32)))
    x = jnp.asarray(arr * scale, jnp.bfloat16).astype(jnp.float32)
    return np.asarray(x)


@hypothesis.given(group_batches())
def test_group_scale_is_exactly_on_e6m2_grid(x):
    """The group scale Algorithm 1 emits lives EXACTLY on the E6M2 grid
    (power-of-two times {1, 1.25, 1.5, 1.75}): encoding and decoding it
    is bitwise lossless, so the packed artifact loses nothing."""
    g = hif4.quantize_groups(jnp.asarray(x))
    rt = R.decode_e6m2(R.encode_e6m2(g.e6m2))
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(g.e6m2))


@hypothesis.given(group_batches(min_scale=-10, max_scale=4),
                  st.integers(min_value=-4, max_value=4))
def test_power_of_two_scaling_equivariance(x, k):
    """Scaling a group by 2^k shifts only the (exact power-of-two) scale:
    the reconstruction scales by exactly 2^k, bitwise — the property that
    makes HiF4 payload bytes an exact roofline numerator regardless of
    tensor magnitude."""
    vm = np.abs(x).max(axis=-1)
    hypothesis.assume(bool(np.all((vm == 0) | (vm >= 2.0 ** -16))))
    base = hif4.dequantize_groups(hif4.quantize_groups(jnp.asarray(x)))
    scaled = hif4.dequantize_groups(
        hif4.quantize_groups(jnp.asarray(x * 2.0 ** k)))
    np.testing.assert_array_equal(
        np.asarray(scaled), np.asarray(base) * 2.0 ** k)


@hypothesis.given(group_batches())
def test_pack_unpack_is_bitwise_idempotent(x):
    """unpack(pack(g)) == g on every component, and re-packing reproduces
    the identical 4.5-bit artifact — the packed bytes are a lossless
    encoding of the quantized value."""
    g = hif4.quantize_groups(jnp.asarray(x))
    p = hif4.pack_groups(g)
    g2 = hif4.unpack_groups(p)
    for a, b in zip(g, g2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p2 = hif4.pack_groups(g2)
    np.testing.assert_array_equal(np.asarray(p.codes), np.asarray(p2.codes))
    np.testing.assert_array_equal(np.asarray(p.meta), np.asarray(p2.meta))


@hypothesis.given(group_batches())
def test_corrupt_meta_nan_propagates_every_path(x):
    """E6M2 code 0xFF decodes to NaN on EVERY path — artifact-layout
    unpack, packed dequantize, and all three K-major kernel-tile helpers.
    Corrupted metadata must poison the whole group loudly, never decode
    to silently-wrong values."""
    n = x.shape[0]
    p = hif4.quantize_packed(jnp.asarray(x))
    bad_meta = (p.meta & jnp.uint32(0x00FFFFFF)) | jnp.uint32(0xFF << 24)
    bad = hif4.HiF4Packed(codes=p.codes, meta=bad_meta)

    assert np.all(np.isnan(np.asarray(hif4.unpack_groups(bad).e6m2)))
    assert np.all(np.isnan(
        np.asarray(hif4.dequantize_packed(bad), np.float32)))

    # K-major kernel-tile layout: one column per group row
    codes_km = jnp.asarray(np.asarray(p.codes).reshape(n * 32, 1))
    meta_km = jnp.asarray(np.asarray(bad_meta).reshape(n, 1))
    _, scale = hif4.expand_meta_km(meta_km)
    assert np.all(np.isnan(np.asarray(scale)))
    _, scale_abs = hif4.absorbed_int_km(codes_km, meta_km)
    assert np.all(np.isnan(np.asarray(scale_abs)))
    deq = hif4.dequantize_km(codes_km, meta_km, dtype=jnp.float32)
    assert np.all(np.isnan(np.asarray(deq)))


@hypothesis.given(group_batches(),
                  st.integers(min_value=0, max_value=31),
                  st.data())
def test_single_meta_bit_flip_is_nan_or_group_local(x, bit, data):
    """The corruption-semantics contract (docs/FORMATS.md): flip ANY
    single bit of ANY packed meta word and the decode either goes NaN
    (the E6M2 byte became the 0xFF sentinel) or perturbs ONLY that
    64-element group — every other group decodes bitwise identically, on
    the artifact path (dequantize_packed) and the K-major kernel path
    (dequantize_km) alike. This locality is what makes quarantining the
    owning request a complete containment."""
    n = x.shape[0]
    g = data.draw(st.integers(min_value=0, max_value=n - 1), label="group")
    p = hif4.quantize_packed(jnp.asarray(x))
    meta = np.asarray(p.meta).copy()
    meta[g] ^= np.uint32(1 << bit)
    bad = hif4.HiF4Packed(codes=p.codes, meta=jnp.asarray(meta))

    clean_pk = np.asarray(hif4.dequantize_packed(p), np.float32)
    flip_pk = np.asarray(hif4.dequantize_packed(bad), np.float32)
    codes_km = jnp.asarray(np.asarray(p.codes).reshape(n * 32, 1))
    clean_km = np.asarray(hif4.dequantize_km(
        codes_km, jnp.asarray(np.asarray(p.meta).reshape(n, 1)),
        dtype=jnp.float32)).reshape(n, hif4.GROUP_SIZE)
    flip_km = np.asarray(hif4.dequantize_km(
        codes_km, jnp.asarray(meta.reshape(n, 1)),
        dtype=jnp.float32)).reshape(n, hif4.GROUP_SIZE)
    np.testing.assert_array_equal(clean_km, clean_pk)   # paths agree clean

    for flip, clean in ((flip_pk, clean_pk), (flip_km, clean_km)):
        others = np.ones(n, bool)
        others[g] = False
        # blast radius: every OTHER group is bitwise untouched
        np.testing.assert_array_equal(flip[others], clean[others])
        if (meta[g] >> 24) == hif4.META_NAN:
            # NaN sentinel: the whole group poisons loudly
            assert np.all(np.isnan(flip[g]))
        else:
            assert np.all(np.isfinite(flip[g]))


@st.composite
def kv_shapes(draw):
    """Randomized KV geometry crossing group boundaries: F = Hkv*Dh sweeps
    whole-group (F % 64 == 0) and staging-tail (F % 64 != 0) layouts."""
    b = draw(st.integers(min_value=1, max_value=2))
    s = draw(st.integers(min_value=1, max_value=6))
    hkv = draw(st.integers(min_value=1, max_value=4))
    dh = draw(st.sampled_from((8, 16, 24, 32, 48, 64)))
    seed = draw(st.integers(min_value=0, max_value=2 ** 16))
    return b, s, hkv, dh, seed


@hypothesis.given(kv_shapes())
def test_bulk_pack_equals_token_at_a_time_append(shape):
    """Per-token grouping: bulk-quantizing a whole sequence produces the
    very bytes of appending its tokens one at a time — in BOTH layouts.
    This is the invariant continuous batching and prefix packing rest on,
    here pinned across randomized batch/seq/head/tail geometry."""
    b, s, hkv, dh, seed = shape
    kv = (jax.random.normal(jax.random.PRNGKey(seed), (b, s, hkv, dh))
          * 0.3).astype(jnp.bfloat16)
    for to_layout in (lambda t: t, kvcache.to_kernel_layout):
        bulk = to_layout(kvcache.quantize_kv(kv))
        cache = jax.tree_util.tree_map(lambda t: jnp.zeros(t.shape, t.dtype),
                                       bulk)
        for i in range(s):
            cache = kvcache.append_token(cache, kv[:, i: i + 1],
                                         jnp.asarray(i))
        for key in bulk:
            np.testing.assert_array_equal(np.asarray(cache[key]),
                                          np.asarray(bulk[key]))
