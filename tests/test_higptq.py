"""HiGPTQ: error-compensated HiF4 PTQ must beat direct-cast on correlated
calibration data (the paper's Tables III-V mechanism, layer-level)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hif4
from repro.core.higptq import (
    hessian_from_activations,
    higptq_quantize,
    layer_output_error,
)


def _correlated_acts(key, n, k):
    """Activations with realistic structure (correlated features)."""
    k1, k2 = jax.random.split(key)
    base = jax.random.normal(k1, (n, k // 4), jnp.float32)
    mix = jax.random.normal(k2, (k // 4, k), jnp.float32) * 0.5
    return base @ mix + 0.1 * jax.random.normal(key, (n, k), jnp.float32)


def _direct_cast(w):
    K, N = w.shape
    g = hif4.quantize_groups(w.T.reshape(N, K // 64, 64).astype(jnp.float32))
    return hif4.dequantize_groups(g).reshape(N, K).T.astype(w.dtype)


class TestHiGPTQ:
    def test_beats_direct_cast(self):
        key = jax.random.PRNGKey(0)
        K, N, S = 256, 64, 512
        kw, kx = jax.random.split(key)
        w = jax.random.normal(kw, (K, N), jnp.float32) * 0.05
        x = _correlated_acts(kx, S, K)

        wq_gptq = higptq_quantize(w, x)
        wq_direct = _direct_cast(w)

        e_gptq = layer_output_error(w, wq_gptq, x)
        e_direct = layer_output_error(w, wq_direct, x)
        assert e_gptq < e_direct, (e_gptq, e_direct)
        # meaningful improvement, not noise
        assert e_gptq < 0.9 * e_direct, (e_gptq, e_direct)

    def test_output_on_hif4_grid(self):
        """Every HiGPTQ weight must be exactly representable in HiF4 given
        some group metadata: re-quantizing is a fixed point."""
        key = jax.random.PRNGKey(1)
        K, N = 128, 32
        w = jax.random.normal(key, (K, N), jnp.float32) * 0.02
        x = _correlated_acts(jax.random.PRNGKey(2), 256, K)
        wq = higptq_quantize(w, x)
        assert bool(jnp.all(jnp.isfinite(wq)))
        # values live on a quarter-grid of some power-of-two-ish scale:
        # direct-cast of wq changes (almost) nothing
        again = _direct_cast(wq)
        rel = float(
            jnp.linalg.norm(again - wq) / jnp.maximum(jnp.linalg.norm(wq), 1e-9)
        )
        assert rel < 0.06, rel

    def test_identity_hessian_reduces_to_direct_cast_grid(self):
        """With uncorrelated (white) activations GPTQ compensation still
        runs but the result must stay close to direct-cast quality."""
        key = jax.random.PRNGKey(3)
        K, N = 128, 16
        w = jax.random.normal(key, (K, N), jnp.float32) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(4), (2048, K), jnp.float32)
        wq = higptq_quantize(w, x)
        e_gptq = layer_output_error(w, wq, x)
        e_direct = layer_output_error(w, _direct_cast(w), x)
        assert e_gptq < e_direct * 1.05, (e_gptq, e_direct)

    def test_hessian_psd(self):
        x = _correlated_acts(jax.random.PRNGKey(5), 64, 128)
        h = hessian_from_activations(x)
        evals = jnp.linalg.eigvalsh(h)
        assert float(jnp.min(evals)) > 0
