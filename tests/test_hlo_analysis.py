"""Validate the loop-aware HLO analyzer against hand-computed costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloModule, analyze


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


class TestFlops:
    def test_plain_matmul(self):
        D = 256
        c = _compile(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32),
        )
        got = analyze(c)["flops_per_device"]
        np.testing.assert_allclose(got, 2 * D**3, rtol=0.01)

    def test_scanned_matmul_counts_trip_count(self):
        """The whole point: cost_analysis reports 1x, we must report 10x."""
        D, L = 128, 10

        def f(w, x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=L)
            return y

        c = _compile(
            f,
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32),
        )
        r = analyze(c)
        np.testing.assert_allclose(r["flops_per_device"], L * 2 * D**3, rtol=0.05)
        # and the XLA no-loop number really is ~L times smaller
        assert r["xla_flops_noloop"] < r["flops_per_device"] / (L / 2)

    def test_nested_scan(self):
        D, L1, L2 = 64, 3, 5

        def f(w, x):
            def outer(c, _):
                def inner(ci, _):
                    return jnp.tanh(ci @ w), None
                ci, _ = jax.lax.scan(inner, c, None, length=L2)
                return ci, None
            y, _ = jax.lax.scan(outer, x, None, length=L1)
            return y

        c = _compile(
            f,
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32),
        )
        got = analyze(c)["flops_per_device"]
        want = L1 * L2 * 2 * D**3
        assert want <= got <= want * 1.2, (got, want)


class TestBytesAndCollectives:
    def test_memory_bytes_lower_bound(self):
        """A big copy-like op must move at least in+out bytes."""
        S = 1 << 20
        c = _compile(
            lambda x: x * 2.0 + 1.0,
            jax.ShapeDtypeStruct((S,), jnp.float32),
        )
        b = analyze(c)["bytes_per_device"]
        assert b >= 2 * 4 * S

    def test_collective_bytes_single_allreduce(self):
        if len(jax.devices()) < 1:
            pytest.skip("needs devices")
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        import jax.experimental.shard_map as shard_map

        # single device: SPMD lowering still emits the collective when we
        # force one through shard_map over a 1-device mesh -> group size 1,
        # which the analyzer must IGNORE (g<=1). So instead just validate
        # the text-level parser on a synthetic HLO snippet.
        text = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%p), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
}
"""
        mod = HloModule(text)
        c = mod.total_cost()
        f = 16 * 1024 * 4
        np.testing.assert_allclose(c.wire_bytes, 2 * f * 15 / 16)
        assert c.coll_ops == {"all-reduce": 1}

    def test_tuple_type_with_index_comments(self):
        """Long tuple types embed /*index=N*/ comments (which contain '=');
        the instruction regex must still match the while op."""
        text = """
HloModule t, entry_computation_layout={()->f32[]}

%b (a: (s32[], f32[8], f32[8], f32[8], f32[8], f32[8], f32[8])) -> (s32[], f32[8], f32[8], f32[8], f32[8], f32[8], f32[8]) {
  %a = (s32[], f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}, f32[8]{0}) parameter(0)
  %g0 = s32[] get-tuple-element(%a), index=0
  %g1 = f32[8]{0} get-tuple-element(%a), index=1
  %e = f32[8]{0} exponential(%g1)
  %c1 = s32[] constant(1)
  %i = s32[] add(%g0, %c1)
  ROOT %t = (s32[], f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}, f32[8]{0}) tuple(%i, %e, %e, %e, %e, %e, %e)
}

%c (a.1: (s32[], f32[8], f32[8], f32[8], f32[8], f32[8], f32[8])) -> pred[] {
  %a.1 = (s32[], f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}, f32[8]{0}) parameter(0)
  %g = s32[] get-tuple-element(%a.1), index=0
  %k = s32[] constant(5)
  ROOT %lt.1 = pred[] compare(%g, %k), direction=LT
}

ENTRY %m (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}, f32[8]{0}) tuple(%z, %p, %p, %p, %p, %p, %p)
  %w = (s32[], f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}, f32[8]{0}) while(%t0), condition=%c, body=%b, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %o = f32[8]{0} get-tuple-element(%w), index=1
}
"""
        mod = HloModule(text)
        c = mod.total_cost()
        # exponential: 8 elems x 5 trips (+ tiny add counted too)
        assert 40 <= c.flops <= 50, c.flops

    def test_collective_inside_while_multiplied(self):
        text = """
HloModule test, entry_computation_layout={()->f32[]}

%body (arg: (s32[], f32[128])) -> (s32[], f32[128]) {
  %arg = (s32[], f32[128]{0}) parameter(0)
  %gte = f32[128]{0} get-tuple-element(%arg), index=1
  %ar = f32[128]{0} all-gather(%gte), channel_id=1, replica_groups=[2,256]<=[512], dimensions={0}
  %c1 = s32[] constant(1)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %add.1 = s32[] add(%gte0, %c1)
  ROOT %t = (s32[], f32[128]{0}) tuple(%add.1, %ar)
}

%cond (arg.1: (s32[], f32[128])) -> pred[] {
  %arg.1 = (s32[], f32[128]{0}) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %c8 = s32[] constant(8)
  ROOT %lt = pred[] compare(%gte.1, %c8), direction=LT
}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128]{0}) tuple(%c0, %p)
  %w = (s32[], f32[128]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
        mod = HloModule(text)
        c = mod.total_cost()
        assert c.coll_ops == {"all-gather": 8}
        f = 128 * 4
        np.testing.assert_allclose(c.wire_bytes, 8 * f * 255 / 256)


class TestRooflineShape:
    def test_terms_present_and_dominant(self):
        D = 512
        c = _compile(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((D, D), jnp.bfloat16),
            jax.ShapeDtypeStruct((D, D), jnp.bfloat16),
        )
        r = analyze(c)
        assert set(
            ["t_compute_s", "t_memory_s", "t_collective_s", "dominant"]
        ) <= set(r)
        assert r["t_collective_s"] == 0.0
        assert r["dominant"] in ("compute", "memory")
