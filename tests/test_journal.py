"""Unit + property tests for the write-ahead journal and recovery plan.

Fast tier: no model, no scheduler — just the journal codec, the torn-tail
framing guarantee, checkpoint save/load fingerprinting, the replay fold,
``recover``'s validation errors, and ``PagePool.audit``'s leak detection.
The crash-the-scheduler-and-resume end-to-end paths live in
tests/test_crash_recovery.py (faults marker); the randomized hypothesis
variants of the codec/replay properties live in
tests/test_journal_properties.py, with the deterministic versions kept
here so the invariants run even where hypothesis is absent.
"""
import json
import os
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kvcache import PagePool
from repro.runtime import journal as J
from repro.runtime.guard import JournalError, RecoveryError

# ---------------------------------------------------------------------------
# Record framing
# ---------------------------------------------------------------------------


def _events(n=5):
    evs = [{"ev": "start", "v": J.JOURNAL_VERSION, "n_requests": 2,
            "budget": 8, "eos": None, "prompts": ["a" * 64, "b" * 64]}]
    for i in range(n):
        evs.append({"ev": "chunk", "idx": i,
                    "emitted": {"0": [i, i + 1], "1": [7 * i]}})
    evs.append({"ev": "done", "rid": 0, "status": "ok", "toks": [1, 2, 3]})
    return evs


def test_codec_round_trip():
    blob = b"".join(J.encode_record(e) for e in _events())
    out, dropped = J.decode_records(blob)
    assert dropped == 0
    assert out == _events()


def test_decode_stops_at_first_bad_frame_never_misparses():
    evs = _events(3)
    blob = b"".join(J.encode_record(e) for e in evs)
    # flip one payload byte mid-stream: crc catches it, everything from
    # that record on is dropped — prefix still parses exactly
    cut = len(J.encode_record(evs[0]) + J.encode_record(evs[1]))
    bad = bytearray(blob)
    bad[cut + J.MAGIC.__len__() + 8 + 2] ^= 0xFF
    out, dropped = J.decode_records(bytes(bad))
    assert out == evs[:2]
    assert dropped == len(blob) - cut


def test_every_truncation_point_yields_a_clean_prefix():
    """Chop the stream at EVERY byte offset: the reader must return some
    record prefix plus a dropped-byte count, and never throw — this is
    the whole crash-mid-write contract."""
    evs = _events(4)
    frames = [J.encode_record(e) for e in evs]
    blob = b"".join(frames)
    bounds = [0]
    for f in frames:
        bounds.append(bounds[-1] + len(f))
    for cut in range(len(blob) + 1):
        out, dropped = J.decode_records(blob[:cut])
        n_complete = sum(1 for b in bounds[1:] if b <= cut)
        assert out == evs[:n_complete]
        assert dropped == cut - bounds[n_complete]


def test_unknown_event_kind_is_a_framing_error():
    payload = json.dumps({"ev": "gremlin"}).encode()
    frame = (J.MAGIC + len(payload).to_bytes(4, "little")
             + zlib.crc32(payload).to_bytes(4, "little") + payload)
    out, dropped = J.decode_records(frame)
    assert out == [] and dropped == len(frame)


def test_prompt_sha256_is_dtype_and_container_stable():
    a = J.prompt_sha256([3, 1, 4, 1, 5])
    b = J.prompt_sha256(np.asarray([3, 1, 4, 1, 5], np.int64))
    c = J.prompt_sha256(jnp.asarray([3, 1, 4, 1, 5], jnp.int32))
    assert a == b == c
    assert a != J.prompt_sha256([3, 1, 4, 1, 6])


# ---------------------------------------------------------------------------
# Writer: staging, activation, torn tails
# ---------------------------------------------------------------------------


def _write_journal(tmp_path, evs):
    j = J.RequestJournal(str(tmp_path))
    for e in evs:
        j.append(e["ev"], **{k: v for k, v in e.items() if k != "ev"})
    j.activate()
    j.close()
    return j


def test_journal_invisible_until_activate(tmp_path):
    j = J.RequestJournal(str(tmp_path))
    j.append("start", v=J.JOURNAL_VERSION, n_requests=0, budget=1,
             eos=None, prompts=[])
    j.commit()
    with pytest.raises(JournalError, match="nothing to resume"):
        J.read_journal(str(tmp_path))     # still staged at .tmp
    j.activate()
    evs, dropped = J.read_journal(str(tmp_path))
    assert dropped == 0 and evs[0]["ev"] == "start"
    j.close()


def test_read_journal_drops_torn_tail(tmp_path):
    j = _write_journal(tmp_path, _events(3))
    torn = 5
    with open(j.path, "r+b") as f:
        f.truncate(os.path.getsize(j.path) - torn)
    evs, dropped = J.read_journal(str(tmp_path))
    assert evs == _events(3)[:-1]         # final record torn off
    assert dropped == len(J.encode_record(_events(3)[-1])) - torn


def test_truncate_tail_matches_real_truncation(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    ja = _write_journal(a, _events(3))
    jb = J.RequestJournal(str(b))
    for e in _events(3):
        jb.append(e["ev"], **{k: v for k, v in e.items() if k != "ev"})
    jb.activate()
    jb.truncate_tail(9)
    jb.close()
    with open(ja.path, "r+b") as f:
        f.truncate(os.path.getsize(ja.path) - 9)
    assert open(ja.path, "rb").read() == open(jb.path, "rb").read()


def test_read_journal_requires_valid_start(tmp_path):
    with pytest.raises(JournalError, match="nothing to resume"):
        J.read_journal(str(tmp_path))
    path = os.path.join(str(tmp_path), J.JOURNAL_NAME)
    with open(path, "wb") as f:
        f.write(J.encode_record({"ev": "done", "rid": 0, "status": "ok",
                                 "toks": []}))
    with pytest.raises(JournalError, match="start record"):
        J.read_journal(str(tmp_path))


# ---------------------------------------------------------------------------
# Checkpoints
# ---------------------------------------------------------------------------


def _fake_snapshot(rng, npages=2, ptok=4):
    pages = {t: {"codes": rng.integers(0, 255, (npages, ptok, 8),
                                       dtype=np.uint8),
                 "meta": rng.integers(0, 255, (npages, ptok, 2),
                                      dtype=np.uint8),
                 "tail": jnp.asarray(
                     rng.standard_normal((npages, ptok, 4)),
                     jnp.bfloat16)}
             for t in ("k", "v")}
    return {"pages": pages, "token": 17, "toks": [4, 5, 6]}


def test_checkpoint_round_trip_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    residents = {0: _fake_snapshot(rng), 3: _fake_snapshot(rng)}
    fname, digest = J.save_pool_checkpoint(str(tmp_path), 7, residents)
    assert fname == "ckpt_00000007.npz"
    record = {"ev": "checkpoint", "chunk": 7, "file": fname,
              "sha256": digest,
              "residents": {str(r): {"token": s["token"], "toks": s["toks"]}
                            for r, s in residents.items()}}
    out = J.load_pool_checkpoint(str(tmp_path), record)
    assert set(out) == {0, 3}
    for rid, snap in residents.items():
        for t in ("k", "v"):
            for key in ("codes", "meta", "tail"):
                np.testing.assert_array_equal(
                    np.asarray(out[rid][t][key]).view(np.uint8),
                    np.asarray(snap["pages"][t][key]).view(np.uint8))


def test_checkpoint_sha_mismatch_and_missing_degrade_to_none(tmp_path):
    rng = np.random.default_rng(1)
    fname, digest = J.save_pool_checkpoint(str(tmp_path), 2,
                                           {1: _fake_snapshot(rng)})
    rec = {"file": fname, "sha256": digest,
           "residents": {"1": {"token": 17, "toks": [4, 5, 6]}}}
    assert J.load_pool_checkpoint(str(tmp_path), rec) is not None
    # bit-rot one byte: fingerprint must reject the whole file
    path = os.path.join(str(tmp_path), fname)
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0x01
    open(path, "wb").write(bytes(data))
    assert J.load_pool_checkpoint(str(tmp_path), rec) is None
    os.remove(path)
    assert J.load_pool_checkpoint(str(tmp_path), rec) is None
    # a record citing a resident the npz does not hold is unusable too
    fname2, digest2 = J.save_pool_checkpoint(str(tmp_path), 3,
                                             {1: _fake_snapshot(rng)})
    rec2 = {"file": fname2, "sha256": digest2,
            "residents": {"1": {"token": 17, "toks": []},
                          "9": {"token": 3, "toks": []}}}
    assert J.load_pool_checkpoint(str(tmp_path), rec2) is None


# ---------------------------------------------------------------------------
# Replay fold + recover() validation
# ---------------------------------------------------------------------------


def _chunked(rid_toks, chunk=2):
    """Split each rid's token stream into per-chunk emissions."""
    n = max((len(t) for t in rid_toks.values()), default=0)
    evs = []
    for c0 in range(0, n, chunk):
        em = {str(r): t[c0:c0 + chunk] for r, t in rid_toks.items()
              if t[c0:c0 + chunk]}
        if em:
            evs.append({"ev": "chunk", "idx": c0 // chunk, "emitted": em})
    return evs


def test_replay_accumulates_and_admission_resets():
    start = {"ev": "start", "v": 1, "n_requests": 2, "budget": 8,
             "eos": None, "prompts": ["x", "y"]}
    evs = [start,
           {"ev": "admitted", "rid": 0, "src": "prefill", "toks": [10]},
           {"ev": "admitted", "rid": 1, "src": "prefill", "toks": [20]}]
    evs += _chunked({0: [11, 12, 13], 1: [21, 22, 23]})
    evs += [{"ev": "preempted", "rid": 1},
            # rid 1 re-admitted from scratch: journaled emission RESETS
            {"ev": "admitted", "rid": 1, "src": "prefill",
             "toks": [20, 21]},
            {"ev": "done", "rid": 0, "status": "ok",
             "toks": [10, 11, 12, 13]}]
    emitted, terminal, in_flight, ckpt = J.replay(evs)
    assert emitted[0] == [10, 11, 12, 13]
    assert emitted[1] == [20, 21]          # reset, not [20,21,22,23,20,21]
    assert set(terminal) == {0} and in_flight == {1}
    assert ckpt is None


def test_replay_any_prefix_is_a_prefix_of_full_replay():
    """Replaying the first k events must yield, for every rid, a prefix
    of the full replay's emission — the determinism recovery leans on."""
    start = {"ev": "start", "v": 1, "n_requests": 3, "budget": 16,
             "eos": None, "prompts": ["a", "b", "c"]}
    evs = [start]
    for rid in range(3):
        evs.append({"ev": "admitted", "rid": rid, "src": "prefill",
                    "toks": [100 + rid]})
    evs += _chunked({r: [100 + r + 10 * i for i in range(1, 7)]
                     for r in range(3)}, chunk=2)
    full, _, _, _ = J.replay(evs)
    for k in range(1, len(evs) + 1):
        part, _, _, _ = J.replay(evs[:k])
        for rid, toks in part.items():
            assert toks == full[rid][: len(toks)], (k, rid)


def test_expected_prefix_clamps_budget_and_eos():
    plan = J.RecoveryPlan(meta={"budget": 4, "eos": 9})
    plan.emitted[0] = [1, 2, 9, 3, 4, 5]
    assert plan.expected_prefix(0) == [1, 2, 9]      # first eos wins
    plan.emitted[1] = [1, 2, 3, 4, 5, 6]
    assert plan.expected_prefix(1) == [1, 2, 3, 4]   # budget clamps
    assert plan.expected_prefix(7) == []


def _journal_for(tmp_path, prompts, *, budget=8, eos=None):
    j = J.RequestJournal(str(tmp_path))
    j.append("start", v=J.JOURNAL_VERSION, kind="paged",
             n_requests=len(prompts), budget=budget, eos=eos, chunk=2,
             prompts=[J.prompt_sha256(p) for p in prompts],
             kv_pages=4, page_tokens=4)
    j.activate()
    return j


def test_recover_validates_request_list_and_config(tmp_path):
    prompts = [[1, 2, 3], [4, 5, 6]]
    j = _journal_for(tmp_path, prompts)
    j.append("admitted", rid=0, src="prefill", toks=[7])
    j.append("done", rid=1, status="ok", toks=[8, 9])
    j.commit()
    j.close()
    with pytest.raises(RecoveryError, match="covers 2 requests"):
        J.recover(str(tmp_path), prompts[:1], budget=8, eos=None)
    with pytest.raises(RecoveryError, match=r"id\(s\) \[1\]"):
        J.recover(str(tmp_path), [prompts[0], [4, 5, 7]], budget=8,
                  eos=None)
    with pytest.raises(RecoveryError, match="budget=3"):
        J.recover(str(tmp_path), prompts, budget=3, eos=None)
    plan = J.recover(str(tmp_path), prompts, budget=8, eos=None)
    assert plan.completed[1]["toks"] == [8, 9]
    assert plan.re_prefilled == 1 and plan.replayed == 0
    assert plan.report()["dropped_bytes"] == 0


def test_recover_uses_checkpoint_and_degrades_without_it(tmp_path):
    rng = np.random.default_rng(2)
    prompts = [[1, 2, 3, 4]]
    j = _journal_for(tmp_path, prompts)
    j.append("admitted", rid=0, src="prefill", toks=[7])
    snap = _fake_snapshot(rng)
    fname, digest = J.save_pool_checkpoint(str(tmp_path), 1, {0: snap})
    j.append("checkpoint", chunk=1, file=fname, sha256=digest,
             residents={"0": {"token": snap["token"],
                              "toks": [7, 8, 9]}})
    j.commit()
    j.close()
    plan = J.recover(str(tmp_path), prompts, budget=8, eos=None)
    assert plan.replayed == 1 and plan.re_prefilled == 0
    assert plan.suspended[0]["toks"] == [7, 8, 9]
    assert plan.suspended[0]["written"] is None
    assert isinstance(plan.suspended[0]["crc32"], int)
    # now lose the npz: same journal must degrade to re-prefill
    os.remove(os.path.join(str(tmp_path), fname))
    plan2 = J.recover(str(tmp_path), prompts, budget=8, eos=None)
    assert plan2.replayed == 0 and plan2.re_prefilled == 1
    assert 0 not in plan2.suspended


def test_journal_residency_counts_bytes(tmp_path):
    assert J.journal_residency(str(tmp_path / "missing")) == {
        "journal_bytes": 0, "checkpoints": 0, "checkpoint_bytes": 0}
    j = _journal_for(tmp_path, [[1, 2]])
    j.close()
    rng = np.random.default_rng(3)
    J.save_pool_checkpoint(str(tmp_path), 1, {0: _fake_snapshot(rng)})
    res = J.journal_residency(str(tmp_path))
    assert res["journal_bytes"] == os.path.getsize(j.path)
    assert res["checkpoints"] == 1 and res["checkpoint_bytes"] > 0


# ---------------------------------------------------------------------------
# PagePool.audit
# ---------------------------------------------------------------------------


def test_audit_passes_on_honest_lifecycles():
    pool = PagePool(n_pages=6, page_tokens=4)
    a, b = pool.alloc("ra"), pool.alloc("rb")
    pool.register_full(a, (1, 2, 3, 4))
    pool.retain(a)
    counters = pool.audit(holders={"ra": [a], "rb": [b], "shared": [a]})
    assert counters["live"] == 2 and counters["free"] == 3
    pool.release(a)
    pool.release(a)                        # hashed -> parks in LRU cache
    pool.release(b)
    counters = pool.audit(holders={})
    assert counters == {"free": 4, "live": 0, "cached": 1, "hashed": 1,
                        "partials": 0}


def test_audit_catches_manufactured_leaks():
    def fresh():
        pool = PagePool(n_pages=5, page_tokens=4)
        pool.alloc("r")
        return pool

    pool = fresh()
    pool.free.remove(pool.free[-1])        # page in no structure
    with pytest.raises(AssertionError, match="leaked pages"):
        pool.audit()

    pool = fresh()
    pool.free.append(next(iter(pool.ref)))  # free AND live
    with pytest.raises(AssertionError, match="tracked twice"):
        pool.audit()

    pool = fresh()
    pid = next(iter(pool.ref))
    pool.ref[pid] = 0                       # dead refcount
    with pytest.raises(AssertionError, match="non-positive refcount"):
        pool.audit()

    pool = fresh()
    pool.partials[4] = {"key": (), "toks": []}   # partial on a free page
    with pytest.raises(AssertionError, match="partial registry"):
        pool.audit()

    pool = fresh()
    pid = next(iter(pool.ref))
    pool.key_of[pid] = (1,)                 # one-sided hash index
    with pytest.raises(AssertionError, match="disagree on size"):
        pool.audit()

    pool = fresh()
    pid = next(iter(pool.ref))
    with pytest.raises(AssertionError, match="holder counts"):
        pool.audit(holders={"r": [pid], "ghost": [pid]})
