"""Hypothesis property tests for the write-ahead journal.

Randomized event streams, truncation points, and single-byte corruptions
pin the three properties crash recovery rests on: the record codec
round-trips byte-stably, a damaged stream decodes to an exact byte-prefix
of itself (torn tails are dropped, never misparsed into bogus events),
and replaying any prefix of a journal yields per-request emissions that
are prefixes of the full replay — the determinism that lets a resumed
serve verify itself bitwise. Deterministic ci profile, same importorskip
guards as the other property suites; the deterministic unit variants
live in tests/test_journal.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.runtime import journal as J

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=50, derandomize=True)
hypothesis.settings.load_profile("ci")

_token_lists = st.lists(st.integers(min_value=0, max_value=2 ** 31 - 1),
                        max_size=6)
_event_dicts = st.one_of(
    st.builds(lambda r, t: {"ev": "admitted", "rid": r, "src": "prefill",
                            "toks": t},
              st.integers(0, 3), _token_lists),
    st.builds(lambda i, em: {"ev": "chunk", "idx": i,
                             "emitted": {str(r): t for r, t in em.items()
                                         if t}},
              st.integers(0, 99),
              st.dictionaries(st.integers(0, 3), _token_lists, max_size=3)),
    st.builds(lambda r, t: {"ev": "done", "rid": r, "status": "ok",
                            "toks": t},
              st.integers(0, 3), _token_lists),
    st.builds(lambda r: {"ev": "preempted", "rid": r}, st.integers(0, 3)),
)


def _with_start(evs):
    return [{"ev": "start", "v": 1, "n_requests": 0, "budget": 1,
             "eos": None, "prompts": []}] + evs


def _chunked(rid_toks, chunk):
    n = max((len(t) for t in rid_toks.values()), default=0)
    evs = []
    for c0 in range(0, n, chunk):
        em = {str(r): t[c0:c0 + chunk] for r, t in rid_toks.items()
              if t[c0:c0 + chunk]}
        if em:
            evs.append({"ev": "chunk", "idx": c0 // chunk, "emitted": em})
    return evs


@hypothesis.given(st.lists(_event_dicts, max_size=12))
def test_property_codec_round_trip(evs):
    evs = _with_start(evs)
    blob = b"".join(J.encode_record(e) for e in evs)
    out, dropped = J.decode_records(blob)
    assert dropped == 0 and out == evs


@hypothesis.given(st.lists(_event_dicts, max_size=12),
                  st.integers(min_value=0, max_value=400),
                  st.data())
def test_property_torn_tail_never_misparses(evs, cut_back, data):
    blob = bytearray(b"".join(J.encode_record(e)
                              for e in _with_start(evs)))
    cut = max(0, len(blob) - cut_back)
    blob = blob[:cut]
    if blob:   # optionally also corrupt one surviving byte
        i = data.draw(st.integers(0, len(blob) - 1))
        blob[i] ^= data.draw(st.integers(0, 255))
    out, dropped = J.decode_records(bytes(blob))
    # whatever parsed is a byte-identical re-encoding of a stream prefix:
    # the reader can drop data after damage, never invent or reorder it
    reblob = b"".join(J.encode_record(e) for e in out)
    assert bytes(blob[: len(reblob)]) == reblob
    assert len(reblob) + dropped == len(blob)


@hypothesis.given(st.dictionaries(st.integers(0, 3),
                                  st.lists(st.integers(0, 9), min_size=1,
                                           max_size=10), max_size=4),
                  st.integers(min_value=1, max_value=4))
def test_property_any_prefix_replay_is_deterministic(streams, chunk):
    start = {"ev": "start", "v": 1, "n_requests": len(streams),
             "budget": 32, "eos": None, "prompts": []}
    evs = [start] + [{"ev": "admitted", "rid": r, "src": "prefill",
                      "toks": t[:1]} for r, t in streams.items()]
    evs += _chunked({r: t[1:] for r, t in streams.items()}, chunk=chunk)
    full, _, _, _ = J.replay(evs)
    for k in range(1, len(evs) + 1):
        part, _, _, _ = J.replay(evs[:k])
        for rid, toks in part.items():
            assert toks == full[rid][: len(toks)]
