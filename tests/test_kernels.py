"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Sweeps shapes/dtypes per the deliverable: every kernel asserts allclose
against ref.py, and the quantize kernel is additionally anchored to the
bit-exact core.hif4 implementation of Algorithm 1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hif4
from repro.kernels import ref
from repro.kernels.bfp_matmul import bfp_matmul_quantized
from repro.kernels.hif4_quant import hif4_quantize


def _rand(key, m, k, dtype, scale=1.0):
    x = jax.random.normal(key, (m, k), jnp.float32) * scale
    return x.astype(dtype)


SHAPES = [(8, 64), (16, 128), (64, 256), (128, 512), (32, 192)]
DTYPES = [jnp.bfloat16, jnp.float32]


class TestHiF4QuantKernel:
    @pytest.mark.parametrize("m,k", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, m, k, dtype):
        x = _rand(jax.random.PRNGKey(m * k), m, k, dtype)
        ints, scales = hif4_quantize(x, block_m=min(m, 32), block_k=min(k, 128),
                                     interpret=True)
        ints_ref, scales_ref = ref.hif4_quantize_ref(x.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ints), np.asarray(ints_ref))
        np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales_ref))

    @pytest.mark.parametrize("scale_exp", [-30, -8, 0, 9])
    def test_wide_dynamic_range(self, scale_exp):
        x = _rand(jax.random.PRNGKey(7), 16, 128, jnp.float32, 2.0 ** scale_exp)
        ints, scales = hif4_quantize(x, interpret=True)
        recon = ref.hif4_dequantize_ref(ints, scales)
        rel = float(jnp.mean((recon - x) ** 2) / jnp.mean(x ** 2))
        assert rel < 0.01, rel

    def test_dequant_matches_core_algorithm1(self):
        """Kernel output dequantizes to exactly Algorithm 1's values."""
        x = _rand(jax.random.PRNGKey(3), 8, 256, jnp.bfloat16)
        ints, scales = hif4_quantize(x, interpret=True)
        got = ref.hif4_dequantize_ref(ints, scales)
        want = hif4.dequantize_groups(
            hif4.quantize_groups(x.astype(jnp.float32).reshape(8, 4, 64))
        ).reshape(8, 256)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int8_budget(self):
        """Absorbed ints stay within the 5-bit shifted budget |q| <= 28."""
        x = _rand(jax.random.PRNGKey(5), 32, 256, jnp.float32, 3.0)
        ints, _ = hif4_quantize(x, interpret=True)
        assert int(jnp.max(jnp.abs(ints.astype(jnp.int32)))) <= 28


class TestBfpMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [(8, 64, 8), (16, 128, 32),
                                       (32, 256, 64), (64, 512, 16)])
    def test_matches_ref(self, m, k, n):
        kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
        x = _rand(kx, m, k, jnp.bfloat16)
        w = _rand(kw, k, n, jnp.bfloat16).T.reshape(k, n)  # arbitrary layout
        ai, ascale = ref.hif4_quantize_ref(x.astype(jnp.float32))
        bi, bscale = ref.hif4_quantize_ref(jnp.asarray(w).T.astype(jnp.float32))
        got = bfp_matmul_quantized(
            ai, ascale, bi.T, bscale.T,
            block_m=min(m, 16), block_n=min(n, 16), block_k=min(k, 128),
            interpret=True,
        )
        want = ref.bfp_matmul_from_quantized_ref(ai, ascale, bi.T, bscale.T)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_end_to_end_close_to_f32_matmul(self):
        """Quantized matmul approximates the f32 matmul (4-bit tolerance)."""
        kx, kw = jax.random.split(jax.random.PRNGKey(11))
        m, k, n = 32, 512, 32
        x = _rand(kx, m, k, jnp.float32, 0.5)
        w = _rand(kw, k, n, jnp.float32, 0.05)
        from repro.kernels.ops import matmul
        got = matmul(x, w, block_m=16, block_n=16, block_k=128, interpret=True)
        want = x @ w
        # For zero-mean operands the output is a random walk, so per-element
        # quantization noise (~9% for two 4-bit operands) does NOT average
        # out with K; ~12% relative output error is the expected regime.
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.2, rel
        # and it must beat MXFP4 (coarser format) on the same data
        from repro.core import mxfp4
        mx = mxfp4.qdq(x, axis=-1) @ mxfp4.qdq(w, axis=0)
        rel_mx = float(jnp.linalg.norm(mx - want) / jnp.linalg.norm(want))
        assert rel < rel_mx, (rel, rel_mx)

    def test_fixed_point_flow_is_exact_vs_dequant(self):
        """Paper §III.B claim: the integer flow loses NOTHING vs computing
        in floats on dequantized values."""
        kx, kw = jax.random.split(jax.random.PRNGKey(13))
        m, k, n = 16, 128, 16
        x = _rand(kx, m, k, jnp.float32)
        w = _rand(kw, k, n, jnp.float32)
        ai, ascale = ref.hif4_quantize_ref(x)
        bi, bscale = ref.hif4_quantize_ref(w.T)
        got = bfp_matmul_quantized(ai, ascale, bi.T, bscale.T,
                                   block_m=16, block_n=16, block_k=128,
                                   interpret=True)
        a_deq = ref.hif4_dequantize_ref(ai, ascale)
        b_deq = ref.hif4_dequantize_ref(bi, bscale)
        want = a_deq @ b_deq.T
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
