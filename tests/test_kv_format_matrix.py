"""resolve_kv_format fallback loudness as a full family matrix.

Every registry arch x every requested KV format, asserting that the
``kv_format_fallback`` flag agrees with (a) the ``KVFallbackWarning``
the verbose resolve emits and (b) the format of the cache leaves
ACTUALLY served — built through ``serve_loop.build_decode_cache``, the
exact sequence ``serve`` decodes against. The enc-dec families
(audio/vlm) must serve packed HiF4 — including the whisper cross
(encoder) cache — with no fallback; only the SSM-state families
(ssm/hybrid) may narrow, and must say so.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.qlinear import QuantConfig
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime import serve_loop
from repro.runtime.scenario import prefill_batch
from repro.runtime.serve_loop import (
    KVFallbackWarning,
    ServeConfig,
    build_decode_cache,
    kv_format_fallback,
    resolve_kv_format,
)

ARCHS = ("qwen1.5-0.5b", "granite-moe-1b-a400m", "mamba2-1.3b",
         "zamba2-2.7b", "whisper-tiny", "llava-next-34b")
FALLBACK_FAMILIES = ("ssm", "hybrid")     # recurrent state: no packed layout


def _served_formats(cache):
    """kv format per attention entry actually present in a decode cache."""
    return {
        entry: "hif4" if kvcache.is_packed_kv(cache[entry]["k"]) else "bf16"
        for entry in ("kv", "self", "cross") if entry in cache
    }


@pytest.mark.slow
@pytest.mark.parametrize("requested", ["bf16", "hif4"])
@pytest.mark.parametrize("arch", ARCHS)
def test_fallback_flag_agrees_with_served_cache(arch, requested):
    cfg = get_arch(arch).reduced()
    quant = QuantConfig(fmt="hif4", impl="packed",
                        kv=kvcache.KVCacheConfig(requested))
    ctx = ModelCtx(quant=quant, remat=False, attn_q_chunk=8, attn_k_chunk=8)
    sc = ServeConfig(max_new_tokens=4, kv_format=requested)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        resolved = resolve_kv_format(cfg, quant, sc, verbose=True)
    fallback = kv_format_fallback(cfg, quant, sc)
    expected_fallback = (requested == "hif4"
                         and cfg.family in FALLBACK_FAMILIES)
    assert fallback == expected_fallback
    assert fallback == (resolved != requested)
    # loudness: narrowing must warn (a catchable KVFallbackWarning, not a
    # print); silence means no narrowing
    fb_warns = [w for w in caught
                if issubclass(w.category, KVFallbackWarning)]
    assert bool(fb_warns) == fallback
    if fallback:
        assert "falls back to bf16" in str(fb_warns[0].message)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    sp = serve_loop.prepare_params_for_serving(params, cfg, quant)
    sctx = serve_loop.serving_ctx(ctx)
    _, cache = build_decode_cache(cfg, sp, prefill_batch(cfg, 2, 16), sctx,
                                  sc, quant=quant)
    fmts = _served_formats(cache)
    if cfg.family == "ssm":
        assert fmts == {}                  # no attention cache at all
    else:
        # every attention entry served carries exactly the resolved format
        assert set(fmts.values()) == {resolved}, fmts
    if cfg.family == "audio":
        # the read-only cross (encoder) cache packs too — the former
        # permanent-fallback cell is gone
        assert fmts["cross"] == resolved
        assert set(fmts) == {"self", "cross"}
