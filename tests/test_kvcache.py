"""HiF4-packed KV cache (repro.core.kvcache): layout, round-trip, the
partial-group staging buffer, and append-one-token vs bulk equivalence —
the invariant continuous-batching parity rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hif4, kvcache


def _kv(shape, seed=0, scale=0.3):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale).astype(
        jnp.bfloat16
    )


def test_layout_shapes_and_dtypes():
    kv = _kv((2, 5, 4, 32))                        # F = 128: G=2, T=0
    pk = kvcache.quantize_kv(kv)
    assert pk["codes"].shape == (2, 5, 2, 32) and pk["codes"].dtype == jnp.uint8
    assert pk["meta"].shape == (2, 5, 2) and pk["meta"].dtype == jnp.uint32
    assert pk["tail"].shape == (2, 5, 0) and pk["tail"].dtype == jnp.bfloat16


def test_roundtrip_matches_qdq():
    """Dequantize-on-read must land exactly on the HiF4 QDQ grid (the
    reconstruction is exact in bf16)."""
    kv = _kv((2, 5, 4, 32))
    deq = kvcache.dequantize_kv(kvcache.quantize_kv(kv), 4, 32)
    want = hif4.qdq(kv.reshape(2, 5, 128).astype(jnp.float32), axis=-1)
    np.testing.assert_array_equal(
        np.asarray(deq.reshape(2, 5, 128), jnp.float32), np.asarray(want))


def test_partial_group_tail_is_exact():
    """F % 64 features stay bf16 in the staging buffer: bit-identical on
    read; whole groups still quantize."""
    kv = _kv((2, 3, 3, 24), seed=1)                # F = 72: G=1, T=8
    pk = kvcache.quantize_kv(kv)
    assert pk["codes"].shape[-2:] == (1, 32) and pk["tail"].shape[-1] == 8
    deq = kvcache.dequantize_kv(pk, 3, 24).reshape(2, 3, 72)
    flat = kv.reshape(2, 3, 72)
    np.testing.assert_array_equal(                 # tail: exact
        np.asarray(deq[..., 64:], jnp.float32),
        np.asarray(flat[..., 64:], jnp.float32))
    want = hif4.qdq(flat[..., :64].astype(jnp.float32), axis=-1)
    np.testing.assert_array_equal(                 # body: on the HiF4 grid
        np.asarray(deq[..., :64], jnp.float32), np.asarray(want))


def test_append_token_matches_bulk_quantize():
    """Per-token grouping: appending token-by-token must produce the very
    bytes of quantizing the whole sequence at once."""
    kv = _kv((2, 6, 4, 32), seed=2)
    bulk = kvcache.quantize_kv(kv)
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in bulk.items()}
    for s in range(6):
        cache = kvcache.append_token(cache, kv[:, s : s + 1], jnp.asarray(s))
    for key in bulk:
        np.testing.assert_array_equal(np.asarray(cache[key]),
                                      np.asarray(bulk[key]))


def test_append_token_per_slot_positions():
    """(B,) per-slot offsets (continuous batching): each slot's token lands
    at its own position, independent of its neighbours."""
    kv = _kv((3, 1, 4, 32), seed=3)
    bulk_rows = kvcache.quantize_kv(kv)            # (3, 1, ...) per slot
    cap = 5
    cache = {k: jnp.zeros((3, cap) + v.shape[2:], v.dtype)
             for k, v in bulk_rows.items()}
    pos = jnp.asarray([0, 2, 4], jnp.int32)
    cache = kvcache.append_token(cache, kv, pos)
    for b, p in enumerate([0, 2, 4]):
        for key in bulk_rows:
            np.testing.assert_array_equal(
                np.asarray(cache[key][b, p]), np.asarray(bulk_rows[key][b, 0]))
            # every other row untouched (zeros)
            others = np.delete(np.asarray(cache[key][b]), p, axis=0)
            assert not np.any(others)


def test_kv_bytes_per_token():
    # F = 128: 2 groups x 36 B = 72 per tensor, K+V = 144 vs 512 bf16
    assert kvcache.kv_bytes_per_token(4, 32, "bf16") == 512
    assert kvcache.kv_bytes_per_token(4, 32, "hif4") == 144
    # whole-group geometries hit the full 4.5-bit ratio
    for hkv, dh in [(4, 32), (8, 128)]:
        assert (kvcache.kv_bytes_per_token(hkv, dh, "bf16")
                / kvcache.kv_bytes_per_token(hkv, dh, "hif4")
                ) == pytest.approx(2 / 0.5625, rel=1e-6)
    # partial group pays bf16 for the tail only: G=1, T=8 at F=72
    assert kvcache.kv_bytes_per_token(3, 24, "hif4") == 2 * (36 + 16)


def test_is_packed_kv_and_nbytes():
    kv = _kv((1, 4, 4, 32))
    pk = kvcache.quantize_kv(kv)
    assert kvcache.is_packed_kv(pk) and not kvcache.is_packed_kv(kv)
    # 4 tokens x (2 groups x 36 B) per tensor
    assert kvcache.packed_kv_nbytes(pk) == 4 * 2 * 36


def test_config_validates():
    assert kvcache.KVCacheConfig("hif4").packed
    assert not kvcache.KVCacheConfig().packed
    with pytest.raises(AssertionError):
        kvcache.KVCacheConfig("int8")


# ---------------------------------------------------------------------------
# Kernel-tile layout (what the fused decode-attention kernel streams)
# ---------------------------------------------------------------------------


def test_kernel_layout_same_bits_same_values():
    """The feature-major re-layout is a pure bit move: same byte count,
    same dequantized values, idempotent, rank-discriminated."""
    kv = _kv((2, 5, 4, 32), seed=4)                # F = 128: G=2, T=0
    pk = kvcache.quantize_kv(kv)
    kl = kvcache.to_kernel_layout(pk)
    assert not kvcache.is_kernel_layout(pk) and kvcache.is_kernel_layout(kl)
    assert kl["codes"].shape == (2, 64, 5) and kl["meta"].shape == (2, 2, 5)
    assert kvcache.to_kernel_layout(kl) is kl      # idempotent
    assert kvcache.packed_kv_nbytes(kl) == kvcache.packed_kv_nbytes(pk)
    assert kvcache.seq_capacity(kl) == kvcache.seq_capacity(pk) == 5
    np.testing.assert_array_equal(
        np.asarray(kvcache.dequantize_kv(kl, 4, 32), jnp.float32),
        np.asarray(kvcache.dequantize_kv(pk, 4, 32), jnp.float32))


def test_kernel_layout_append_matches_bulk():
    """Bulk pack + re-layout == token-at-a-time appends INTO the kernel
    layout, bitwise — the invariant that lets the serving cache be resident
    in kernel order while continuous batching appends per slot."""
    kv = _kv((2, 6, 4, 32), seed=5)
    bulk = kvcache.to_kernel_layout(kvcache.quantize_kv(kv))
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in bulk.items()}
    for s in range(6):
        cache = kvcache.append_token(cache, kv[:, s : s + 1], jnp.asarray(s))
    for key in bulk:
        np.testing.assert_array_equal(np.asarray(cache[key]),
                                      np.asarray(bulk[key]))
    # per-slot positions land in kernel order too
    cache2 = {k: jnp.zeros(v.shape, v.dtype) for k, v in bulk.items()}
    for s in range(6):
        cache2 = kvcache.append_token(cache2, kv[:, s : s + 1],
                                      jnp.full((2,), s, jnp.int32))
    for key in bulk:
        np.testing.assert_array_equal(np.asarray(cache2[key]),
                                      np.asarray(bulk[key]))


def test_slice_and_pad_tokens_both_layouts():
    """slice_tokens/pad_tokens address the token axis of either layout;
    slicing commutes with dequantize (per-token grouping), padding is
    shape-only."""
    kv = _kv((2, 8, 3, 24), seed=6)                # F = 72: G=1, T=8
    for pk in (kvcache.quantize_kv(kv),
               kvcache.to_kernel_layout(kvcache.quantize_kv(kv))):
        sl = kvcache.slice_tokens(pk, 2, 4)
        assert kvcache.seq_capacity(sl) == 4
        np.testing.assert_array_equal(
            np.asarray(kvcache.dequantize_kv(sl, 3, 24), jnp.float32),
            np.asarray(kvcache.dequantize_kv(pk, 3, 24)[:, 2:6], jnp.float32))
        pad = kvcache.pad_tokens(pk, 12)
        assert kvcache.seq_capacity(pad) == 12
        np.testing.assert_array_equal(
            np.asarray(kvcache.dequantize_kv(pad, 3, 24)[:, :8], jnp.float32),
            np.asarray(kvcache.dequantize_kv(pk, 3, 24), jnp.float32))
