"""The matrix perf gates must fail LOUDLY on a doctored trajectory.

Builds a synthetic-but-valid BENCH_matrix.json record straight from the
cell declarations in benchmarks/matrix.py, checks that it (and the
committed record) pass ``matrix.check``, then doctors it one gate at a
time — dropped cells, a failed dispatch assertion, an undeclared
hif4->bf16 fallback, an enc-dec fallback, a regressed ratio, a missing
gate — and asserts every doctoring raises with the gate's name in the
message. The last test drives ``benchmarks.run.check_matrix_gates``
against a doctored file on disk: the run.py entry point itself must
raise, not skip.
"""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import matrix, run

FAMILY_OF_ARCH = {arch: family for arch, family in matrix.ARCHS.values()}


def _synthetic_record():
    """A record shaped exactly like a real --cells all --update run, with
    deterministic fake timings derived from each cell's declaration."""
    cells = []
    for i, s in enumerate(matrix.CELLS):
        fallback = "kv:fallback" in s.expect
        resolved = "bf16" if (fallback or s.kv_format == "bf16") else "hif4"
        ms = 1.0 + 0.01 * i
        cells.append({
            "name": s.name,
            "arch": s.arch,
            "family": FAMILY_OF_ARCH[s.arch],
            "impl": s.impl,
            "kv_format": s.kv_format,
            "kv_format_resolved": resolved,
            "paged": s.paged,
            "policy": s.policy,
            "batch": s.batch,
            "prompt_len": s.prompt_len,
            "new_tokens": s.new_tokens,
            "rel_tol": s.rel_tol,
            "expect": list(s.expect),
            "dispatch_ok": True,
            "dispatch_failures": [],
            "dispatch": {"kv_format_fallback": fallback},
            "decode_step_ms": round(ms, 4),
            "prefill_ms": 2.0,
            "roofline": {"bytes_per_step": 1 << 20, "mem_bw": 1 << 32,
                         "predicted_ms": 0.25, "achieved_fraction": 0.25},
        })
    by_name = {c["name"]: c for c in cells}
    # make both ratio gates pass: baseline slightly slower than subject
    for g in matrix.RATIO_GATES:
        by_name[g["baseline"]]["decode_step_ms"] = 1.0
        by_name[g["subject"]]["decode_step_ms"] = 0.95
    # the crash+resume cell carries the recovery report the
    # recovery_replay gate inspects
    by_name[matrix.RECOVERY_CELL]["recovery"] = {
        "crashed": True, "bitwise": True, "verified": 2, "replayed": 2,
        "re_prefilled": 0, "completed": 0, "dropped_bytes": 0,
        "recovery_ms": 1.5, "resume_ms": 40.0}
    return {
        "version": matrix.VERSION,
        "backend": "cpu",
        "mem_bw": 1 << 32,
        "repeats": 7,
        "ratio_gates": matrix.compute_ratio_gates(by_name),
        "cells": cells,
        # the searched-policy cell's calibration summary the
        # searched_policy_frontier gate inspects (build_calibration output)
        "calibration": {
            "cell": matrix.CALIBRATION_CELL,
            "policy": matrix.SEARCHED_POLICY,
            "arch": "qwen1.5-0.5b",
            "target": matrix.CALIBRATION_BASELINE,
            "budget_met": True,
            "n_sites": 7,
            "searched": {"total_bytes": 325632, "total_error": 11517.0,
                         "bpv": 0.9937},
            "baseline": {"total_bytes": 325632, "total_error": 13626.0,
                         "bpv": 0.9937},
        },
    }


def test_synthetic_record_passes():
    matrix.check(_synthetic_record())


def test_committed_trajectory_passes():
    """The record actually in the repo must satisfy every static gate."""
    path = matrix.OUT_PATH
    assert os.path.exists(path), "benchmarks/BENCH_matrix.json not committed"
    with open(path) as f:
        record = json.load(f)
    matrix.check(record)
    # and it must cover the declared matrix exactly
    assert {c["name"] for c in record["cells"]} == {s.name
                                                   for s in matrix.CELLS}


def test_gate_names_cover_every_enforced_gate():
    """GATE_NAMES is the documented gate vocabulary (docs lint keys off
    it); the ratio gates must be declared in it."""
    for g in matrix.RATIO_GATES:
        assert g["name"] in matrix.GATE_NAMES
    assert {"cell_coverage", "dispatch_ok",
            "no_silent_fallback"} <= matrix.GATE_NAMES


@pytest.fixture
def record():
    return _synthetic_record()


def test_doctored_version_fails(record):
    record["version"] = 0
    with pytest.raises(AssertionError, match="version"):
        matrix.check(record)


def test_doctored_cell_count_fails_coverage(record):
    record["cells"] = record["cells"][:10]
    with pytest.raises(AssertionError, match="cell_coverage"):
        matrix.check(record)


def test_doctored_family_loss_fails_coverage(record):
    record["cells"] = [c for c in record["cells"] if c["family"] != "audio"]
    with pytest.raises(AssertionError, match="cell_coverage"):
        matrix.check(record)


def test_doctored_missing_measurement_fails(record):
    record["cells"][0]["decode_step_ms"] = None
    with pytest.raises(AssertionError, match="decode_step_ms"):
        matrix.check(record)


def test_doctored_missing_roofline_prediction_fails(record):
    record["cells"][0]["roofline"]["predicted_ms"] = None
    with pytest.raises(AssertionError, match="predicted_ms"):
        matrix.check(record)


def test_doctored_dispatch_failure_fails(record):
    record["cells"][3]["dispatch_ok"] = False
    record["cells"][3]["dispatch_failures"] = ["attn:fused_decode_attention"]
    with pytest.raises(AssertionError, match="dispatch_ok"):
        matrix.check(record)


def test_doctored_silent_fallback_fails(record):
    # a dense hif4 cell that fell back without declaring kv:fallback
    cell = next(c for c in record["cells"]
                if c["family"] == "dense" and c["kv_format"] == "hif4")
    cell["dispatch"]["kv_format_fallback"] = True
    cell["kv_format_resolved"] = "bf16"
    with pytest.raises(AssertionError, match="no_silent_fallback"):
        matrix.check(record)


def test_doctored_encdec_fallback_fails_even_if_declared(record):
    # whisper/llava hif4 cells may NEVER fall back — the cross-attention
    # cache packs; declaring the fallback does not make it legal
    cell = next(c for c in record["cells"]
                if c["family"] == "audio" and c["kv_format"] == "hif4")
    cell["dispatch"]["kv_format_fallback"] = True
    cell["kv_format_resolved"] = "bf16"
    cell["expect"] = list(cell["expect"]) + ["kv:fallback"]
    with pytest.raises(AssertionError, match="enc-dec"):
        matrix.check(record)


def test_doctored_ratio_below_min_fails(record):
    gate = record["ratio_gates"][0]
    gate["value"] = 0.5
    with pytest.raises(AssertionError, match=gate["name"]):
        matrix.check(record)


def test_doctored_ratio_null_with_both_cells_fails(record):
    record["ratio_gates"][1]["value"] = None
    with pytest.raises(AssertionError, match="skipped, not inapplicable"):
        matrix.check(record)


def test_doctored_missing_gate_fails(record):
    record["ratio_gates"] = record["ratio_gates"][1:]
    with pytest.raises(AssertionError, match="gate missing"):
        matrix.check(record)


def test_doctored_recovery_not_bitwise_fails(record):
    cell = next(c for c in record["cells"]
                if c["name"] == matrix.RECOVERY_CELL)
    cell["recovery"]["bitwise"] = False
    with pytest.raises(AssertionError, match="recovery_replay"):
        matrix.check(record)


def test_doctored_missing_recovery_report_fails(record):
    cell = next(c for c in record["cells"]
                if c["name"] == matrix.RECOVERY_CELL)
    del cell["recovery"]
    with pytest.raises(AssertionError, match="recovery_replay"):
        matrix.check(record)


def test_doctored_recovery_missing_timing_fails(record):
    cell = next(c for c in record["cells"]
                if c["name"] == matrix.RECOVERY_CELL)
    del cell["recovery"]["recovery_ms"]
    with pytest.raises(AssertionError, match="recovery_ms"):
        matrix.check(record)


def test_doctored_missing_calibration_section_fails(record):
    del record["calibration"]
    with pytest.raises(AssertionError, match="searched_policy_frontier"):
        matrix.check(record)


def test_doctored_calibration_over_budget_fails(record):
    record["calibration"]["budget_met"] = False
    with pytest.raises(AssertionError, match="searched_policy_frontier"):
        matrix.check(record)


def test_doctored_searched_worse_than_baseline_fails(record):
    record["calibration"]["searched"]["total_error"] = (
        record["calibration"]["baseline"]["total_error"] + 1.0)
    with pytest.raises(AssertionError, match="searched_policy_frontier"):
        matrix.check(record)


def test_compare_flags_regression_and_dropped_expectation(record):
    fresh = copy.deepcopy(record["cells"])
    assert matrix.compare(record, fresh) == []     # identical -> in tolerance

    slow = copy.deepcopy(record["cells"])
    slow[0]["decode_step_ms"] = (record["cells"][0]["decode_step_ms"]
                                 * slow[0]["rel_tol"] * 1.5)
    fails = matrix.compare(record, slow)
    assert any("trajectory_regression" in f for f in fails)

    weakened = copy.deepcopy(record["cells"])
    weakened[0]["expect"] = weakened[0]["expect"][1:]
    fails = matrix.compare(record, weakened)
    assert any("dropped expectation" in f for f in fails)


def test_compare_within_tolerance_passes(record):
    fresh = copy.deepcopy(record["cells"])
    for c in fresh:                              # slower, but inside rel_tol
        c["decode_step_ms"] = c["decode_step_ms"] * (c["rel_tol"] * 0.9)
    assert matrix.compare(record, fresh) == []


def test_run_check_matrix_gates_fails_loudly_on_doctored_file(tmp_path,
                                                             record,
                                                             capsys):
    """The run.py entry point itself: a doctored trajectory on disk must
    raise AssertionError (so benchmarks.run exits non-zero), and a valid
    one must print the gate summary."""
    good = tmp_path / "good.json"
    good.write_text(json.dumps(record))
    run.check_matrix_gates(path=str(good))
    out = capsys.readouterr().out
    assert "[matrix gates]" in out and "dispatch assertions passed" in out

    record["cells"][5]["dispatch_ok"] = False
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(record))
    with pytest.raises(AssertionError, match="dispatch_ok"):
        run.check_matrix_gates(path=str(bad))

    with pytest.raises(AssertionError, match="missing"):
        run.check_matrix_gates(path=str(tmp_path / "absent.json"))
