"""PackedW serving path: 4.5-bit packed weights must produce EXACTLY the
same logits as offline-QDQ'd dense weights (pack/unpack is lossless on
quantized values), at 3.56x less weight residency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.qlinear import PackedW, QuantConfig, quantize_params_offline
from repro.models import lm
from repro.models.common import ModelCtx

CFG = get_arch("qwen1.5-0.5b").reduced()
CTX = ModelCtx(quant=QuantConfig(fmt="hif4", offline_weights=True),
               remat=False, attn_q_chunk=32, attn_k_chunk=32)


def test_packedw_roundtrip_2d():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 96), jnp.bfloat16) * 0.05
    p = PackedW.from_dense(w, (0,))
    deq = p.dequantize()
    assert deq.shape == (128, 96) and deq.dtype == jnp.bfloat16
    # equals direct QDQ along axis 0
    from repro.core import hif4
    want = hif4.qdq(w.astype(jnp.float32), axis=0)
    np.testing.assert_array_equal(
        np.asarray(deq.astype(jnp.float32)), np.asarray(want))
    # 3.56x storage
    packed_bytes = p.codes.size + 4 * p.meta.size
    assert packed_bytes / (w.size * 2) < 0.30


def test_packedw_roundtrip_4d_wo():
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 128), jnp.bfloat16) * 0.1
    p = PackedW.from_dense(w, (0, 1))          # contract (H, Dh)
    deq = p.dequantize()
    assert deq.shape == (128, 128)


@pytest.mark.slow
def test_packed_serving_matches_offline_qdq():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, CFG.vocab)

    # reference: offline QDQ'd dense weights
    ref_params = dict(params)
    ref_params["blocks"] = quantize_params_offline(
        params["blocks"], QuantConfig(fmt="hif4"), contract_axis=0)
    ref_logits, _ = lm.prefill(ref_params, {"tokens": tokens}, CFG, CTX)

    # packed: same quantized values, 4.5-bit buffers, dequantized in-graph
    packed_params = lm.pack_params_for_serving(params, CFG)
    logits, cache = lm.prefill(packed_params, {"tokens": tokens}, CFG, CTX)

    # packed weights only cover the default-packable matmuls; biases/norms are
    # identical, so logits should agree to bf16 tolerance
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=0.02, atol=0.02)

    # and a decode step runs
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache = lm.pad_cache(cache, CFG, 24)
    logits2, _ = lm.decode_step(packed_params, tok, cache, CFG, CTX)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.slow
def test_fully_packed_serving_residency():
    """Packed weights AND a packed KV cache together: the whole serving
    working set (weights 0.5625 B/value, cache 4.5 bits/value + tail)
    measured off the real pytrees, while decode still runs."""
    from repro.runtime.serve_loop import (
        ServeConfig, kv_cache_bytes, packed_weight_bytes,
        prepare_params_for_serving, serve)

    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    qp = QuantConfig(fmt="hif4", impl="packed")
    serving_params = prepare_params_for_serving(params, CFG, qp)
    nbytes, nvals = packed_weight_bytes(serving_params)
    assert nvals and nbytes / nvals == 0.5625

    cap = 24
    packed_cache = lm.init_cache(CFG, 2, cap, kv_format="hif4")
    bf16_cache = lm.init_cache(CFG, 2, cap, kv_format="bf16")
    pk_bytes, slots = kv_cache_bytes(packed_cache)
    bf_bytes, slots_bf = kv_cache_bytes(bf16_cache)
    assert slots == slots_bf == 2 * cap
    assert bf_bytes / pk_bytes >= 3.0          # >= 3x cache reduction

    ctx = ModelCtx(quant=qp, remat=False, attn_q_chunk=32, attn_k_chunk=32)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 8),
                                            0, CFG.vocab)}
    toks = serve(CFG, serving_params, prompts, ctx,
                 ServeConfig(max_new_tokens=4, kv_format="hif4"))
    assert toks.shape == (2, 4) and bool(jnp.all(toks >= 0))
