"""Paged HiF4 KV cache: pool primitives, paged attention parity, and the
page-pool continuous-batching scheduler.

The load-bearing claim (docs/FORMATS.md "Paged KV-cache pool"): pages
partition the token axis exactly like the kernel's KV tiles and fully
masked tiles are exact no-ops of the online-softmax recurrence, so paged
serving is BITWISE equal to contiguous/solo serving at ``block_kv = P`` on
a page-multiple capacity — paging buys admission, never bits. These tests
pin that parity at the kernel level (interpret kernel + XLA twin against
the contiguous paths), through the scheduler (shared prefixes, COW
divergence, forced preemption), and at the host allocator (PagePool
refcounts / LRU / sharing indexes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import kvcache
from repro.core.qlinear import QuantConfig
from repro.kernels import fused_attention as fa
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.serve_loop import (
    ServeConfig,
    kv_format_fallback,
    resolve_kv_format,
    serve,
    serve_requests,
)

CFG = get_arch("qwen1.5-0.5b").reduced()


def _ctx(impl="packed", **kw):
    return ModelCtx(quant=QuantConfig(fmt="hif4", impl=impl,
                                      kv=kvcache.KVCacheConfig("hif4")),
                    remat=False, attn_q_chunk=2, attn_k_chunk=2, **kw)


# ---------------------------------------------------------------------------
# Pool primitives (device-side array ops)
# ---------------------------------------------------------------------------


def test_split_pages_roundtrip_bitwise():
    """split_pages is a pure bit move: gathering the pages back in order
    reassembles the contiguous kernel-layout cache exactly."""
    Hkv, Dh, S, P = 4, 32, 40, 16
    kv = (jax.random.normal(jax.random.PRNGKey(0), (1, 1, S, Hkv, Dh))
          * 0.3).astype(jnp.bfloat16)
    pk = kvcache.to_kernel_layout(kvcache.quantize_kv(kv))   # (1, 1, F, S)
    pages = kvcache.split_pages(pk, P)                       # (1, 3, F, P)
    n = kvcache.pages_for_tokens(S, P)
    assert pages["meta"].shape[1] == n
    back = {key: jnp.moveaxis(a, 1, 2).reshape(
        a.shape[0], 1, a.shape[2], n * P)[..., :S]
        for key, a in pages.items()}
    for key in ("codes", "meta", "tail"):
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(pk[key]))


def test_append_token_paged_matches_contiguous_append():
    """One decode append through the page table writes exactly the bytes a
    contiguous kernel-layout append would — including a slot mid-page and
    a slot exactly on a page boundary."""
    Hkv, Dh, P, maxp = 4, 32, 8, 3
    B = 2
    pos = jnp.asarray([13, 16], jnp.int32)       # mid-page / page boundary
    kv_new = (jax.random.normal(jax.random.PRNGKey(1), (B, 1, Hkv, Dh))
              * 0.3).astype(jnp.bfloat16)

    pool = lm.init_paged_cache(CFG, B, 8, P, maxp)["kv"]["k"]
    layer0 = {key: a[0] for key, a in pool.items()}          # (NP, F, P)
    table = jnp.asarray([[1, 2, 0], [3, 4, 5]], jnp.int32)
    out = kvcache.append_token_paged(layer0, kv_new, pos, table)

    one = kvcache.to_kernel_layout(kvcache.quantize_kv(kv_new))
    for b, (p, row) in enumerate([(13, 1), (16, 5)]):
        pid = int(table[b, p // P])
        for key in ("codes", "meta", "tail"):
            np.testing.assert_array_equal(
                np.asarray(out[key][pid, :, p % P]),
                np.asarray(one[key][b, :, 0]))


# ---------------------------------------------------------------------------
# Paged attention parity: kernel (interpret), XLA twin, contiguous paths
# ---------------------------------------------------------------------------


def _build_paged_case(seed=0, B=2, Hkv=4, Dh=32, P=16, maxp=3):
    """Random per-slot KV prefixes scattered into a shuffled page pool,
    plus the equivalent contiguous kernel-layout cache."""
    cap = maxp * P
    lengths = jnp.asarray([cap - 5, P + 3][:B], jnp.int32)
    kv_k = (jax.random.normal(jax.random.PRNGKey(seed), (B, cap, Hkv, Dh))
            * 0.3).astype(jnp.bfloat16)
    kv_v = (jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (B, cap, Hkv, Dh)) * 0.3).astype(jnp.bfloat16)
    q = (jax.random.normal(jax.random.PRNGKey(seed + 2), (B, Hkv * 3, Dh))
         * 0.3).astype(jnp.bfloat16)

    def contiguous(kv):
        return kvcache.to_kernel_layout(kvcache.quantize_kv(kv))

    kc, vc = contiguous(kv_k), contiguous(kv_v)              # (B, F, cap)

    # scatter each slot's pages into the pool at shuffled, non-contiguous
    # ids (page 0 = scratch stays zero)
    n_pages = B * maxp + 1
    pool = kvcache.init_page_pool(1, Hkv, Dh, n_pages, P)
    ids = [[2, 5, 1], [6, 3, 4]]
    for b in range(B):
        pk = kvcache.split_pages(
            {key: a[b][None, None] for key, a in kc.items()}, P)
        pv = kvcache.split_pages(
            {key: a[b][None, None] for key, a in vc.items()}, P)
        row = jnp.asarray(ids[b], jnp.int32)
        pool["k"] = kvcache.scatter_pages(pool["k"], pk, row)
        pool["v"] = kvcache.scatter_pages(pool["v"], pv, row)
    table = jnp.asarray(ids, jnp.int32)
    k_pool = {key: a[0] for key, a in pool["k"].items()}     # (NP, F, P)
    v_pool = {key: a[0] for key, a in pool["v"].items()}
    return q, (kc, vc), (k_pool, v_pool), table, lengths, (Hkv, Dh, P)


def test_paged_attention_bitwise_vs_contiguous():
    """All four executions — paged kernel (interpret), paged XLA twin,
    contiguous kernel at block_kv=P, contiguous XLA twin — produce the SAME
    bits: the page gather only reorders DMA, never arithmetic."""
    q, (kc, vc), (kp, vp), table, lengths, (Hkv, Dh, P) = _build_paged_case()

    cont_kernel = fa.fused_decode_attention(
        q, kc, vc, lengths, n_kv_heads=Hkv, d_head=Dh, block_kv=P,
        interpret=True)
    cont_xla = fa.fused_decode_attention_xla(
        q, kc, vc, lengths, Hkv, Dh, block_kv=P)
    paged_kernel = fa.fused_paged_decode_attention(
        q, kp, vp, table, lengths, n_kv_heads=Hkv, d_head=Dh, interpret=True)
    paged_xla = fa.fused_paged_decode_attention_xla(
        q, kp, vp, table, lengths, Hkv, Dh)

    ref = np.asarray(cont_kernel)
    for got in (cont_xla, paged_kernel, paged_xla):
        np.testing.assert_array_equal(np.asarray(got), ref)


def test_paged_attention_trailing_scratch_pages_are_noops():
    """Table rows longer than the live prefix point at the zero scratch
    page; those fully masked tiles must not change a single bit."""
    q, _, (kp, vp), table, lengths, (Hkv, Dh, P) = _build_paged_case()
    # slot 1 holds P+3 tokens: logical page 2 is entirely masked — swapping
    # its table entry for the scratch page is invisible
    alt = table.at[1, 2].set(0)
    a = fa.fused_paged_decode_attention_xla(q, kp, vp, table, lengths,
                                            Hkv, Dh)
    b = fa.fused_paged_decode_attention_xla(q, kp, vp, alt, lengths,
                                            Hkv, Dh)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Host-side allocator: refcounts, LRU cache, sharing indexes
# ---------------------------------------------------------------------------


def test_page_pool_alloc_release_scratch_reserved():
    pool = kvcache.PagePool(4, 8)
    assert pool.usable_pages == 3
    got = [pool.alloc(owner="a") for _ in range(3)]
    assert 0 not in got and None not in got
    assert pool.alloc() is None                  # dry, nothing evictable
    pool.release(got[0])                         # unhashed -> frees
    assert pool.available() == 1
    assert pool.alloc(owner="b") == got[0]
    pool.audit()


def test_page_pool_refcount_and_cow_ownership():
    pool = kvcache.PagePool(4, 8)
    pid = pool.alloc(owner="a")
    pool.retain(pid)                             # sharer
    assert pool.ref[pid] == 2 and pool.owner[pid] == "a"
    pool.release(pid)                            # owner drops out
    assert pool.ref[pid] == 1                    # sharer keeps it live
    pool.audit(holders={"sharer": [pid]})


def test_page_pool_lru_cache_revive_and_evict():
    pool = kvcache.PagePool(4, 8)
    a, b, c = (pool.alloc(owner="r") for _ in range(3))
    pool.register_full(a, (1, 2))
    pool.register_full(b, (1, 2, 3, 4))
    for pid in (a, b, c):
        pool.release(pid)
    # a, b park in the LRU cache (hashed); c frees (unhashed)
    assert list(pool.cached) == [a, b] and pool.free == [c]
    # a prefix hit revives b out of the cache
    assert pool.lookup_full((1, 2, 3, 4)) == b
    pool.retain(b)
    assert b not in pool.cached and pool.ref[b] == 1
    # pool dry -> alloc evicts the LRU cached page (a) and drops its hash
    pool.alloc(owner="x")                        # takes the free page c
    got = pool.alloc(owner="x")
    assert got == a and pool.evictions == 1
    assert pool.lookup_full((1, 2)) is None
    pool.audit()


def test_page_pool_partial_registry_prefix_match():
    pool = kvcache.PagePool(4, 8)
    pid = pool.alloc(owner="a")
    pool.register_partial(pid, (7, 8), [1, 2, 3])
    assert pool.lookup_partial((7, 8), [1, 2]) == pid
    assert pool.lookup_partial((7, 8), [1, 9]) is None       # diverges
    assert pool.lookup_partial((0,), [1, 2]) is None         # wrong prefix
    assert pool.lookup_partial((7, 8), [1, 2, 3, 4]) is None  # too long
    # promoting the page to a hashed full drops it from the registry
    pool.register_full(pid, (7, 8, 1, 2, 3))
    assert pool.lookup_partial((7, 8), [1, 2]) is None
    pool.audit(holders={"a": [pid]})


def test_page_pool_register_full_first_writer_wins():
    pool = kvcache.PagePool(4, 8)
    a, b = pool.alloc(), pool.alloc()
    pool.register_full(a, (1,))
    pool.register_full(b, (1,))                  # duplicate: stays unshared
    assert pool.lookup_full((1,)) == a
    assert b not in pool.key_of
    pool.audit(holders={"x": [a], "y": [b]})


# ---------------------------------------------------------------------------
# Paged scheduler vs solo serving (bitwise)
# ---------------------------------------------------------------------------


def _solo(params, r, ctx, P, cap, budget, eos=None):
    solo_ctx = dataclasses.replace(ctx, attn_kv_block=P)
    sc = ServeConfig(max_new_tokens=budget, cache_capacity=cap,
                     kv_format="hif4", eos_id=eos)
    return serve(CFG, params, {"tokens": r[None, :]}, solo_ctx, sc)[0]


@pytest.mark.slow
def test_paged_scheduler_matches_solo_shared_prefix():
    """Mixed prompt lengths with a common 12-token prefix through the page
    pool: per-request outputs bitwise equal solo serving, and the prefix
    pages are actually shared."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    prefix = jax.random.randint(jax.random.PRNGKey(5), (12,), 0, CFG.vocab)
    reqs = [jnp.concatenate([prefix, jax.random.randint(
        jax.random.PRNGKey(30 + i), (4 + 2 * i,), 0, CFG.vocab)])
        for i in range(3)]                       # prompts 16, 18, 20
    ctx = _ctx()
    P, budget = 8, 6
    cap = 32                                     # page multiple >= 20 + 6
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2, cache_capacity=cap,
                     kv_format="hif4", kv_pages=9, kv_page_tokens=P)
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=3, stats=stats)
    assert stats["scheduler"] == "paged"
    assert stats["shared_page_hits"] >= 1        # the shared prefix page
    assert stats["pool_audit"]["live"] == 0      # serve-end invariant audit
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(res[i]), np.asarray(_solo(params, r, ctx, P, cap,
                                                 budget)))


@pytest.mark.slow
def test_paged_scheduler_prompt_on_page_boundary():
    """A prompt filling its pages EXACTLY (16 = 2 x P) must admit cleanly
    and put its first decode token at offset 0 of a fresh page."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    r = jax.random.randint(jax.random.PRNGKey(9), (16,), 0, CFG.vocab)
    ctx = _ctx()
    P, budget, cap = 8, 4, 24
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2, cache_capacity=cap,
                     kv_format="hif4", kv_pages=6, kv_page_tokens=P)
    res = serve_requests(CFG, params, [r], ctx, sc, slots=1)
    np.testing.assert_array_equal(
        np.asarray(res[0]), np.asarray(_solo(params, r, ctx, P, cap, budget)))


@pytest.mark.slow
def test_paged_scheduler_single_token_pages():
    """P=1 is the degenerate page size: every token its own page, the table
    IS the token order. Still bitwise vs solo at block_kv=1."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    r = jax.random.randint(jax.random.PRNGKey(11), (4,), 0, CFG.vocab)
    ctx = _ctx()
    P, budget, cap = 1, 3, 7
    sc = ServeConfig(max_new_tokens=budget, cache_capacity=cap,
                     kv_format="hif4", kv_pages=8, kv_page_tokens=P)
    res = serve_requests(CFG, params, [r], ctx, sc, slots=1)
    np.testing.assert_array_equal(
        np.asarray(res[0]), np.asarray(_solo(params, r, ctx, P, cap, budget)))


@pytest.mark.slow
def test_paged_scheduler_cow_divergence():
    """B's prompt is a strict prefix of A's that ends INSIDE A's live tail
    page: B shares the page via the partial registry, then its first
    append lands there and must copy-on-write — A's bytes never change and
    both stay bitwise vs solo."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    a = jax.random.randint(jax.random.PRNGKey(13), (20,), 0, CFG.vocab)
    reqs = [a, a[:18]]
    ctx = _ctx()
    P, budget, cap = 8, 6, 32
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2, cache_capacity=cap,
                     kv_format="hif4", kv_pages=10, kv_page_tokens=P)
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=2, stats=stats)
    # 2 full prefix pages + the live partial tail page
    assert stats["shared_page_hits"] >= 3
    assert stats["pool_audit"]["live"] == 0
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(res[i]), np.asarray(_solo(params, r, ctx, P, cap,
                                                 budget)))


@pytest.mark.slow
def test_paged_scheduler_preemption_bitwise():
    """A pool too small for both sequences' decode growth: the younger slot
    is preempted mid-admission (its page BYTES snapshotted), restored after
    the older retires, and still finishes bitwise equal to solo serving."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [jax.random.randint(jax.random.PRNGKey(15 + i), (8,), 0,
                               CFG.vocab) for i in range(2)]
    ctx = _ctx()
    P, budget, cap = 4, 8, 16
    # 5 usable pages; each sequence needs 4 -> they cannot both finish
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2, cache_capacity=cap,
                     kv_format="hif4", kv_pages=6, kv_page_tokens=P)
    stats: dict = {}
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=2, stats=stats)
    assert stats["preemptions"] >= 1
    assert stats["pool_audit"]["live"] == 0
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(res[i]), np.asarray(_solo(params, r, ctx, P, cap,
                                                 budget)))


@pytest.mark.slow
def test_paged_scheduler_eos_matches_solo():
    """eos handling through the paged retire path: a request stopping early
    returns exactly solo's eos-padded result."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    r = jax.random.randint(jax.random.PRNGKey(21), (12,), 0, CFG.vocab)
    ctx = _ctx()
    P, budget, cap = 8, 6, 24
    solo_free = _solo(params, r, ctx, P, cap, budget)
    eos = int(solo_free[2])                      # stop after the 3rd token
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2, cache_capacity=cap,
                     kv_format="hif4", kv_pages=8, kv_page_tokens=P,
                     eos_id=eos)
    res = serve_requests(CFG, params, [r], ctx, sc, slots=1)
    np.testing.assert_array_equal(
        np.asarray(res[0]),
        np.asarray(_solo(params, r, ctx, P, cap, budget, eos=eos)))


# ---------------------------------------------------------------------------
# Legacy slot scheduler: retire() eos regressions (satellite)
# ---------------------------------------------------------------------------


def _eos_case(eos_pick):
    """Serve 3 mixed-length requests through 2 slots with an eos chosen
    from one request's solo output; every request must match its own solo
    serve under the same eos."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [jax.random.randint(jax.random.PRNGKey(50 + i), (8 + 4 * i,), 0,
                               CFG.vocab) for i in range(3)]
    ctx = _ctx()
    budget = 6
    solo_free = serve(CFG, params, {"tokens": reqs[0][None, :]}, ctx,
                      ServeConfig(max_new_tokens=budget, kv_format="hif4"))
    eos = eos_pick(np.asarray(solo_free[0]))
    sc = ServeConfig(max_new_tokens=budget, decode_chunk=2,
                     kv_format="hif4", eos_id=eos)
    res = serve_requests(CFG, params, reqs, ctx, sc, slots=2)
    for i, r in enumerate(reqs):
        solo = serve(CFG, params, {"tokens": r[None, :]}, ctx,
                     ServeConfig(max_new_tokens=budget, kv_format="hif4",
                                 eos_id=eos))
        np.testing.assert_array_equal(np.asarray(res[i]), np.asarray(solo[0]))


@pytest.mark.slow
def test_retire_eos_at_first_token():
    """eos emitted by prefill itself: the slot retires before any decode
    chunk ran for it, and the result is budget-length eos padding."""
    _eos_case(lambda toks: int(toks[0]))


@pytest.mark.slow
def test_retire_eos_near_budget():
    """eos on the LAST budgeted token: the trim-to-budget and pad-past-eos
    paths of retire() compose without off-by-one."""
    _eos_case(lambda toks: int(toks[-1]))


@pytest.mark.slow
def test_retire_no_eos_token_matches_eos_free():
    """An eos id that never appears must serve exactly like eos_id=None."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [jax.random.randint(jax.random.PRNGKey(60 + i), (8 + 4 * i,), 0,
                               CFG.vocab) for i in range(3)]
    ctx = _ctx()
    sc_free = ServeConfig(max_new_tokens=6, decode_chunk=2, kv_format="hif4")
    res_free = serve_requests(CFG, params, reqs, ctx, sc_free, slots=2)
    emitted = {int(t) for r in res_free for t in np.asarray(r)}
    eos = next(t for t in range(CFG.vocab) if t not in emitted)
    res_eos = serve_requests(CFG, params, reqs, ctx,
                             dataclasses.replace(sc_free, eos_id=eos),
                             slots=2)
    for a, b in zip(res_eos, res_free):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# KV-format fallback loudness (satellite)
# ---------------------------------------------------------------------------


def test_kv_fallback_loud_and_recorded():
    """A family without a packed KV layout must fall back to bf16 LOUDLY
    (verbose resolve emits a catchable KVFallbackWarning) and visibly
    (kv_format_fallback=True for the records benchmark/dryrun carry) —
    never silently."""
    import warnings

    from repro.runtime.serve_loop import KVFallbackWarning

    ssm = get_arch("mamba2-1.3b").reduced()
    quant = QuantConfig(fmt="hif4", impl="qdq",
                        kv=kvcache.KVCacheConfig("hif4"))
    sc = ServeConfig()
    assert resolve_kv_format(ssm, quant, sc) == "bf16"
    with pytest.warns(KVFallbackWarning, match="falls back to bf16"):
        assert resolve_kv_format(ssm, quant, sc, verbose=True) == "bf16"
    assert kv_format_fallback(ssm, quant, sc) is True
    # a KV-cache family narrows nothing and warns nothing
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert resolve_kv_format(CFG, quant, sc, verbose=True) == "hif4"
    assert not [w for w in caught
                if issubclass(w.category, KVFallbackWarning)]
    assert kv_format_fallback(CFG, quant, sc) is False


def test_paged_pool_requires_hif4():
    """kv_pages on a bf16 cache (or a fallen-back family) must refuse, not
    silently serve unpaged."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    r = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, CFG.vocab)
    sc = ServeConfig(max_new_tokens=2, kv_format="bf16", kv_pages=4,
                     kv_page_tokens=8)
    with pytest.raises(AssertionError, match="paged KV pool"):
        serve_requests(CFG, params, [r], _ctx(), sc, slots=1)
