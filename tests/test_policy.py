"""QuantPolicy resolution: rule precedence, glob matching against real
param trees, preset goldens, policy-driven packing, and the back-compat
guarantee — a uniform policy is BITWISE identical to the legacy global
QuantConfig on every impl."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import (
    PRESETS,
    QuantPolicy,
    QuantRule,
    get_policy,
    known_policy_spec,
)
from repro.core.qlinear import PackedW, QuantConfig, quantize_params_offline
from repro.runtime.guard import ArtifactLayoutError
from repro.models import lm
from repro.models.common import ModelCtx
from repro.runtime.serve_loop import (
    ServeConfig,
    load_serving_artifact,
    prepare_params_for_serving,
    save_serving_artifact,
    serve,
    serving_ctx,
)

CFG = get_arch("qwen1.5-0.5b").reduced()            # dense family
MOE_CFG = get_arch("phi3.5-moe-42b-a6.6b").reduced()


def _ctx(plan=None, quant=None):
    return ModelCtx(quant=quant if quant is not None else plan.base,
                    plan=plan, remat=False, attn_q_chunk=32, attn_k_chunk=32)


# ---------------------------------------------------------------------------
# Rule semantics
# ---------------------------------------------------------------------------


def test_rule_precedence_later_wins():
    pol = QuantPolicy(rules=(
        QuantRule("*", fmt="hif4", impl="packed"),
        QuantRule("*.attn.*", fmt="nvfp4"),
        QuantRule("*.attn.wq", fmt="none"),
    ))
    assert pol.config_at("blocks.mlp.wg").fmt == "hif4"
    assert pol.config_at("blocks.attn.wk").fmt == "nvfp4"
    assert pol.config_at("blocks.attn.wq").fmt == "none"
    # unset fields inherit from earlier rules
    assert pol.config_at("blocks.attn.wq").impl == "packed"
    # unmatched sites stay unquantized
    assert not QuantPolicy(rules=(QuantRule("mlp.*", fmt="hif4"),)
                           ).config_at("blocks.attn.wq").enabled


def test_pattern_matches_trailing_subpath():
    r = QuantRule("attn.wq")
    assert r.matches("blocks.attn.wq") and r.matches("attn.wq")
    assert not r.matches("blocks.xattn.wq")        # 'xattn' != '.attn'
    assert QuantRule("moe.*").matches("blocks.moe.wg")
    assert QuantRule("lm_head").matches("lm_head")
    assert not QuantRule("lm_head").matches("blocks.attn.wq")


# ---------------------------------------------------------------------------
# Resolution against real param trees
# ---------------------------------------------------------------------------


def test_resolve_dense_tree_sites_and_packing():
    plan = lm.quant_plan(CFG, QuantConfig(fmt="hif4", impl="packed"))
    paths = {s.path for s in plan.sites}
    assert {"blocks.attn.wq", "blocks.attn.wo", "blocks.mlp.wg",
            "blocks.mlp.wo", "embed", "lm_head"} <= paths
    assert plan.packed_paths == {
        "blocks.attn.wq", "blocks.attn.wk", "blocks.attn.wv",
        "blocks.attn.wo", "blocks.mlp.wg", "blocks.mlp.wu", "blocks.mlp.wo",
    }
    # §IV default rules: sensitive sites unquantized, embed clamped
    assert plan.at("lm_head").fmt == "none"
    assert plan.at("embed").fmt == "none"
    # tied embeddings: the lm_head site exists but has no offline artifact
    assert CFG.tie_embeddings and not plan.site("lm_head").quantize_offline


def test_resolve_moe_tree_excludes_experts_from_packing():
    plan = lm.quant_plan(MOE_CFG, QuantConfig(fmt="hif4", impl="packed"))
    assert plan.at("blocks.moe.router").fmt == "none"    # §IV-C default rule
    assert plan.at("blocks.moe.wg").fmt == "hif4"        # experts quantize...
    assert "blocks.moe.wg" not in plan.packed_paths      # ...but never pack
    assert "blocks.attn.wq" in plan.packed_paths
    # glob over the moe subtree flips the experts off in one rule
    pol = QuantPolicy(rules=(QuantRule("*", fmt="hif4", impl="packed"),
                             QuantRule("moe.*", fmt="none")))
    plan2 = pol.resolve(lm.abstract_params(MOE_CFG), family=MOE_CFG.family)
    assert plan2.at("blocks.moe.wg").fmt == "none"
    assert plan2.at("blocks.attn.wq").fmt == "hif4"


def test_preset_goldens():
    for name in PRESETS:
        assert known_policy_spec(name)
    assert known_policy_spec("uniform:hif4")
    assert not known_policy_spec("uniform:bogus")
    assert not known_policy_spec("no-such-preset")

    plan = lm.quant_plan(CFG, get_policy("paper-iv", impl="packed"))
    assert plan.at("blocks.attn.wq").fmt == "hif4"
    assert plan.at("blocks.attn.wq").impl == "packed"
    assert plan.at("lm_head").fmt == "none"
    assert plan.at("embed").fmt == "none"

    plan = lm.quant_plan(CFG, get_policy("sensitive-fallback", impl="packed"))
    assert plan.at("blocks.attn.wo").fmt == "none"
    assert plan.at("blocks.mlp.wo").fmt == "none"
    assert plan.at("blocks.attn.wq").fmt == "hif4"
    assert "blocks.attn.wo" not in plan.packed_paths
    assert "blocks.attn.wq" in plan.packed_paths

    plan = lm.quant_plan(CFG, get_policy("nvfp4-baseline"))
    assert plan.at("blocks.attn.wq").fmt == "nvfp4_pts"
    assert not plan.packed_paths                   # no packed container

    with pytest.raises(ValueError):
        get_policy("no-such-preset")


def test_policy_json_roundtrip():
    pol = get_policy("sensitive-fallback", impl="pallas")
    back = QuantPolicy.from_json_dict(
        json.loads(json.dumps(pol.to_json_dict())))
    assert back == pol


def test_get_policy_json_file_honors_impl_and_kv(tmp_path):
    """A policy file that only sets fmt must still serve under the
    launcher's --impl/--kv-format: impl arrives as a base catch-all rule
    (file rules still win) and kv fills in only when the file is silent."""
    from repro.core.kvcache import KVCacheConfig

    path = tmp_path / "pol.json"
    path.write_text(json.dumps({"name": "file-pol", "rules": [
        {"pattern": "*", "fmt": "hif4"},
        {"pattern": "*.mlp.*", "fmt": "hif4", "impl": "qdq"},
    ]}))
    pol = get_policy(str(path), impl="packed", kv=KVCacheConfig("hif4"))
    assert pol.config_at("blocks.attn.wq").impl == "packed"
    assert pol.config_at("blocks.mlp.wg").impl == "qdq"   # file rule wins
    assert pol.kv.kv_format == "hif4"
    path.write_text(json.dumps({"name": "file-pol", "kv_format": "bf16",
                                "rules": [{"pattern": "*", "fmt": "hif4"}]}))
    assert get_policy(str(path), kv=KVCacheConfig("hif4")).kv.kv_format == "bf16"


def test_plan_ctx_derives_quant_from_plan():
    """ModelCtx(plan=plan) without an explicit quant must dispatch KV and
    packed attention off the plan's attention-site config, not NO_QUANT."""
    from repro.core.kvcache import KVCacheConfig

    plan = lm.quant_plan(CFG, get_policy("paper-iv", impl="packed",
                                         kv=KVCacheConfig("hif4")))
    ctx = ModelCtx(plan=plan)
    assert ctx.quant == plan.base
    assert ctx.quant.impl == "packed" and ctx.quant.kv.kv_format == "hif4"


# ---------------------------------------------------------------------------
# Back-compat: uniform policy == legacy global config, bitwise, per impl
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["qdq", "packed", "pallas"])
def test_uniform_policy_bitwise_equals_legacy(impl):
    """The uniform shim must reproduce the pre-policy paths exactly: same
    serving artifact, same prefill logits, same decode-step logits — to
    the bit, on every impl."""
    qc = QuantConfig(fmt="hif4", impl=impl)
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, CFG.vocab)

    legacy_params = prepare_params_for_serving(params, CFG, qc)
    plan = lm.quant_plan(CFG, QuantPolicy.uniform(qc))
    policy_params = prepare_params_for_serving(params, CFG, plan)
    for a, b in zip(jax.tree_util.tree_leaves(legacy_params),
                    jax.tree_util.tree_leaves(policy_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    lctx = serving_ctx(_ctx(quant=qc))
    pctx = serving_ctx(_ctx(plan=plan))
    l_logits, l_cache = lm.prefill(legacy_params, {"tokens": tokens}, CFG, lctx)
    p_logits, p_cache = lm.prefill(policy_params, {"tokens": tokens}, CFG, pctx)
    np.testing.assert_array_equal(np.asarray(l_logits), np.asarray(p_logits))

    tok = jnp.argmax(l_logits, -1).astype(jnp.int32)
    l_cache = lm.pad_cache(l_cache, CFG, 12)
    p_cache = lm.pad_cache(p_cache, CFG, 12)
    l2, _ = lm.decode_step(legacy_params, tok, l_cache, CFG, lctx)
    p2, _ = lm.decode_step(policy_params, tok, p_cache, CFG, pctx)
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(p2))


@pytest.mark.parametrize("impl", ["qdq", "packed"])
def test_uniform_policy_serve_tokens_match_legacy(impl):
    qc = QuantConfig(fmt="hif4", impl=impl)
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                            0, CFG.vocab)}
    plan = lm.quant_plan(CFG, QuantPolicy.uniform(qc))
    t_legacy = serve(CFG, params, prompts, _ctx(quant=qc),
                     ServeConfig(max_new_tokens=6))
    t_policy = serve(CFG, params, prompts, _ctx(plan=plan),
                     ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(np.asarray(t_legacy), np.asarray(t_policy))


# ---------------------------------------------------------------------------
# Policy-driven packing + mixed-policy serving
# ---------------------------------------------------------------------------


def test_packing_decided_solely_by_policy():
    """A rule flipping one site away from hif4/packed must un-pack exactly
    that site — the packed leaf set IS the plan's packed set."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    plan = lm.quant_plan(CFG, get_policy("sensitive-fallback", impl="packed"))
    sp = prepare_params_for_serving(params, CFG, plan)

    def packed_leaf_paths(tree, prefix=()):
        out = set()
        for k, v in tree.items():
            if isinstance(v, PackedW):
                out.add(".".join(prefix + (k,)))
            elif isinstance(v, dict):
                out |= packed_leaf_paths(v, prefix + (k,))
        return out

    assert packed_leaf_paths(sp) == plan.packed_paths
    # the bf16-fallback sites keep their ORIGINAL dense weights
    np.testing.assert_array_equal(
        np.asarray(sp["blocks"]["attn"]["wo"]),
        np.asarray(params["blocks"]["attn"]["wo"]))
    # and the mixed artifact serves end-to-end through the packed path
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8),
                                            0, CFG.vocab)}
    toks = serve(CFG, sp, prompts, _ctx(plan=plan),
                 ServeConfig(max_new_tokens=4))
    assert toks.shape == (2, 4) and bool(jnp.all(toks >= 0))


def test_paper_iv_serves_end_to_end_packed():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    plan = lm.quant_plan(CFG, get_policy("paper-iv", impl="packed"))
    sp = prepare_params_for_serving(params, CFG, plan)
    assert isinstance(sp["blocks"]["attn"]["wq"], PackedW)
    assert not isinstance(sp["embed"], PackedW)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8),
                                            0, CFG.vocab)}
    toks = serve(CFG, sp, prompts, _ctx(plan=plan),
                 ServeConfig(max_new_tokens=4))
    assert toks.shape == (2, 4)


def test_offline_qdq_routes_through_plan():
    """Satellite: the offline-PTQ predicate and the packing predicate are
    one resolution — plan-driven quantize_params_offline must equal the
    legacy structural path for the uniform policy."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    qc = QuantConfig(fmt="hif4", impl="qdq")
    legacy = quantize_params_offline(params["blocks"], qc)
    plan = lm.quant_plan(CFG, QuantPolicy.uniform(qc))
    via_plan = quantize_params_offline(params["blocks"], qc, plan=plan,
                                       prefix="blocks")
    for a, b in zip(jax.tree_util.tree_leaves(legacy),
                    jax.tree_util.tree_leaves(via_plan)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and a per-site fmt flip reaches the offline artifact
    pol = QuantPolicy(rules=(QuantRule("*", fmt="hif4", impl="qdq"),
                             QuantRule("*.mlp.wg", fmt="none")))
    plan2 = lm.quant_plan(CFG, pol)
    mixed = quantize_params_offline(params["blocks"], qc, plan=plan2,
                                    prefix="blocks")
    np.testing.assert_array_equal(                  # flipped site untouched
        np.asarray(mixed["mlp"]["wg"]), np.asarray(params["blocks"]["mlp"]["wg"]))
    assert not np.array_equal(                      # quantized site changed
        np.asarray(mixed["attn"]["wq"]), np.asarray(params["blocks"]["attn"]["wq"]))


# ---------------------------------------------------------------------------
# Artifact serialization: the policy rides inside the checkpoint
# ---------------------------------------------------------------------------


def test_serving_artifact_roundtrip(tmp_path):
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    policy = get_policy("sensitive-fallback", impl="packed")
    # packed trees may already be in the (irreversible) kernel layout —
    # the artifact writer must refuse them instead of corrupting the disk
    with pytest.raises(ArtifactLayoutError, match="already-packed"):
        save_serving_artifact(str(tmp_path),
                              prepare_params_for_serving(params, CFG, policy),
                              CFG, policy)
    save_serving_artifact(str(tmp_path), params, CFG, policy)
    loaded, loaded_policy = load_serving_artifact(str(tmp_path), CFG)
    assert loaded_policy == policy

    plan = lm.quant_plan(CFG, loaded_policy)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8),
                                            0, CFG.vocab)}
    t_loaded = serve(CFG, loaded, prompts, _ctx(plan=plan),
                     ServeConfig(max_new_tokens=4))
    t_direct = serve(CFG, prepare_params_for_serving(params, CFG, plan),
                     prompts, _ctx(plan=plan), ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(np.asarray(t_loaded), np.asarray(t_direct))
