"""Record filtering in benchmarks/roofline.py: load_records must drop
error records, and table() must enforce the mesh/quant/shard filter (the
fsdp/seq_shard condition was once a dead no-op branch — these tests pin
that it now actually filters)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import roofline


def _rec(arch="a", mesh="16x16", quant="hif4", fsdp=True, seq_shard=False,
         **over):
    r = {
        "arch": arch, "shape": "decode", "mesh": mesh, "quant": quant,
        "fsdp": fsdp, "seq_shard": seq_shard,
        "roofline": {"t_compute_s": 1e-3, "t_memory_s": 2e-3,
                     "t_collective_s": 5e-4, "dominant": "memory"},
        "useful_flops_ratio": 0.5,
        "memory": {"peak_bytes_est": 2 ** 30},
    }
    r.update(over)
    return r


def test_load_records_skips_error_records(tmp_path):
    with open(tmp_path / "a.json", "w") as f:
        json.dump(_rec(arch="good"), f)
    with open(tmp_path / "b.json", "w") as f:
        json.dump({"error": "OOM", "mesh": "16x16"}, f)
    recs = roofline.load_records(str(tmp_path))
    assert [r["arch"] for r in recs] == ["good"]


def test_table_filters_mesh_quant_and_shard_flags():
    recs = [
        _rec(arch="keep"),
        _rec(arch="wrong-mesh", mesh="2x16x16"),
        _rec(arch="wrong-quant", quant="bf16"),
        _rec(arch="fsdp-off", fsdp=False),
        _rec(arch="no-shard-flag", seq_shard=None),
    ]
    del recs[4]["seq_shard"]                      # flag absent entirely
    rows = roofline.table(recs, mesh="16x16", quant="hif4")
    assert [r["arch"] for r in rows] == ["keep"]
    # seq_shard must be an explicit bool; both True and False qualify
    rows = roofline.table([_rec(arch="sp", seq_shard=True), _rec(arch="dp")])
    assert sorted(r["arch"] for r in rows) == ["dp", "sp"]


def test_table_rows_and_markdown_shape():
    rows = roofline.table([_rec()])
    assert rows[0]["dominant"] == "memory"
    assert rows[0]["t_memory_ms"] == pytest.approx(2.0)
    md = roofline.markdown(rows, "t")
    assert md.startswith("### t") and "| a | decode |" in md


def test_stream_bandwidth_and_prediction():
    """The serve-matrix wiring: a measured positive bandwidth and the
    bytes -> predicted-ms conversion it feeds."""
    bw = roofline.measure_stream_bandwidth(nbytes=1 << 16, repeats=2)
    assert bw > 0
    assert roofline.predict_step_ms(bw, bw) == pytest.approx(1e3)
    assert roofline.predict_step_ms(0, bw) == 0.0
