"""The scenario harness executes real serve cells and probes honestly.

Runs two tiny cells (dense hif4 + audio hif4) through
``run_scenarios`` and pins (a) the record schema the matrix gates
consume, (b) that the analytic dispatch probe agrees with
``engine.attention_dispatch_info`` evaluated on the cache leaves the
cell ACTUALLY served (built via ``build_decode_cache``) — the probe is
trusted because the serve jit cache prevents runtime spying, so this
equivalence is the load-bearing test.
"""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import engine as qengine
from repro.runtime import scenario, serve_loop
from repro.runtime.scenario import Scenario, run_scenarios

CELLS = (
    Scenario(name="dense-hif4", arch="qwen1.5-0.5b", impl="packed",
             kv_format="hif4", batch=1, prompt_len=8, new_tokens=4,
             expect=("kv:hif4", "kv:no-fallback",
                     "attn:fused_decode_attention", "matmul:fused")),
    Scenario(name="audio-hif4", arch="whisper-tiny", impl="packed",
             kv_format="hif4", batch=1, prompt_len=8, new_tokens=4,
             expect=("kv:hif4", "kv:no-fallback",
                     "attn:fused_decode_attention", "matmul:fused")),
)


@pytest.fixture(scope="module")
def records():
    return {r["name"]: r for r in run_scenarios(CELLS, repeats=2,
                                                log=lambda *_: None)}


@pytest.mark.slow
def test_record_schema_and_measurements(records):
    assert set(records) == {"dense-hif4", "audio-hif4"}
    for rec in records.values():
        assert rec["dispatch_ok"] is True, rec["dispatch_failures"]
        assert rec["dispatch_failures"] == []
        assert rec["decode_step_ms"] > 0
        assert rec["prefill_ms"] > 0
        assert rec["timing"] == "scan-interleaved"
        assert rec["kv_format_resolved"] == "hif4"
        assert rec["dispatch"]["kv_format_fallback"] is False
        ro = rec["roofline"]
        assert ro["bytes_per_step"] == (ro["weight_bytes"] + ro["kv_bytes"]
                                        + ro["state_bytes"])
        assert ro["weight_bytes"] > 0 and ro["kv_bytes"] > 0
    # whisper decodes against self + cross caches; qwen against one kv
    assert (records["audio-hif4"]["roofline"]["kv_bytes"]
            != records["dense-hif4"]["roofline"]["kv_bytes"])


@pytest.mark.slow
@pytest.mark.parametrize("scn", CELLS, ids=lambda s: s.name)
def test_probe_agrees_with_served_cache(scn):
    """probe_dispatch == attention_dispatch_info on the served leaves."""
    cfg, ctx, sp = scenario._build_cell(scn)
    sc = scenario._serve_cfg(scn)
    probe = scenario.probe_dispatch(cfg, ctx.quant, sc, sp,
                                    batch=scn.batch,
                                    prompt_len=scn.prompt_len)
    sctx = serve_loop.serving_ctx(ctx)
    _, cache = serve_loop.build_decode_cache(
        cfg, sp, scenario.prefill_batch(cfg, scn.batch, scn.prompt_len),
        sctx, sc, quant=ctx.quant)
    entry = cache["self"] if cfg.family == "audio" else cache["kv"]
    a = cfg.attn
    actual = qengine.attention_dispatch_info(
        ctx.quant, entry["k"], n_kv_heads=a.n_kv_heads, d_head=a.d_head)
    assert actual["kernel_eligible"] == probe["attn"]["kernel_eligible"]
    assert actual["route"] == probe["attn"]["route"]
    assert actual["execution"] == probe["attn"]["execution"]
