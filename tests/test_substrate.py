"""Data pipeline, checkpointing, fault-tolerant resume, serving, straggler
monitor, gradient compression (error-feedback math + multi-device wire test
in a subprocess)."""
import json
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.core.qlinear import QuantConfig
from repro.data import SyntheticLMDataset
from repro.models.common import ModelCtx
from repro.optim.grad_compress import ef_compress_step, qdq_flat
from repro.runtime import ServeConfig, TrainLoopConfig, serve, train


class TestData:
    def test_deterministic(self):
        d1 = SyntheticLMDataset(512, 32, 4, seed=7)
        d2 = SyntheticLMDataset(512, 32, 4, seed=7)
        for _ in range(3):
            b1, b2 = next(d1), next(d2)
            np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                          np.asarray(b2["tokens"]))

    def test_state_resume(self):
        d1 = SyntheticLMDataset(512, 32, 4, seed=7)
        for _ in range(5):
            next(d1)
        state = d1.state_dict()
        want = next(d1)
        d2 = SyntheticLMDataset(512, 32, 4, seed=7)
        d2.load_state_dict(state)
        got = next(d2)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(want["tokens"]))

    def test_learnable_structure(self):
        """Next token is mostly an affine function of the current one."""
        b = next(SyntheticLMDataset(512, 64, 8, seed=0))["tokens"]
        t, nxt = np.asarray(b[:, :-1]), np.asarray(b[:, 1:])
        agree = np.mean(nxt == (31 * t + 17) % 512)
        assert agree > 0.85, agree


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 3, tree, {"step": 3})
        assert latest_step(str(tmp_path)) == 3
        got, extra = load_checkpoint(str(tmp_path), 3, tree, verify=True)
        assert extra["step"] == 3
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
        assert got["b"]["c"].dtype == jnp.bfloat16

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        save_checkpoint(str(tmp_path), 1, tree)
        # simulate a crash mid-write: directory without manifest
        os.makedirs(tmp_path / "step_00000002")
        (tmp_path / "step_00000002" / "arr_00000.npy").write_bytes(b"junk")
        assert latest_step(str(tmp_path)) == 1

    def test_keeps_latest(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 5, 3):
            save_checkpoint(str(tmp_path), s, tree)
        assert latest_step(str(tmp_path)) == 5


CFG = get_arch("qwen1.5-0.5b").reduced()
CTX = ModelCtx(quant=QuantConfig(fmt="hif4"), remat=False,
               attn_q_chunk=32, attn_k_chunk=32)


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        _, _, hist = train(CFG, CTX, TrainLoopConfig(
            steps=30, global_batch=8, seq_len=64, log_every=100))
        first = np.mean(hist["loss"][:5])
        last = np.mean(hist["loss"][-5:])
        assert last < first - 0.5, (first, last)

    def test_kill_and_resume_is_bit_deterministic(self, tmp_path):
        """The fault-tolerance contract: a killed-and-restarted run follows
        the exact same trajectory as an uninterrupted one. The optimizer
        schedule is pinned explicitly (a crash doesn't change the config)."""
        from repro.optim.adamw import AdamWConfig

        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        ref_dir, ft_dir = str(tmp_path / "ref"), str(tmp_path / "ft")
        loop = dict(global_batch=4, seq_len=32, checkpoint_every=4)
        _, _, ref = train(CFG, CTX, TrainLoopConfig(
            steps=10, checkpoint_dir=ref_dir, **loop), opt_cfg=opt)
        # "crash" after 6 steps (checkpoint at 4), then restart to 10
        train(CFG, CTX, TrainLoopConfig(steps=6, checkpoint_dir=ft_dir, **loop),
              opt_cfg=opt)
        _, _, ft = train(CFG, CTX, TrainLoopConfig(
            steps=10, checkpoint_dir=ft_dir, **loop), opt_cfg=opt)
        # resumed run re-executes steps 6..9; its losses must match exactly
        np.testing.assert_allclose(ref["loss"][-4:], ft["loss"][-4:], rtol=1e-5)

    def test_straggler_monitor_field(self):
        _, _, hist = train(CFG, CTX, TrainLoopConfig(
            steps=6, global_batch=2, seq_len=32))
        assert "stragglers" in hist


class TestServe:
    def test_batched_greedy_decode(self):
        import repro.models.lm as lm
        params = lm.init_params(CFG, jax.random.PRNGKey(0))
        prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                (2, 16), 0, CFG.vocab)}
        toks = serve(CFG, params, prompts, CTX, ServeConfig(max_new_tokens=8))
        assert toks.shape == (2, 8)
        assert toks.dtype == jnp.int32
        assert int(jnp.max(toks)) < CFG.vocab

    def test_quantized_vs_bf16_serving_agreement(self):
        """HiF4-served tokens should mostly agree with bf16 greedy tokens
        on a model with smooth logits (direct-cast quality check)."""
        import repro.models.lm as lm
        params = lm.init_params(CFG, jax.random.PRNGKey(0))
        prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(2),
                                                (2, 16), 0, CFG.vocab)}
        t_q = serve(CFG, params, prompts, CTX, ServeConfig(max_new_tokens=4))
        t_f = serve(CFG, params, prompts,
                    ModelCtx(remat=False, attn_q_chunk=32, attn_k_chunk=32),
                    ServeConfig(max_new_tokens=4))
        assert t_q.shape == t_f.shape


class TestGradCompress:
    def test_error_feedback_unbiased_over_steps(self):
        """sum of EF-compressed grads -> sum of true grads (residual stays
        bounded), the property that keeps compressed SGD convergent."""
        key = jax.random.PRNGKey(0)
        g_true = jnp.zeros((1000,))
        g_sent = jnp.zeros((1000,))
        err = jnp.zeros((1000,))
        for i in range(20):
            g = jax.random.normal(jax.random.fold_in(key, i), (1000,)) * (
                10.0 ** ((i % 5) - 2)
            )
            q, err = ef_compress_step(g, err)
            g_true = g_true + g
            g_sent = g_sent + q
        resid = float(jnp.linalg.norm(g_true - g_sent - err))
        assert resid < 1e-3 * float(jnp.linalg.norm(g_true)), resid

    def test_qdq_flat_relative_error(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (777,)) * 1e-6
        y = qdq_flat(x)
        rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
        assert rel < 0.1, rel

    def test_compressed_psum_multidevice_subprocess(self):
        """Real all_to_all/all_gather wire path on 4 fake devices."""
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
try:                                  # jax >= 0.5
    from jax.sharding import AxisType
    mesh_kw = {"axis_types": (AxisType.Auto,)}
except ImportError:                   # older jax: Auto is implicit
    mesh_kw = {}
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
import sys; sys.path.insert(0, "src")
from repro.optim.grad_compress import compressed_psum

mesh = jax.make_mesh((4,), ("data",), **mesh_kw)
x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024)) * 0.1

body = lambda v: compressed_psum(v[0], "data", 4)[None]
try:
    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                  check_vma=False)
except TypeError:                     # older jax spells it check_rep
    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                  check_rep=False)
got = np.asarray(f(x))
want = np.asarray(jnp.mean(x, axis=0))
for i in range(4):
    rel = np.linalg.norm(got[i] - want) / np.linalg.norm(want)
    assert rel < 0.15, rel
print("OK")
"""
        r = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
