"""Docs lint: fail when README/docs reference symbols or files that no
longer exist.

Scans the prose docs (README.md, docs/*.md, ROADMAP.md) and the module
docstrings of the kernel package (``src/repro/kernels/*.py`` — the modules
whose prose makes cross-module claims about layouts and test anchors) for

  * dotted ``repro...`` references (``repro.core.kvcache``,
    ``repro.models.attention.decode_attention_packed``, ...): the longest
    importable module prefix is imported and the remainder resolved with
    getattr — a renamed function or deleted module fails the lint;
  * repo-relative file references (``docs/FORMATS.md``,
    ``benchmarks/serve_throughput.py``, ``tests/test_engine.py``, ...):
    the path must exist;
  * quantization-policy preset references (``--policy paper-iv``,
    backticked ``uniform:<fmt>`` spellings, and backticked hyphenated
    names on lines that mention a policy/preset): the name must resolve
    in the ``repro.core.policy`` preset registry — docs advertising a
    renamed or deleted preset fail CI;
  * matrix perf-gate references (the ``gate:`name``` spelling): the name
    must be declared in ``benchmarks.matrix.GATE_NAMES`` — docs
    documenting a gate ``check_matrix_gates`` does not enforce fail CI;
  * serve-status references (the ``status:`name``` spelling): the name
    must be declared in ``repro.runtime.guard.STATUS_NAMES`` — the
    failure-semantics docs promise per-request terminal statuses, and a
    doc naming a status the scheduler never emits fails CI;
  * fault-class references (the ``fault:`name``` spelling): the name
    must be declared in ``repro.runtime.faults.FAULT_CLASSES`` — the
    failure-semantics and crash-recovery docs enumerate the injectable
    fault/crash classes, and a doc naming one the injector cannot fire
    fails CI.

Runs as a section of ``benchmarks/run.py`` and as the tier-1 test
``tests/test_docs.py``, so stale docs break CI instead of readers.

    PYTHONPATH=src python -m tools.check_docs
"""
from __future__ import annotations

import importlib
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# CHANGES.md is deliberately excluded: it is an append-only historical log
# whose old entries legitimately name since-renamed symbols.
DOC_FILES = ["README.md", "ROADMAP.md", "docs"]

# repro.a.b or repro.a.b.symbol — at least one dotted component
SYMBOL_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
# repo-relative paths with a known top-level dir and a file extension
PATH_RE = re.compile(
    r"\b(?:docs|tests|benchmarks|examples|tools|src)/[\w./-]+\.(?:py|md|json)\b"
)

# policy-preset references: `--policy <name>` CLI spellings anywhere, plus
# backticked preset-shaped tokens (`uniform:<fmt>` always; hyphenated
# names only on lines that talk about a policy/preset, so `--kv-format`
# prose doesn't false-positive). JSON paths are policy files, not presets.
POLICY_FLAG_RE = re.compile(r"--policy[ =]+([A-Za-z0-9_:.\-/]+)")
POLICY_UNIFORM_RE = re.compile(r"`(uniform:[A-Za-z0-9_]+)`")
POLICY_NAME_RE = re.compile(r"`([a-z0-9]+(?:-[a-z0-9]+)+)`")

# matrix perf-gate references: docs spell them gate:`name` so the lint
# can tell a gate claim from ordinary backticked code
GATE_RE = re.compile(r"gate:`([A-Za-z0-9_]+)`")

# per-request serve statuses: docs spell them status:`name` so the
# failure-semantics vocabulary stays pinned to the scheduler's enum
STATUS_RE = re.compile(r"status:`([A-Za-z0-9_]+)`")

# injectable fault/crash classes: docs spell them fault:`name` so the
# recovery-matrix vocabulary stays pinned to the injector's enum
FAULT_RE = re.compile(r"fault:`([A-Za-z0-9_]+)`")


def _policy_candidates(text: str) -> set:
    cands = set(POLICY_FLAG_RE.findall(text))
    cands |= set(POLICY_UNIFORM_RE.findall(text))
    for line in text.splitlines():
        if "policy" in line.lower() or "preset" in line.lower():
            for name in POLICY_NAME_RE.findall(line):
                if not name.startswith("--"):
                    cands.add(name)
    return {c for c in cands
            if not c.endswith(".json") and "/" not in c and "<" not in c}


# Code packages whose MODULE DOCSTRINGS are linted like prose docs: kernel
# modules document payload layouts and name their test/doc anchors, and a
# renamed anchor must fail CI the same way a stale README does.
DOCSTRING_DIRS = ["src/repro/kernels"]


def _doc_paths() -> list[str]:
    out = []
    for entry in DOC_FILES:
        full = os.path.join(REPO, entry)
        if os.path.isdir(full):
            out.extend(
                os.path.join(full, f) for f in sorted(os.listdir(full))
                if f.endswith(".md")
            )
        elif os.path.exists(full):
            out.append(full)
    return out


def _docstring_paths() -> list[str]:
    out = []
    for entry in DOCSTRING_DIRS:
        full = os.path.join(REPO, entry)
        if os.path.isdir(full):
            out.extend(
                os.path.join(full, f) for f in sorted(os.listdir(full))
                if f.endswith(".py")
            )
    return out


def _resolve_symbol(dotted: str) -> str | None:
    """Return an error string, or None if the reference resolves."""
    parts = dotted.split(".")
    # find the longest importable module prefix
    mod, n_mod = None, 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            n_mod = i
            break
        except ImportError:
            continue
        except Exception as e:  # import-time crash is a real doc problem too
            return f"importing {'.'.join(parts[:i])} raised {e!r}"
    if mod is None:
        return "no importable module prefix"
    obj = mod
    for attr in parts[n_mod:]:
        if not hasattr(obj, attr):
            return f"{'.'.join(parts[:n_mod])} has no attribute {attr!r}"
        obj = getattr(obj, attr)
    return None


def check_file(path: str, docstring_only: bool = False) -> list[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    rel = os.path.relpath(path, REPO)
    errors = []
    if docstring_only:
        import ast

        text = ast.get_docstring(ast.parse(text)) or ""
        # Kernel modules carry the payload-layout and test-anchor prose
        # this lint exists for: a NEW kernel module shipped without a
        # module docstring would otherwise pass vacuously.
        if not text.strip() and not os.path.basename(path).startswith("__"):
            return [f"{rel}: kernel module has no module docstring "
                    f"(layout/anchor prose is required, see DOCSTRING_DIRS)"]
    for dotted in sorted(set(SYMBOL_RE.findall(text))):
        err = _resolve_symbol(dotted)
        if err is not None:
            errors.append(f"{rel}: dead symbol `{dotted}` ({err})")
    for ref in sorted(set(PATH_RE.findall(text))):
        if not os.path.exists(os.path.join(REPO, ref)):
            errors.append(f"{rel}: dead file reference `{ref}`")
    from repro.core.policy import known_policy_spec

    for name in sorted(_policy_candidates(text)):
        if not known_policy_spec(name):
            errors.append(
                f"{rel}: unknown policy preset `{name}` (not in the "
                f"repro.core.policy registry)")
    gate_refs = sorted(set(GATE_RE.findall(text)))
    if gate_refs:
        from benchmarks.matrix import GATE_NAMES

        for name in gate_refs:
            if name not in GATE_NAMES:
                errors.append(
                    f"{rel}: unknown matrix gate gate:`{name}` (not in "
                    f"benchmarks.matrix.GATE_NAMES)")
    status_refs = sorted(set(STATUS_RE.findall(text)))
    if status_refs:
        from repro.runtime.guard import STATUS_NAMES

        for name in status_refs:
            if name not in STATUS_NAMES:
                errors.append(
                    f"{rel}: unknown serve status status:`{name}` (not in "
                    f"repro.runtime.guard.STATUS_NAMES)")
    fault_refs = sorted(set(FAULT_RE.findall(text)))
    if fault_refs:
        from repro.runtime.faults import FAULT_CLASSES

        for name in fault_refs:
            if name not in FAULT_CLASSES:
                errors.append(
                    f"{rel}: unknown fault class fault:`{name}` (not in "
                    f"repro.runtime.faults.FAULT_CLASSES)")
    return errors


def run() -> list[str]:
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.path.insert(0, REPO)                   # for benchmarks.matrix
    errors = []
    for path in _doc_paths():
        errors.extend(check_file(path))
    for path in _docstring_paths():
        errors.extend(check_file(path, docstring_only=True))
    return errors


def main():
    errors = run()
    for e in errors:
        print(f"[check_docs] {e}")
    n_files = len(_doc_paths()) + len(_docstring_paths())
    assert not errors, f"{len(errors)} dead doc references (see above)"
    print(f"[check_docs] {n_files} doc files clean")


if __name__ == "__main__":
    main()
